"""The paper's own experiment, end to end (Figures 6-8 on LeNet/CIFAR-10).

Runs a batch-256 LeNet inference pass under MONOLITHIC / FLEXIBLE_DMA /
SIDEBAR with relu and softplus, printing the latency / energy / EDP table
and checking the paper's claims.

Run: PYTHONPATH=src python examples/lenet_paper_workload.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DEFAULT_TABLE,
    ExecutionMode,
    account_model,
    estimate,
    normalized_edp,
    run,
)
from repro.models import lenet


def main():
    lenet.register_pooling(DEFAULT_TABLE)
    params = lenet.engine_params(lenet.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 3, 32, 32), jnp.float32)

    for act in ("relu", "softplus"):
        graphs = lenet.to_layer_graphs(batch=256, activation=act)
        print(f"\n=== LeNet inference, activation = {act} ===")
        print(f"{'design':<18}{'latency (us)':>13}{'energy (mJ)':>13}"
              f"{'norm. EDP':>11}{'vs mono':>9}")
        ests = {m.value: estimate(account_model(graphs, m, DEFAULT_TABLE))
                for m in ExecutionMode}
        norm = normalized_edp(ests)
        mono_lat = ests["monolithic"].latency_s
        for mode in ExecutionMode:
            e = ests[mode.value]
            print(f"{mode.value:<18}{e.latency_s*1e6:>13.1f}"
                  f"{e.energy_j*1e3:>13.3f}{norm[mode.value]:>11.3f}"
                  f"{e.latency_s/mono_lat:>9.3f}")

        # run numerically too (correctness across modes)
        outs = {}
        for mode in ExecutionMode:
            out = x
            for g in graphs:
                out = run(g, params, out, mode, DEFAULT_TABLE).output
            outs[mode] = np.asarray(out)
        ok = all(
            np.allclose(outs[m], outs[ExecutionMode.MONOLITHIC], atol=1e-4)
            for m in ExecutionMode
        )
        print(f"numerics identical across designs: {ok}")

    print("\nPaper claims (Figure 6/8, softplus):")
    graphs = lenet.to_layer_graphs(batch=256, activation="softplus")
    ests = {m.value: estimate(account_model(graphs, m, DEFAULT_TABLE))
            for m in ExecutionMode}
    norm = normalized_edp(ests)
    dma_gap = ests["flexible_dma"].latency_s / ests["monolithic"].latency_s
    sb_gap = ests["sidebar"].latency_s / ests["monolithic"].latency_s
    print(f"  flexible-DMA latency overhead: {100*(dma_gap-1):.1f}% "
          f"(paper: 8-14%)")
    print(f"  sidebar latency overhead:      {100*(sb_gap-1):.1f}% "
          f"(paper: <=2%)")
    print(f"  flexible-DMA EDP:              {norm['flexible_dma']:.2f}x "
          f"(paper: ~1.5x)")
    print(f"  sidebar EDP:                   {norm['sidebar']:.2f}x "
          f"(paper: ~1.07x)")


if __name__ == "__main__":
    main()
