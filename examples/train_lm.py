"""End-to-end fault-tolerant training driver.

Trains an LM with the full production loop: deterministic data pipeline,
microbatched AdamW, async checkpointing, auto-resume, straggler watchdog.
Defaults are CPU-sized (a ~10M-param llama-style model, 40 steps); the
same driver scales to the full configs on a real mesh:

  # CPU demo (about a minute):
  PYTHONPATH=src python examples/train_lm.py

  # ~115M-param model, a few hundred steps (longer):
  PYTHONPATH=src python examples/train_lm.py --d-model 768 --layers 12 \
      --heads 12 --d-ff 3072 --vocab 32000 --steps 200

  # kill it at any point and re-run: it resumes from the last checkpoint.
"""

import argparse

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell, TrainConfig
from repro.launch.train import Trainer
from repro.launch.roofline import count_params
from repro.models import layers as L
from repro.models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    args = ap.parse_args()

    cfg = ModelConfig(
        arch_id="train-lm-demo", family="dense",
        num_layers=args.layers, d_model=args.d_model, num_heads=args.heads,
        num_kv_heads=max(1, args.heads // 2), d_ff=args.d_ff,
        vocab_size=args.vocab, dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
    total, emb, _ = count_params(get_model(cfg).param_specs(cfg, L.HOST))
    print(f"model: {total/1e6:.1f}M params ({(total-emb)/1e6:.1f}M non-embed)")

    cell = ShapeCell("train_demo", args.seq, args.batch, "train")
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       microbatch_per_device=max(1, args.batch // 2),
                       grad_compression=args.compression)
    trainer = Trainer(cfg, tcfg, cell, ckpt_dir=args.ckpt_dir, ckpt_every=10)
    report = trainer.run(args.steps)
    if report.resumed_from:
        print(f"resumed from checkpoint at step {report.resumed_from}")
    print(f"ran {report.steps_run} steps; "
          f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f}; "
          f"stragglers={report.straggler_events}")


if __name__ == "__main__":
    main()
