"""End-to-end serving driver (the paper is an inference paper).

Serves a small LM with batched requests: bucket prompts, prefill once,
greedy-decode N tokens per request, report tokens/s. Architecture is
selectable (--arch, smoke-scale configs on CPU).

Run: PYTHONPATH=src python examples/serve_batch.py --arch deepseek-7b \
         --batch 4 --prompt-len 32 --gen 16 \
         --execution-mode sidebar_pipelined --pipeline-depth 4
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.core.modes import ExecutionMode, LayerPlan
from repro.launch.serve import Server
from repro.models.registry import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=cfglib.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument(
        "--execution-mode", default="sidebar",
        choices=[ExecutionMode.SIDEBAR.value,
                 ExecutionMode.SIDEBAR_PIPELINED.value],
        help="sidebar kernel variant backing the fused MLP ops",
    )
    ap.add_argument(
        "--pipeline-depth", type=int, default=2,
        help="VMEM ring depth T for sidebar_pipelined (>= 1)",
    )
    args = ap.parse_args()

    cfg = cfglib.get_smoke_config(args.arch)
    api = get_model(cfg)
    plan = LayerPlan(ExecutionMode(args.execution_mode),
                     depth=args.pipeline_depth)
    print(f"arch={cfg.arch_id} (reduced config for CPU), "
          f"batch={args.batch}, prompt={args.prompt_len}, gen={args.gen}, "
          f"mode={plan.mode.value}, depth={plan.depth}")

    params = api.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=args.prompt_len + args.gen,
                    plan=plan)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, dtype=jnp.int32,
    )
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.num_image_tokens, cfg.d_model), cfg.dtype)

    # warmup (compile)
    server.generate(prompts, 2, extra)
    t0 = time.perf_counter()
    result = server.generate(prompts, args.gen, extra)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.gen
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s on CPU)")
    print("sample continuation ids:",
          result.tokens[0, args.prompt_len:args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
