"""End-to-end serving driver (the paper is an inference paper).

Static-batch mode: bucket prompts, prefill once, scan-compiled greedy
decode of N tokens in ONE dispatch (``--decode loop`` keeps the PR-2
per-token loop for comparison). ``--pipeline-depths 2,4`` builds a
per-layer ``ExecutionPlan`` (layer i gets depth[i % len]) so different
layers trace different sidebar kernel variants.

Continuous mode (``--continuous``): mixed-length traffic through the
slot scheduler — bucketed admission into freed slots between scan-
compiled decode segments, one persistent slot KV cache, and an
executable cache keyed by (bucket, plan).

Run: PYTHONPATH=src python examples/serve_batch.py --arch deepseek-7b \
         --batch 4 --prompt-len 32 --gen 16 \
         --execution-mode sidebar_pipelined --pipeline-depth 4
     PYTHONPATH=src python examples/serve_batch.py --continuous \
         --requests 8 --slots 4 --segment 8
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.core.modes import ExecutionMode, ExecutionPlan, LayerPlan
from repro.launch.sampling import SamplingParams
from repro.launch.scheduler import (
    ContinuousBatchingServer,
    PagedContinuousBatchingServer,
)
from repro.launch.serve import Server
from repro.models.registry import get_model


def build_sampling(args) -> SamplingParams | None:
    temperature = args.temperature
    if temperature is None:
        if args.top_k is None and args.top_p is None:
            return None  # no sampling flags at all -> greedy
        temperature = 1.0  # top-k/top-p imply sampling
    return SamplingParams(temperature=temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.seed)


def build_faults(args):
    """``--faults site=rate,...`` -> a seeded ``FaultInjector`` (sites:
    alloc, evict_storm, stage_stall — see launch/faults.py)."""
    if not args.faults:
        return None
    from repro.launch.faults import FaultInjector

    rates = {}
    for part in args.faults.split(","):
        site, rate = part.split("=")
        rates[site.strip()] = float(rate)
    return FaultInjector(seed=args.fault_seed, rates=rates,
                         max_per_site=args.max_faults_per_site)


def build_spec(args, cfg, params, api):
    """``--spec-k K`` -> a ``SpecConfig`` (K drafted tokens per row per
    step). ``--draft-arch`` picks the draft model; the default (unset)
    is the ORACLE draft — the target itself drafts, so greedy
    acceptance is exactly 1.0 and the run measures pure
    draft+verify overhead. Output tokens are bit-identical to plain
    decode either way; only throughput depends on the draft."""
    if not args.spec_k:
        return None
    from repro.launch.spec import SpecConfig

    if args.draft_arch is None:
        return SpecConfig(draft_cfg=cfg, draft_params=params, k=args.spec_k)
    draft_cfg = cfglib.get_smoke_config(args.draft_arch)
    draft_api = get_model(draft_cfg)
    draft_params = draft_api.init(jax.random.PRNGKey(1), draft_cfg)
    return SpecConfig(draft_cfg=draft_cfg, draft_params=draft_params,
                      k=args.spec_k)


def build_mesh(args):
    """``--mesh RxC`` (or RxCxP) -> a canonical serving mesh; the
    "model" (last) axis is the tensor-parallel degree. Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get more
    than one CPU device."""
    if not args.mesh:
        return None
    from repro.launch.mesh import make_serving_mesh

    shape = tuple(int(d) for d in args.mesh.lower().split("x"))
    return make_serving_mesh(shape)


def build_plan(args, cfg):
    mode = ExecutionMode(args.execution_mode)
    if args.pipeline_depths:
        depths = [int(d) for d in args.pipeline_depths.split(",")]
        per_layer = [
            LayerPlan(ExecutionMode.SIDEBAR_PIPELINED,
                      depth=depths[i % len(depths)])
            for i in range(cfg.num_layers)
        ]
        return ExecutionPlan.by_index(per_layer)
    return LayerPlan(mode, depth=args.pipeline_depth)


def run_static(args, cfg, api, params, plan):
    sample = build_sampling(args)
    print(f"arch={cfg.arch_id} (reduced config for CPU), "
          f"batch={args.batch}, prompt={args.prompt_len}, gen={args.gen}, "
          f"plan={plan}, decode={args.decode}, sample={sample}")
    server = Server(cfg, params, max_len=args.prompt_len + args.gen,
                    plan=plan, mesh=build_mesh(args))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, dtype=jnp.int32,
    )
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.num_image_tokens, cfg.d_model), cfg.dtype)

    # warmup (compile) — same gen length so the timed call reuses the
    # cached N-step scan executable instead of tracing it
    server.generate(prompts, args.gen, extra, decode=args.decode,
                    sample=sample)
    t0 = time.perf_counter()
    result = server.generate(prompts, args.gen, extra, decode=args.decode,
                             sample=sample)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.gen
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s on CPU)")
    print("sample continuation ids:",
          result.tokens[0, args.prompt_len:args.prompt_len + 8].tolist())


def run_continuous(args, cfg, api, params, plan):
    sample = build_sampling(args)
    mesh = build_mesh(args)
    max_len = args.prompt_len + args.gen
    spec = build_spec(args, cfg, params, api)
    if spec is not None and not args.paged:
        raise SystemExit("--spec-k requires --paged (the verifier runs "
                         "through the block pool)")
    if args.paged:
        # block_size must divide max_len; snap to the nearest divisor
        bs = args.block_size
        while max_len % bs:
            bs -= 1
        sched = PagedContinuousBatchingServer(
            cfg, params, num_slots=args.slots, max_len=max_len,
            block_size=bs, prefill_chunk=args.prefill_chunk,
            segment=args.segment, plan=plan, kernel=args.kernel,
            mesh=mesh, spec=spec,
        )
        kind = f"paged (block_size={bs}, kernel={args.kernel}"
        if spec is not None:
            kind += (f", spec k={spec.k} "
                     f"draft={spec.draft_cfg.arch_id}"
                     f"{' (oracle)' if args.draft_arch is None else ''}")
        kind += ")"
    else:
        sched = ContinuousBatchingServer(
            cfg, params, num_slots=args.slots, max_len=max_len,
            buckets=(args.prompt_len // 2, args.prompt_len),
            segment=args.segment, plan=plan, mesh=mesh,
        )
        kind = "slab"
    if mesh is not None:
        kind += (f", mesh={'x'.join(map(str, mesh.devices.shape))} "
                 f"{tuple(mesh.axis_names)}")
    print(f"arch={cfg.arch_id} continuous [{kind}]: "
          f"requests={args.requests}, slots={args.slots}, "
          f"segment={args.segment}, plan={plan}, sample={sample}")
    rng = np.random.RandomState(0)
    # paged traffic carries a shared prefix (the chat system-prompt
    # shape) so the smoke exercises prefix-cache splicing; slab traffic
    # keeps the full [2, prompt_len) length spread so BOTH admission
    # buckets stay covered
    prefix = rng.randint(0, cfg.vocab_size, size=args.prompt_len // 2)
    useful = 0
    for i in range(args.requests):
        gen = int(rng.randint(1, args.gen))
        useful += gen
        if args.paged:
            tail = int(rng.randint(2, max(3, args.prompt_len // 2)))
            prompt = np.concatenate(
                [prefix, rng.randint(0, cfg.vocab_size, size=tail)])
        else:
            plen = int(rng.randint(2, args.prompt_len))
            prompt = rng.randint(0, cfg.vocab_size, size=plen)
        # alternate sampled/greedy rows so the smoke covers the mixed
        # segment program when sampling flags are given
        sched.submit(prompt, gen, sample=sample if i % 2 == 0 else None)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    print(f"drained {len(done)} requests / {useful} tokens in {dt:.2f}s "
          f"({useful/dt:.1f} tok/s on CPU, cold)")
    # the executable-cache counters are THE re-trace regression signal:
    # repeat traffic of a shape/plan already served must be all hits, so
    # a compile count that grows run-over-run in the CI smoke log means
    # something started re-tracing; the paged lines add pool occupancy
    # and the prefix hit rate (> 0 expected on this shared-prefix mix)
    print(sched.stats.summary())
    print("executables:", [k[:3] for k in sched.executable_cache_keys()])
    if args.paged:
        # the shared prefix spans >= one full block, so the index MUST
        # be consulted and MUST hit — a vacuously-passing guard here
        # would let a dead prefix cache through the CI smoke
        assert sched.stats.prefix_block_lookups > 0, (
            "paged smoke never consulted the prefix index"
        )
        if args.requests >= 3:  # enough traffic behind the first admits
            assert sched.stats.prefix_block_hits > 0, (
                "shared-prefix smoke produced zero prefix-cache hits"
            )
    if spec is not None:
        # the speculative smoke's contract: it actually speculated, the
        # pool drained clean, and an oracle draft was always accepted
        assert sched.stats.spec_steps > 0, "spec run never speculated"
        assert sched.mgr.alloc.in_use == 0, "spec run leaked pool blocks"
        if args.draft_arch is None and args.temperature is None:
            assert sched.stats.spec_acceptance_rate == 1.0, (
                "greedy oracle draft must be fully accepted, got "
                f"{sched.stats.spec_acceptance_rate:.2f}"
            )


def run_rag(args, cfg, api, params, plan):
    """The CI RAG smoke: shared-corpus multi-turn traffic through
    ``submit_query``. Retrieval runs as a host-side flexible op between
    segment dispatches (overlapped with in-flight decode by default),
    the pipeline assembles block-aligned prompts, and distinct queries
    that retrieve the same chunks splice each other's chunk-addressed
    KV blocks. Asserts the reuse is real: nonzero chunk-level cache
    hits, every query drained, pool clean."""
    from repro.retrieval import ChunkedCorpus, EmbeddingIndex, RagPipeline
    from repro.retrieval import make_toy_corpus

    sample = build_sampling(args)
    max_len = args.prompt_len + args.gen
    bs = args.block_size
    while max_len % bs:
        bs -= 1
    chunk_tokens = args.chunk_tokens or bs
    if chunk_tokens % bs:
        raise SystemExit(f"--chunk-tokens {chunk_tokens} must be a "
                         f"multiple of the pool block size {bs}")
    docs = make_toy_corpus(cfg.vocab_size, n_docs=args.corpus_size,
                           doc_len=max(2 * chunk_tokens, 32),
                           seed=args.seed)
    corpus = ChunkedCorpus(docs, chunk_tokens=chunk_tokens)
    index = EmbeddingIndex(corpus, vocab_size=cfg.vocab_size,
                           seed=args.seed)
    rag = RagPipeline(index, system_prefix=list(range(5, 5 + bs // 2)),
                      block_size=bs, top_k=args.rag_top_k)
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=args.slots, max_len=max_len,
        block_size=bs, prefill_chunk=args.prefill_chunk,
        segment=args.segment, plan=plan, kernel=args.kernel,
        mesh=build_mesh(args), rag=rag,
    )
    print(f"arch={cfg.arch_id} rag [paged, block_size={bs}, "
          f"kernel={args.kernel}]: corpus={args.corpus_size} docs x "
          f"{len(corpus.chunks)} chunks ({chunk_tokens} tok), "
          f"top_k={args.rag_top_k}, queries={args.requests}, "
          f"slots={args.slots}, sample={sample}")
    rng = np.random.RandomState(args.seed)
    # multi-turn traffic over a SHARED corpus: queries concentrate on a
    # few documents so distinct turns retrieve overlapping chunk sets —
    # the canonical-order pipeline turns that overlap into shared
    # leading block runs the pool can splice
    hot = max(1, args.corpus_size // 2)
    useful = 0
    for i in range(args.requests):
        d = docs[rng.randint(hot)]
        lo = int(rng.randint(0, d.size - 6))
        q = d[lo:lo + int(rng.randint(3, 7))]
        gen = int(rng.randint(1, args.gen))
        useful += gen
        sched.submit_query(q, gen, sample=sample if i % 2 == 0 else None)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    print(f"drained {len(done)} requests / {useful} tokens in {dt:.2f}s "
          f"({useful/dt:.1f} tok/s on CPU, cold)")
    print(sched.stats.summary())
    # the smoke's contract: every query retrieved and drained, the
    # shared corpus produced real chunk-level KV reuse (a zero here
    # means content addressing is dead), and the pool came back clean
    assert len(done) == args.requests, (
        f"drain lost requests: {len(done)} != {args.requests}")
    assert sched.stats.retrievals == args.requests
    assert sched.stats.retrieval_chunk_blocks > 0
    if args.requests >= 3:  # enough turns behind the first admits
        assert sched.stats.retrieval_chunk_hits > 0, (
            "shared-corpus RAG smoke produced zero chunk-cache hits"
        )
    assert sched.mgr.alloc.in_use == 0, "RAG run leaked pool blocks"


def run_overload(args, cfg, api, params, plan):
    """The CI overload smoke: 2x-oversubscribed priority traffic on a
    deliberately tiny paged pool (optionally with seeded fault
    injection). A low-priority backlog saturates every slot; high-
    priority requests land mid-drain and jump it via EDF admission +
    preemption (spill to the sidebar region, restore later). Asserts
    the robustness invariants end to end: every request completes and
    the pool drains with zero leaked blocks."""
    faults = build_faults(args)
    max_len = args.prompt_len + args.gen
    bs = args.block_size
    while max_len % bs:
        bs -= 1
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=args.slots, max_len=max_len,
        block_size=bs, prefill_chunk=args.prefill_chunk,
        num_blocks=args.num_blocks, segment=args.segment, plan=plan,
        kernel=args.kernel, faults=faults, scheduling="edf",
    )
    pool_str = (f"{args.num_blocks} blocks" if args.num_blocks
                else "default pool")
    print(f"arch={cfg.arch_id} overload [paged, {pool_str}, "
          f"block_size={bs}]: slots={args.slots}, "
          f"faults={args.faults or 'none'} (seed={args.fault_seed})")
    rng = np.random.RandomState(args.seed)
    n_low = 2 * args.slots                  # 2x oversubscription
    n_high = max(1, args.slots // 2)
    for _ in range(n_low):
        p = rng.randint(0, cfg.vocab_size,
                        size=max(2, args.prompt_len // 4))
        sched.submit(p, args.gen, priority=0)
    done = sched.step()                     # backlog mid-flight ...
    for _ in range(n_high):                 # ... then the highs land
        p = rng.randint(0, cfg.vocab_size,
                        size=max(2, args.prompt_len - 1))
        sched.submit(p, max(2, args.gen // 2), priority=1,
                     ttft_target=60.0)
    t0 = time.perf_counter()
    done += sched.run()
    dt = time.perf_counter() - t0
    print(f"drained {len(done)} requests in {dt:.2f}s (cold)")
    print(sched.stats.summary())
    if faults is not None:
        print(f"faults injected: {faults.total_injected} "
              f"({dict(faults.injected)})")
    # the smoke's contract: everything completes, nothing leaks
    assert len(done) == n_low + n_high, (
        f"drain lost requests: {len(done)} != {n_low + n_high}")
    assert sched.mgr.alloc.in_use == 0, "pool leaked blocks"
    assert (sched.mgr.alloc.num_free + sched.mgr.alloc.num_evictable
            == sched.mgr.alloc.capacity), "pool accounting drifted"
    assert len(sched.spill) == 0 and sched.spill.in_use_bytes == 0, (
        "spill region holds payloads after a full drain")
    if args.num_blocks:  # tiny pool: overload must actually preempt
        assert sched.stats.preemptions > 0, (
            "tiny-pool overload smoke never preempted"
        )
        assert sched.stats.restores > 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=cfglib.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument(
        "--execution-mode", default="sidebar",
        choices=[ExecutionMode.SIDEBAR.value,
                 ExecutionMode.SIDEBAR_PIPELINED.value],
        help="sidebar kernel variant backing the fused MLP ops",
    )
    ap.add_argument(
        "--pipeline-depth", type=int, default=2,
        help="VMEM ring depth T for sidebar_pipelined (>= 1)",
    )
    ap.add_argument(
        "--pipeline-depths", default=None,
        help="comma list of per-layer ring depths -> heterogeneous "
             "ExecutionPlan (layer i gets depths[i %% len]); unrolls the "
             "layer stack so each layer traces its own kernel variant",
    )
    ap.add_argument(
        "--decode", default="scan", choices=["scan", "loop"],
        help="scan: N tokens in one compiled program; loop: PR-2 "
             "one-dispatch-per-token baseline",
    )
    ap.add_argument("--continuous", action="store_true",
                    help="mixed-length traffic through the slot scheduler")
    ap.add_argument("--paged", action="store_true",
                    help="with --continuous: serve through the paged KV "
                         "pool (block tables, prefix caching, chunked "
                         "prefill-ahead)")
    ap.add_argument("--kernel", default="paged",
                    choices=["paged", "slab"],
                    help="with --paged: 'paged' decodes in place on the "
                         "block pool (table-walking attention, no "
                         "gather/scatter); 'slab' keeps the dense "
                         "round-trip reference segment")
    ap.add_argument("--use-pallas", action="store_true",
                    help="enable the Pallas kernels (interpret mode off "
                         "TPU) — CI's paged-attention kernel smoke")
    ap.add_argument("--block-size", type=int, default=8,
                    help="KV pool block size in token positions")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks (default: enough that "
                         "no request ever waits; set it small to force "
                         "preemption under load)")
    ap.add_argument("--overload", action="store_true",
                    help="overload smoke: 2x-oversubscribed priority "
                         "traffic on the paged server — low-priority "
                         "backlog, high-priority arrivals mid-drain, "
                         "EDF admission + preemption; asserts zero "
                         "leaks and full completion")
    ap.add_argument("--faults", default=None,
                    help="seeded fault injection, 'site=rate,...' "
                         "(sites: alloc, evict_storm, stage_stall), "
                         "e.g. --faults alloc=0.1,evict_storm=0.1")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--max-faults-per-site", type=int, default=8,
                    help="bound Bernoulli firings per site so a drain "
                         "terminates even at rate 1.0")
    ap.add_argument("--rag", action="store_true",
                    help="RAG smoke: shared-corpus multi-turn queries "
                         "through submit_query — host-side retrieval "
                         "between segment dispatches, chunk-addressed "
                         "KV splicing; asserts nonzero chunk-cache hits")
    ap.add_argument("--corpus-size", type=int, default=4,
                    help="with --rag: number of documents in the toy "
                         "corpus (queries concentrate on the first half)")
    ap.add_argument("--rag-top-k", type=int, default=2,
                    help="with --rag: retrieved chunks per query "
                         "(--top-k is the SAMPLING top-k; the retrieval "
                         "fan-in lives here)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="with --rag: corpus chunk length in tokens; "
                         "must be a multiple of the pool block size "
                         "(default: one block)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prefill-ahead chunk length (default block size)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft K tokens per row "
                         "per step and verify them in one batched "
                         "program (0 disables; requires --paged); "
                         "output tokens stay bit-identical to plain "
                         "decode regardless of the draft model")
    ap.add_argument("--draft-arch", default=None, choices=cfglib.ARCH_IDS,
                    help="draft model architecture for --spec-k "
                         "(default: the target itself — the 'oracle' "
                         "draft with greedy acceptance 1.0, measuring "
                         "pure speculation overhead)")
    ap.add_argument("--mesh", default=None,
                    help="serving mesh shape 'DATAxMODEL' (e.g. 1x2): "
                         "continuous serving runs tensor-parallel over "
                         "the mesh's 'model' axis via shard_map")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--segment", type=int, default=8)
    ap.add_argument(
        "--temperature", type=float, default=None,
        help="enable sampled decoding (temperature 0 = exact greedy); "
             "continuous mode samples every other request",
    )
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--top-p", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling seed (same seed => same tokens)")
    args = ap.parse_args()

    cfg = cfglib.get_smoke_config(args.arch)
    if args.use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)
    api = get_model(cfg)
    plan = build_plan(args, cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    if args.rag:
        run_rag(args, cfg, api, params, plan)
    elif args.overload:
        run_overload(args, cfg, api, params, plan)
    elif args.continuous:
        run_continuous(args, cfg, api, params, plan)
    else:
        run_static(args, cfg, api, params, plan)


if __name__ == "__main__":
    main()
