"""Quickstart: the Sidebar engine in 60 lines.

Builds one matmul->activation->matmul task, runs it under the paper's
three designs, prints the latency/energy/EDP table, and demonstrates the
flexibility claim: hot-swapping the activation updates the SIDEBAR design
but not the 'taped-out' MONOLITHIC artifact.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    StaticOp,
    build_monolithic,
    estimate,
    make_default_table,
    run,
)


def mm(w, x):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def main():
    b, d, f = 64, 512, 2048
    graph = LayerGraph(
        name="mlp",
        ops=(
            StaticOp("w1", mm, (b, f), flops=2 * b * d * f,
                     weight_bytes=d * f * 4),
            FlexibleOp("softplus", (b, f)),
            StaticOp("w2", mm, (b, d), flops=2 * b * f * d,
                     weight_bytes=f * d * 4),
        ),
        in_shape=(b, d),
    )
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w1": jax.random.normal(k1, (d, f), jnp.float32) * 0.02,
        "w2": jax.random.normal(k2, (f, d), jnp.float32) * 0.02,
    }
    x = jax.random.normal(k3, (b, d), jnp.float32)
    table = make_default_table()

    print(f"{'design':<14}{'latency (us)':>14}{'energy (uJ)':>14}{'EDP':>12}")
    outs = {}
    for mode in ExecutionMode:
        res = run(graph, params, x, mode, table)
        est = estimate(res.accounting)
        outs[mode] = np.asarray(res.output)
        print(f"{mode.value:<14}{est.latency_s*1e6:>14.2f}"
              f"{est.energy_j*1e6:>14.2f}{est.edp:>12.3e}")
    assert np.allclose(outs[ExecutionMode.SIDEBAR],
                       outs[ExecutionMode.MONOLITHIC], atol=1e-5)
    print("\nall three designs compute identical results ✓")

    # --- the flexibility claim -------------------------------------------
    mono = build_monolithic(graph, table)           # 'tape-out'
    before = np.asarray(mono(params, x))
    table.register("softplus", lambda v: jnp.maximum(v, 0.0), overwrite=True)
    after = np.asarray(mono(params, x))
    sidebar_new = np.asarray(
        run(graph, params, x, ExecutionMode.SIDEBAR, table).output
    )
    print("hot-swapped softplus -> relu in the function table:")
    print(f"  monolithic output changed: {not np.allclose(before, after)}"
          "  (frozen silicon)")
    print(f"  sidebar    output changed: "
          f"{not np.allclose(sidebar_new, before)}  (driver update only)")


if __name__ == "__main__":
    main()
