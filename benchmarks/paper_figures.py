"""Paper-artifact benchmarks: Figures 6-8, Table 3, and the §6.1
activation sweep, all on the paper's own workload (LeNet/CIFAR-10,
batch 256) through the sidebar engine.

Two number classes per row:
  * ``us_per_call`` — measured wall-clock of actually executing the
    engine on this host (CPU): real dispatch/fusion effects.
  * ``derived``     — the analytical model's value (latency s / energy J /
    EDP ratio) for the target chip, i.e. the paper-comparable number.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (
    DEFAULT_TABLE,
    ExecutionMode,
    account_model,
    estimate,
    normalized_edp,
    run,
)
from repro.core.engine import segment_static_chains
from repro.core.modes import StaticOp
from repro.models import lenet

BATCH = 256
MODES = list(ExecutionMode)


def _setup(activation: str = "relu"):
    lenet.register_pooling(DEFAULT_TABLE)
    params = lenet.engine_params(lenet.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, 3, 32, 32),
                          jnp.float32)
    graphs = lenet.to_layer_graphs(batch=BATCH, activation=activation)
    return params, x, graphs


def _measure_wall(graphs, params, x, mode, repeats: int = 3) -> float:
    """Median wall-time (us) of one inference pass under `mode`."""
    outs = []
    for _ in range(repeats + 1):
        t0 = time.perf_counter()
        out = x
        for g in graphs:
            out = run(g, params, out, mode, DEFAULT_TABLE).output
        jax.block_until_ready(out)
        outs.append((time.perf_counter() - t0) * 1e6)
    outs = sorted(outs[1:])  # drop warmup
    return outs[len(outs) // 2]


def fig6_latency() -> list[tuple[str, float, float]]:
    """Figure 6: inference latency per design (relu + softplus)."""
    rows = []
    for act in ("relu", "softplus"):
        params, x, graphs = _setup(act)
        for mode in MODES:
            wall = _measure_wall(graphs, params, x, mode)
            est = estimate(account_model(graphs, mode, DEFAULT_TABLE))
            rows.append((f"fig6/{act}/{mode.value}/latency_s", wall,
                         est.latency_s))
    return rows


def fig7_energy() -> list[tuple[str, float, float]]:
    """Figure 7: data-communication energy split (DRAM bus vs Sidebar)."""
    rows = []
    params, x, graphs = _setup("relu")
    for mode in MODES:
        est = estimate(account_model(graphs, mode, DEFAULT_TABLE))
        rows.append((f"fig7/relu/{mode.value}/dram_energy_j", 0.0, est.e_hbm_j))
        rows.append((f"fig7/relu/{mode.value}/sidebar_energy_j", 0.0,
                     est.e_sidebar_j))
        rows.append((f"fig7/relu/{mode.value}/total_energy_j", 0.0,
                     est.energy_j))
    return rows


def fig8_edp() -> list[tuple[str, float, float]]:
    """Figure 8: EDP normalized to the monolithic design."""
    rows = []
    for act in ("relu", "softplus"):
        _, _, graphs = _setup(act)
        ests = {m.value: estimate(account_model(graphs, m, DEFAULT_TABLE))
                for m in MODES}
        norm = normalized_edp(ests)
        for mode, v in norm.items():
            rows.append((f"fig8/{act}/{mode}/normalized_edp", 0.0, v))
    return rows


def table3_primitives() -> list[tuple[str, float, float]]:
    """Table 3 analogue: per-primitive (S1..S5) latency + 'area' proxy.

    The paper's area blow-up came from per-accelerator private memory;
    our proxy is each chain's weight+IO bytes. Latency is the chain's
    standalone estimate; energy its model energy.
    """
    rows = []
    _, _, graphs = _setup("relu")
    graph = graphs[0]
    chains = segment_static_chains(graph)
    shapes = graph.shapes()
    idx = 0
    for i, chain in enumerate(chains):
        static = [op for op in chain if isinstance(op, StaticOp)]
        if not static:
            continue
        name = "+".join(op.name for op in static)
        flops = sum(op.flops for op in static)
        wbytes = sum(op.weight_bytes for op in static)
        from repro.core.constants import V5E

        t = max(flops / V5E.peak_flops, wbytes / V5E.hbm_bytes_per_s)
        e = flops * V5E.e_mxu_per_flop + wbytes * V5E.e_hbm_per_byte
        rows.append((f"table3/S{i+1}_{name}/latency_s", 0.0, t))
        rows.append((f"table3/S{i+1}_{name}/energy_j", 0.0, e))
        rows.append((f"table3/S{i+1}_{name}/area_proxy_bytes", 0.0, wbytes))
    # monolithic totals (Relu + SoftPlus variants, as in Table 3)
    for act in ("relu", "softplus"):
        _, _, gs = _setup(act)
        est = estimate(account_model(gs, ExecutionMode.MONOLITHIC,
                                     DEFAULT_TABLE))
        rows.append((f"table3/monolithic_{act}/latency_s", 0.0, est.latency_s))
        rows.append((f"table3/monolithic_{act}/energy_j", 0.0, est.energy_j))
    return rows


def activation_sweep() -> list[tuple[str, float, float]]:
    """§6.1 generalized: overhead-vs-monolithic for every Table-1
    activation, showing the flexible-DMA gap growing with activation cost
    while the sidebar gap stays flat."""
    rows = []
    for act in ("heaviside", "relu", "leaky_relu", "elu", "sigmoid",
                "tanh", "gelu", "softplus"):
        _, _, graphs = _setup(act)
        ests = {m: estimate(account_model(graphs, m, DEFAULT_TABLE))
                for m in MODES}
        mono = ests[ExecutionMode.MONOLITHIC].latency_s
        rows.append((
            f"sweep/{act}/dma_overhead_pct", 0.0,
            100.0 * (ests[ExecutionMode.FLEXIBLE_DMA].latency_s / mono - 1),
        ))
        rows.append((
            f"sweep/{act}/sidebar_overhead_pct", 0.0,
            100.0 * (ests[ExecutionMode.SIDEBAR].latency_s / mono - 1),
        ))
    return rows


def validate_paper_claims() -> list[tuple[str, float, float]]:
    """EXPERIMENTS.md §Paper-validation: claim -> 1.0 (holds) / 0.0."""
    lenet.register_pooling(DEFAULT_TABLE)
    g_relu = lenet.to_layer_graphs(BATCH, "relu")
    g_soft = lenet.to_layer_graphs(BATCH, "softplus")
    checks = {}
    for tag, graphs in (("relu", g_relu), ("softplus", g_soft)):
        ests = {m.value: estimate(account_model(graphs, m, DEFAULT_TABLE))
                for m in MODES}
        lat = {k: v.latency_s for k, v in ests.items()}
        edp = normalized_edp(ests)
        checks[f"claims/{tag}/ordering_latency"] = float(
            lat["monolithic"] <= lat["sidebar"] < lat["flexible_dma"])
        checks[f"claims/{tag}/dma_latency_gap_8pct_plus"] = float(
            lat["flexible_dma"] / lat["monolithic"] >= 1.08)
        checks[f"claims/{tag}/sidebar_latency_within_10pct"] = float(
            lat["sidebar"] / lat["monolithic"] <= 1.10)
        checks[f"claims/{tag}/edp_dma_worst"] = float(
            edp["flexible_dma"] > edp["sidebar"] > 0.999)
    return [(k, 0.0, v) for k, v in checks.items()]
