"""Measured serving-path benchmark: scan-compiled decode + batching.

Three comparisons, all wall-clock on this host (CPU numbers are not TPU
numbers, but the *mechanisms* measured — dispatch count, retrace count,
slot utilization — are backend-independent):

  loop_vs_scan   — the PR-2 one-jitted-dispatch-per-token Python decode
                   loop vs the same decode compiled into ONE program
                   (``jax.lax.scan`` with the cache donated through the
                   carry). Reports tokens/s; for the loop, real
                   per-dispatch p50/p95 (each decode dispatch timed);
                   for the scan, the amortized per-token cost
                   (wall/steps) — the loop pays a host->device dispatch
                   per token, the scan pays one per generation.
  flat_vs_plan   — a uniform plan served by the scanned layer stack vs a
                   heterogeneous per-layer ``ExecutionPlan`` (unrolled
                   stack, one kernel-variant trace per layer). Measures
                   the serving-layer cost of per-layer dispatch; the
                   kernel-level payoff of the per-layer depth choice is
                   the depth_sweep section of fusion_bench.
  continuous     — mixed-length traffic through the slot scheduler (ONE
                   batched segment program over all occupied slots at
                   per-row positions; batched admission fused into a
                   single gather-prefill-correct-scatter dispatch) vs
                   static batching that pads every request to the batch
                   max. Useful-token throughput over a traffic-mix sweep
                   (homogeneous -> uniform -> heavy-tailed generation
                   lengths) on a compute-dominated smoke config; the
                   measured crossover records the first mix where
                   continuous wins (static batching's padding waste
                   outgrows the scheduler's boundary overhead).

Rows are ``(tag, us_per_token, derived)`` where derived is tokens/s
(or a ratio for the summary rows), so ``benchmarks/run.py serving
--json BENCH_serving.json`` emits the machine-readable trajectory file.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.core.modes import ExecutionMode, ExecutionPlan, LayerPlan
from repro.launch.scheduler import (
    ContinuousBatchingServer,
    PagedContinuousBatchingServer,
)
from repro.launch.serve import Server
from repro.models.registry import get_model

ARCH = "nemotron-4-15b"
BATCH, PROMPT, GEN = 4, 16, 32
TRIALS = 5


def _setup(arch: str = ARCH, **server_kw):
    cfg = cfglib.get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=PROMPT + GEN + 8, **server_kw)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (BATCH, PROMPT), 0, cfg.vocab_size,
        dtype=jnp.int32,
    )
    return cfg, params, server, prompts


def _pct(samples, q):
    return float(np.percentile(np.asarray(samples), q))


def _loop_token_latencies(server, prompts, gen):
    """Per-DISPATCH decode latencies for the loop path: drive the same
    jitted single-step the loop uses and time each dispatch (a whole-
    generate wall divided by N would hide the per-token tail)."""
    from repro.kernels import ops as kops

    b, s = prompts.shape
    samples = []
    with kops.execution_plan(server.plan):
        cache = server._take_cache(b)
        nxt, cache = server._prefill(server.params,
                                     {"tokens": prompts}, cache)
        pos = s
        for _ in range(gen - 1):
            t0 = time.perf_counter()
            nxt, cache = server._decode(server.params, nxt, cache,
                                        jnp.int32(pos), None)
            jax.block_until_ready(nxt)
            samples.append((time.perf_counter() - t0) * 1e6)
            pos += 1
    server._return_cache(b, cache)
    return samples


def loop_vs_scan_rows():
    _, _, server, prompts = _setup()
    out = []
    tag = f"serving/{ARCH}/b{BATCH}_g{GEN}"

    # loop: throughput over whole generates + real per-dispatch p50/p95
    server.generate(prompts, GEN, decode="loop")  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(TRIALS):
        jax.block_until_ready(
            server.generate(prompts, GEN, decode="loop").tokens)
    loop_wall = time.perf_counter() - t0
    loop_tok_s = TRIALS * GEN * BATCH / loop_wall
    loop_us = _loop_token_latencies(server, prompts, GEN)

    # scan: one dispatch per generation — per-token latency only exists
    # amortized (that is the point), reported as wall/steps per trial
    server.generate(prompts, GEN, decode="scan")  # warmup/compile
    scan_amort_us = []
    t0 = time.perf_counter()
    for _ in range(TRIALS):
        t1 = time.perf_counter()
        jax.block_until_ready(
            server.generate(prompts, GEN, decode="scan").tokens)
        scan_amort_us.append((time.perf_counter() - t1) * 1e6 / GEN)
    scan_wall = time.perf_counter() - t0
    scan_tok_s = TRIALS * GEN * BATCH / scan_wall

    out.append((f"{tag}/loop/tok_s", float(np.median(loop_us)), loop_tok_s))
    out.append((f"{tag}/loop/p50_us", _pct(loop_us, 50), _pct(loop_us, 50)))
    out.append((f"{tag}/loop/p95_us", _pct(loop_us, 95), _pct(loop_us, 95)))
    out.append((f"{tag}/scan/tok_s", float(np.median(scan_amort_us)),
                scan_tok_s))
    out.append((f"{tag}/scan/amortized_tok_us_p50", _pct(scan_amort_us, 50),
                _pct(scan_amort_us, 50)))
    out.append((f"{tag}/scan/amortized_tok_us_p95", _pct(scan_amort_us, 95),
                _pct(scan_amort_us, 95)))
    out.append((f"{tag}/scan_over_loop_speedup", 0.0,
                scan_tok_s / loop_tok_s))
    return out


def flat_vs_plan_rows():
    depth2 = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=2)
    depth4 = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=4)
    cfg = cfglib.get_smoke_config(ARCH)
    per_layer = ExecutionPlan(
        default=depth2,
        layers={i: (depth4 if i % 2 else depth2)
                for i in range(cfg.num_layers)},
    )
    out = []
    for name, plan in (("flat", depth2), ("per_layer", per_layer)):
        _, _, server, prompts = _setup(plan=plan)
        server.generate(prompts, GEN)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(TRIALS):
            jax.block_until_ready(server.generate(prompts, GEN).tokens)
        wall = time.perf_counter() - t0
        tok_s = TRIALS * GEN * BATCH / wall
        out.append((f"serving/{ARCH}/plan_{name}/tok_s",
                    wall * 1e6 / (TRIALS * GEN), tok_s))
    out.append((f"serving/{ARCH}/per_layer_over_flat", 0.0,
                out[1][2] / out[0][2]))
    return out


# continuous-vs-static runs on a compute-dominated smoke config (d=256,
# 4 layers — still seconds on CPU): at the tiny test size a single XLA
# dispatch costs as much as several decode steps, so the comparison
# measures Python/dispatch overhead instead of scheduler mechanics. The
# traffic sweep moves from homogeneous generation lengths (static
# batching's best case: zero padding waste) to a heavy-tailed chat-like
# mix (many short answers, a few long ones — every static batch pads to
# its longest member); the measured crossover is the first mix where
# batched segment decode wins.
CONT_SLOTS, CONT_REQS, CONT_TRIALS = 4, 24, 3
TRAFFIC_MIXES = ("uniform_28_32", "uniform_8_32", "heavy_tail")


def _continuous_cfg():
    import dataclasses

    return dataclasses.replace(
        cfglib.get_smoke_config(ARCH), d_model=256, num_heads=8,
        num_kv_heads=2, d_ff=1024, num_layers=4,
    )


def _traffic(cfg, mix: str):
    rng = np.random.RandomState(7)
    if mix == "uniform_28_32":
        gens = [int(rng.randint(28, GEN)) for _ in range(CONT_REQS)]
    elif mix == "uniform_8_32":
        gens = [int(rng.randint(8, GEN)) for _ in range(CONT_REQS)]
    else:  # heavy_tail: 3/4 short chat answers, 1/4 long generations
        n_long = CONT_REQS // 4
        gens = [int(rng.randint(2, 7)) for _ in range(CONT_REQS - n_long)]
        gens += [int(rng.randint(28, GEN)) for _ in range(n_long)]
        rng.shuffle(gens)
    return [
        (rng.randint(0, cfg.vocab_size, size=rng.randint(4, 15)).astype(
            np.int32), g)
        for g in gens
    ]


def _measure_mix(cfg, params, server, reqs):
    """Interleaved paired trials (continuous then static per trial) so
    host noise cancels in the ratio; returns medians."""
    useful = sum(g for _, g in reqs)
    max_len = PROMPT + GEN + 8
    sched = ContinuousBatchingServer(
        cfg, params, num_slots=CONT_SLOTS, max_len=max_len, buckets=(16,),
        segment=8,
    )
    for p, g in reqs:
        sched.submit(p, g)
    sched.run()  # warmup: compiles every (bucket, steps, plan) executable
    batches = [reqs[i:i + CONT_SLOTS]
               for i in range(0, CONT_REQS, CONT_SLOTS)]

    def run_static():
        # static baseline: fixed batches of `slots`, padded to the batch
        # max prompt/gen (Server pads nothing itself: bucket by hand).
        for batch in batches:
            s_max = max(p.size for p, _ in batch)
            g_max = max(g for _, g in batch)
            toks = np.zeros((len(batch), s_max), np.int32)
            for j, (p, _) in enumerate(batch):
                toks[j, :p.size] = p  # right-pad (throughput-only proxy)
            jax.block_until_ready(
                server.generate(jnp.asarray(toks), g_max).tokens)

    run_static()  # warmup
    ratios, cont, static = [], [], []
    for _ in range(CONT_TRIALS):
        for p, g in reqs:
            sched.submit(p, g)
        t0 = time.perf_counter()
        sched.run()
        cw = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_static()
        sw = time.perf_counter() - t0
        ratios.append(sw / cw)
        cont.append(useful / cw)
        static.append(useful / sw)
    # report the median-RATIO trial's own numbers so the three rows stay
    # self-consistent (independent medians can disagree with the paired
    # ratio under host noise)
    mid = int(np.argsort(ratios)[len(ratios) // 2])
    return cont[mid], static[mid], ratios[mid], sched


def continuous_rows():
    cfg = _continuous_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=PROMPT + GEN + 8)

    out = []
    ratios = {}
    sched = None
    for mix in TRAFFIC_MIXES:
        reqs = _traffic(cfg, mix)
        cont, static, ratio, sched = _measure_mix(cfg, params, server, reqs)
        ratios[mix] = ratio
        if mix == "heavy_tail":  # the flagship comparison
            out.append((f"serving/{ARCH}/continuous/tok_s", 1e6 / cont,
                        cont))
            out.append((f"serving/{ARCH}/static_batch/tok_s", 1e6 / static,
                        static))
            out.append((f"serving/{ARCH}/continuous_over_static", 0.0,
                        ratio))
        out.append((f"serving/{ARCH}/continuous_over_static/{mix}", 0.0,
                    ratio))
    # measured crossover: 1-based index (in increasing traffic
    # heterogeneity) of the first mix where continuous wins; 0 = never
    crossover = next((i + 1 for i, m in enumerate(TRAFFIC_MIXES)
                      if ratios[m] >= 1.0), 0)
    out.append((f"serving/{ARCH}/continuous_crossover_mix", 0.0,
                float(crossover)))
    # idle-row fraction: free/dead slot rows the batched segment
    # programs decode alongside active ones (shrink-to-fit already makes
    # active-slot overshoot zero), per active decode step
    out.append((f"serving/{ARCH}/continuous/wasted_step_frac", 0.0,
                sched.stats["wasted_steps"] /
                max(sched.stats["decode_steps"], 1)))
    return out


# paged-vs-synchronous-admission runs the heavy-tail mix with a shared
# system prefix (the realistic chat shape: every request front-loads the
# same instructions). The slab scheduler prefills every prompt at its
# admission boundary (synchronous admission, PR-4); the paged scheduler
# splices the shared prefix out of the block index and stages the rest
# chunk-by-chunk between segments (prefill-ahead), so the prefill compute
# the slab path repeats per request mostly disappears. Interleaved paired
# trials as in _measure_mix.
PAGED_BLOCK, PAGED_PREFIX, PAGED_TRIALS = 8, 24, 5


def _prefix_traffic(cfg):
    rng = np.random.RandomState(7)
    system = rng.randint(0, cfg.vocab_size, size=PAGED_PREFIX).astype(
        np.int32)
    n_long = CONT_REQS // 4
    gens = [int(rng.randint(2, 7)) for _ in range(CONT_REQS - n_long)]
    gens += [int(rng.randint(28, GEN)) for _ in range(n_long)]
    rng.shuffle(gens)
    return [
        (np.concatenate([system, rng.randint(
            0, cfg.vocab_size, size=rng.randint(2, 7)).astype(np.int32)]),
         g)
        for g in gens
    ]


def paged_rows():
    cfg = _continuous_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    reqs = _prefix_traffic(cfg)
    useful = sum(g for _, g in reqs)
    max_len = PAGED_PREFIX + 8 + GEN  # prompt <= prefix+7, gen <= GEN
    paged = PagedContinuousBatchingServer(
        cfg, params, num_slots=CONT_SLOTS, max_len=max_len,
        block_size=PAGED_BLOCK, prefill_chunk=PAGED_BLOCK, segment=8,
    )
    slab = ContinuousBatchingServer(
        cfg, params, num_slots=CONT_SLOTS, max_len=max_len,
        buckets=(16, 32), segment=8,
    )

    def run(server):
        for p, g in reqs:
            server.submit(p, g)
        t0 = time.perf_counter()
        server.run()
        return time.perf_counter() - t0

    for _ in range(2):     # warmup: compile + populate the prefix index
        run(paged), run(slab)
    hits0 = paged.stats.prefix_block_hits        # measured trials only
    lookups0 = paged.stats.prefix_block_lookups
    ratios, pg, sy = [], [], []
    for _ in range(PAGED_TRIALS):
        pw = run(paged)
        sw = run(slab)
        ratios.append(sw / pw)
        pg.append(useful / pw)
        sy.append(useful / sw)
    mid = int(np.argsort(ratios)[len(ratios) // 2])
    hit_rate = (paged.stats.prefix_block_hits - hits0) / max(
        paged.stats.prefix_block_lookups - lookups0, 1)
    return [
        (f"serving/{ARCH}/paged/tok_s", 1e6 / pg[mid], pg[mid]),
        (f"serving/{ARCH}/sync_admission/tok_s", 1e6 / sy[mid], sy[mid]),
        (f"serving/{ARCH}/paged_over_sync_admission", 0.0, ratios[mid]),
        (f"serving/{ARCH}/paged/prefix_hit_rate", 0.0, hit_rate),
        (f"serving/{ARCH}/paged/pool_occupancy_peak", 0.0,
         paged.stats.pool_in_use_peak / max(paged.stats.pool_blocks, 1)),
        (f"serving/{ARCH}/paged/stage_chunks", 0.0,
         float(paged.stats.stage_chunks)),
    ]


def paged_kernel_rows():
    """In-place paged decode (PR-6) vs the slab round-trip it replaced:
    the SAME paged scheduler and shared-prefix heavy-tail traffic, only
    the segment program differs — ``kernel="paged"`` walks the block
    tables in place (attention width sliced to the active frontier),
    ``kernel="slab"`` brackets every segment with pool-wide
    gather_blocks/scatter_blocks and attends full max_len. A generous
    max_len makes the structural difference visible on CPU: the slab
    segment pays for ALL of it every segment, the paged kernel only for
    blocks that can hold live KV. The config trims d_ff so the
    attention/copy work the two kernels disagree on isn't drowned by
    MLP compute identical on both sides. Interleaved paired trials as
    above."""
    import dataclasses

    cfg = dataclasses.replace(_continuous_cfg(), d_ff=256)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    # boundary-heavy traffic: tiny prompts, moderate generations, a
    # deep backlog — pending admissions keep segments short, which is
    # where the kernels structurally differ (the slab pays its pool
    # round-trip at every boundary; the paged kernel pays nothing)
    rng = np.random.RandomState(7)
    reqs = [
        (rng.randint(0, cfg.vocab_size,
                     size=rng.randint(4, 9)).astype(np.int32),
         int(rng.randint(8, 17)))
        for _ in range(32)
    ]
    useful = sum(g for _, g in reqs)
    max_len = 256  # >> live prefixes (<= 63): the slab's fixed cost

    def make(kernel):
        # a right-sized pool (live KV is <= 3 blocks/slot), not the
        # defensive slots*max_len default: pool memory proportional to
        # LIVE data is the paged design's premise, and per-step pool
        # writes cost what the pool occupies
        return PagedContinuousBatchingServer(
            cfg, params, num_slots=CONT_SLOTS, max_len=max_len,
            num_blocks=64, block_size=PAGED_BLOCK,
            prefill_chunk=PAGED_BLOCK, segment=2, kernel=kernel,
        )

    inplace, roundtrip = make("paged"), make("slab")

    def run(server):
        for p, g in reqs:
            server.submit(p, g)
        t0 = time.perf_counter()
        server.run()
        return time.perf_counter() - t0

    for _ in range(2):     # warmup: compile both segment families
        run(inplace), run(roundtrip)
    ratios, pk, sk = [], [], []
    for _ in range(2 * PAGED_TRIALS - 1):  # thin margin: tighter median
        pw = run(inplace)
        sw = run(roundtrip)
        ratios.append(sw / pw)
        pk.append(useful / pw)
        sk.append(useful / sw)
    mid = int(np.argsort(ratios)[len(ratios) // 2])
    return [
        (f"serving/{ARCH}/paged_kernel/tok_s", 1e6 / pk[mid], pk[mid]),
        (f"serving/{ARCH}/paged_slab/tok_s", 1e6 / sk[mid], sk[mid]),
        (f"serving/{ARCH}/paged_kernel_over_slab", 0.0, ratios[mid]),
    ]


# tensor-parallel serving: the SAME paged scheduler, solo vs a (1,2)
# mesh (shard_map step programs, weights/KV split on "model", logits
# all-gathered per step). Runs in a SUBPROCESS so the bench can force
# two virtual CPU devices without disturbing this process's jax. On one
# physical CPU the two "devices" share cores, so the tp row measures
# the sharding + collective OVERHEAD (tp2_over_solo < 1 is expected
# here); the gated invariant is tp_tokens_match — TP must be a pure
# parallelization, token-identical to solo serving.
MESH_TRIALS = 3

_MESH_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json, time
import jax, jax.numpy as jnp, numpy as np
from repro import configs as cfglib
from repro.launch.mesh import make_serving_mesh
from repro.launch.scheduler import PagedContinuousBatchingServer
from repro.models.registry import get_model

ARCH, PREFIX, GEN, TRIALS = %r, %d, %d, %d
cfg = dataclasses.replace(
    cfglib.get_smoke_config(ARCH), d_model=256, num_heads=8,
    num_kv_heads=2, d_ff=1024, num_layers=4,
)
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0), cfg)
rng = np.random.RandomState(7)
system = rng.randint(0, cfg.vocab_size, size=PREFIX).astype(np.int32)
reqs = [
    (np.concatenate([system, rng.randint(
        0, cfg.vocab_size, size=rng.randint(2, 7)).astype(np.int32)]),
     int(rng.randint(4, 17)))
    for _ in range(16)
]
useful = sum(g for _, g in reqs)
max_len = PREFIX + 8 + GEN

def make(mesh):
    return PagedContinuousBatchingServer(
        cfg, params, num_slots=4, max_len=max_len, block_size=8,
        prefill_chunk=8, segment=8, mesh=mesh)

solo, tp = make(None), make(make_serving_mesh((1, 2)))

def run(server):
    for p, g in reqs:
        server.submit(p, g)
    t0 = time.perf_counter()
    done = server.run()
    return time.perf_counter() - t0, done

_, d_solo = run(solo)          # warmup: compile + seed prefix index
_, d_tp = run(tp)
match = all(
    np.array_equal(a.tokens, b.tokens)
    for a, b in zip(sorted(d_solo, key=lambda r: r.rid),
                    sorted(d_tp, key=lambda r: r.rid))
) and len(d_solo) == len(d_tp) == len(reqs)
ratios, so, tr = [], [], []
for _ in range(TRIALS):
    sw, ds = run(solo)
    tw, dt = run(tp)
    match = match and all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(sorted(ds, key=lambda r: r.rid),
                        sorted(dt, key=lambda r: r.rid)))
    ratios.append(sw / tw)
    so.append(useful / sw)
    tr.append(useful / tw)
mid = int(np.argsort(ratios)[len(ratios) // 2])
print(json.dumps({"solo_tok_s": so[mid], "tp_tok_s": tr[mid],
                  "ratio": ratios[mid], "match": int(match)}))
"""


def mesh_rows():
    import json
    import os
    import subprocess
    import sys

    child = _MESH_CHILD % (ARCH, PAGED_PREFIX, GEN, MESH_TRIALS)
    env = {**os.environ, "JAX_PLATFORMS": "cpu"}
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    res = subprocess.run(
        [sys.executable, "-c", child], capture_output=True, text=True,
        timeout=900, env=env,
    )
    if res.returncode != 0:
        raise RuntimeError(
            f"mesh bench child failed:\n{res.stdout}\n{res.stderr}")
    data = json.loads(res.stdout.strip().splitlines()[-1])
    return [
        (f"serving/{ARCH}/tp2/tok_s", 1e6 / data["tp_tok_s"],
         data["tp_tok_s"]),
        (f"serving/{ARCH}/tp_solo/tok_s", 1e6 / data["solo_tok_s"],
         data["solo_tok_s"]),
        (f"serving/{ARCH}/tp2_over_solo", 0.0, data["ratio"]),
        (f"serving/{ARCH}/tp_tokens_match", 0.0, float(data["match"])),
    ]


# replica-router fleet: 4 paged replicas, shared-prefix wave traffic,
# prefix-affinity steering vs random spray. Greedy decode + seeded
# traffic + seeded router make BOTH hit rates deterministic, so the
# affinity-over-random ratio is a hard-gateable invariant, not a timing.
FLEET_REPLICAS, FLEET_WAVES, FLEET_PER_WAVE, FLEET_FAMILIES = 4, 3, 8, 4


def _fleet_waves(cfg):
    rng = np.random.RandomState(7)
    fams = [rng.randint(0, cfg.vocab_size, size=PAGED_PREFIX).astype(
        np.int32) for _ in range(FLEET_FAMILIES)]
    waves = []
    for _ in range(FLEET_WAVES):
        wave = []
        for i in range(FLEET_PER_WAVE):
            tail = rng.randint(0, cfg.vocab_size,
                               size=rng.randint(2, 7)).astype(np.int32)
            wave.append((np.concatenate([fams[i % FLEET_FAMILIES], tail]),
                         int(rng.randint(2, 7))))
        waves.append(wave)
    return waves


def router_rows():
    from repro.launch.router import ReplicaRouter

    cfg = _continuous_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    waves = _fleet_waves(cfg)
    max_len = PAGED_PREFIX + 8 + GEN
    rates = {}
    for policy in ("prefix", "random"):
        replicas = [
            PagedContinuousBatchingServer(
                cfg, params, num_slots=2, max_len=max_len,
                block_size=PAGED_BLOCK, prefill_chunk=PAGED_BLOCK,
                segment=8)
            for _ in range(FLEET_REPLICAS)
        ]
        fleet = ReplicaRouter(replicas, policy=policy, seed=3)
        for wave in waves:
            for p, g in wave:
                fleet.submit(p, g)
            fleet.run()   # drain between waves so the index seeds
        rates[policy] = fleet.stats.prefix_hit_rate
    return [
        (f"serving/{ARCH}/fleet_prefix_hit_rate", 0.0, rates["prefix"]),
        (f"serving/{ARCH}/fleet_random_hit_rate", 0.0, rates["random"]),
        (f"serving/{ARCH}/router_affinity_over_random", 0.0,
         rates["prefix"] / max(rates["random"], 1e-9)),
    ]


# speculative decode: the SAME paged scheduler and heavy-tail traffic,
# spec (oracle draft, k tokens verified per step) vs plain segment
# decode. An oracle draft (draft == target) accepts everything, so the
# spec arm takes exactly ceil(gen/(k+1)) verify dispatches where plain
# takes gen segment steps — the measured ratio is the dispatch-count
# mechanism, but on this host the small draft is NOT small (it IS the
# target), so spec_over_plain is recorded, never gated. The gated
# invariant is spec_tokens_match: speculation must be invisible in the
# emitted stream, bit for bit.
SPEC_K = 3


def spec_rows():
    from repro.launch.spec import SpecConfig

    cfg = _continuous_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    reqs = _traffic(cfg, "heavy_tail")
    useful = sum(g for _, g in reqs)
    max_len = PROMPT + GEN + 8

    def make(spec):
        return PagedContinuousBatchingServer(
            cfg, params, num_slots=CONT_SLOTS, max_len=max_len,
            block_size=PAGED_BLOCK, prefill_chunk=PAGED_BLOCK, segment=8,
            spec=spec)

    spec = make(SpecConfig(draft_cfg=cfg, draft_params=params, k=SPEC_K))
    plain = make(None)

    def run(server):
        for p, g in reqs:
            server.submit(p, g)
        t0 = time.perf_counter()
        done = server.run()
        return time.perf_counter() - t0, done

    _, d_spec = run(spec)       # warmup: compile draft/verify/stage
    _, d_plain = run(plain)

    def tokens(done):
        return {r.rid: np.asarray(r.tokens) for r in done}

    match = (len(d_spec) == len(d_plain) == len(reqs)
             and all(np.array_equal(t, tokens(d_plain)[rid])
                     for rid, t in tokens(d_spec).items()))
    ratios, sp, pl = [], [], []
    for _ in range(PAGED_TRIALS):
        sw, ds = run(spec)
        pw, dp = run(plain)
        match = match and all(np.array_equal(t, tokens(dp)[rid])
                              for rid, t in tokens(ds).items())
        ratios.append(pw / sw)
        sp.append(useful / sw)
        pl.append(useful / pw)
    mid = int(np.argsort(ratios)[len(ratios) // 2])
    return [
        (f"serving/{ARCH}/spec/tok_s", 1e6 / sp[mid], sp[mid]),
        (f"serving/{ARCH}/spec_plain/tok_s", 1e6 / pl[mid], pl[mid]),
        (f"serving/{ARCH}/spec_over_plain", 0.0, ratios[mid]),
        (f"serving/{ARCH}/spec_tokens_match", 0.0, float(match)),
        (f"serving/{ARCH}/spec/acceptance_rate", 0.0,
         spec.stats.spec_acceptance_rate),
    ]


# overload: the fleet at 2x oversubscription. A low-priority backlog
# saturates every slot on a pool sized so two fully grown spans fill it
# (lazy allocation's pressure case), then high-priority requests land
# MID-DRAIN with a TTFT target. Under EDF the arrival stages by
# reclaiming from strictly worse holders — spilling an active low to
# the sidebar region — and admits within a boundary or two; under FIFO
# it waits out the whole backlog. Goodput counts only SLO-compliant
# tokens (best-effort lows carry no target, so they always comply);
# the EDF/FIFO ratio measures the scheduling mechanism, not host
# timing — both arms run identical seeded traffic on identical fleets.
# preempt_bitexact is the safety side of the same coin: a forced
# preempt/restore drain must be token-identical to an unpressured one.
OVR_REPLICAS, OVR_SLOTS, OVR_BLOCKS = 4, 2, 13  # 12 allocatable blocks
OVR_LOW, OVR_HIGH = 16, 8           # 16 lows on 8 slots = 2x oversub
OVR_GEN_LOW, OVR_GEN_HIGH = 48, 24  # highs are the long SLO-bearing work
OVR_HEAD_STEPS = 9                  # lows grown & pool full, THEN highs
OVR_MAXLEN = 64


def _overload_traffic(cfg):
    rng = np.random.RandomState(11)
    low = [(rng.randint(0, cfg.vocab_size,
                        size=int(rng.randint(5, 9))).astype(np.int32),
            OVR_GEN_LOW) for _ in range(OVR_LOW)]
    # long high prompts: first-token latency is prefill-dominated on
    # BOTH the loaded and unloaded fleet, so the TTFT comparison is
    # about queueing (what the scheduler controls), not prompt length
    high = [(rng.randint(0, cfg.vocab_size,
                         size=int(rng.randint(20, 25))).astype(np.int32),
             OVR_GEN_HIGH) for _ in range(OVR_HIGH)]
    return low, high


def _overload_fleet(cfg, params, scheduling):
    from repro.launch.router import ReplicaRouter

    replicas = [
        PagedContinuousBatchingServer(
            cfg, params, num_slots=OVR_SLOTS, max_len=OVR_MAXLEN,
            block_size=PAGED_BLOCK, prefill_chunk=PAGED_BLOCK,
            num_blocks=OVR_BLOCKS, segment=4, scheduling=scheduling)
        for _ in range(OVR_REPLICAS)
    ]
    return ReplicaRouter(replicas, policy="prefix", seed=3)


def _overload_drain(fleet, low, high, target):
    """Submit the low backlog, step until every slot is occupied and
    grown, then submit the highs mid-drain (the overload moment) and
    drain. Returns (wall, finished, high-priority fleet ids)."""
    t0 = time.perf_counter()
    for p, g in low:
        fleet.submit(p, g, priority=0)
    done = []
    for _ in range(OVR_HEAD_STEPS):
        done += fleet.step()
    hf = {fleet.submit(p, g, priority=1, ttft_target=target)
          for p, g in high}
    done += fleet.run()
    return time.perf_counter() - t0, done, hf


def _flush_fleet(fleet):
    """Force-evict every cached block (prefix index included) on every
    replica: each measured drain starts from the same cold pool the
    warmup saw, so warmup and measurement execute the same schedule and
    the measured run compiles nothing."""
    for r in fleet.replicas:
        r.mgr.alloc.evict_cached()


def _goodput(wall, done, high_fids, target):
    ok = sum(r.generated for r in done
             if r.rid not in high_fids or r.ttft <= target)
    return ok / wall


def _high_only_ttfts(fleet, high):
    """Unloaded-fleet TTFT for the high prompts. First tokens only
    materialize at segment boundaries, and an idle fleet uncaps its
    first segment to the whole remaining span — calibrating with the
    traffic's full gen would measure segment shape, not first-token
    latency. Same prompts, one-segment gen: the unloaded first
    boundary gets the same granularity the loaded fleet's capped
    segments have."""
    fids = {fleet.submit(p, 4, priority=1) for p, _ in high}
    return [r.ttft for r in fleet.run() if r.rid in fids]


def _preempt_bitexact(cfg, params):
    """Forced preempt/restore vs an unpressured pool, token-for-token,
    greedy and sampled rows in the same drain."""
    from repro.launch.sampling import SamplingParams

    rng = np.random.RandomState(3)
    reqs = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 18)
            for _ in range(2)]
    samples = [None, SamplingParams(temperature=0.8, top_k=40, seed=13)]

    def drain(**kw):
        sched = PagedContinuousBatchingServer(
            cfg, params, num_slots=2, max_len=48, block_size=8,
            segment=4, **kw)
        for (p, g), sp in zip(reqs, samples):
            sched.submit(p, g, sp)
        return sched.run(), sched.stats

    ample, a_st = drain()               # default pool: no pressure
    tight, t_st = drain(num_blocks=6)   # two grown spans cannot coexist
    ok = (a_st.preemptions == 0 and t_st.preemptions > 0
          and t_st.restores > 0 and len(ample) == len(tight) == 2)
    for a, b in zip(sorted(ample, key=lambda r: r.rid),
                    sorted(tight, key=lambda r: r.rid)):
        ok = ok and np.array_equal(a.tokens, b.tokens)
    return float(ok)


def overload_rows():
    cfg = _continuous_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    low, high = _overload_traffic(cfg)

    edf = _overload_fleet(cfg, params, "edf")
    _overload_drain(edf, low, high, None)   # warmup: compile every shape
    _flush_fleet(edf)
    _high_only_ttfts(edf, high)             # warmup the unloaded shapes
    _flush_fleet(edf)
    ttfts_u = _high_only_ttfts(edf, high)   # measured: unloaded fleet
    p95_u = _pct(ttfts_u, 95)
    # the SLO is the acceptance bound itself: high-priority first tokens
    # within twice the unloaded fleet's p95
    target = 2.0 * p95_u
    _flush_fleet(edf)
    t0 = edf.stats.totals
    pre0, res0, stl0 = t0.preemptions, t0.restores, edf.stats.stolen
    wall_e, done_e, hf_e = _overload_drain(edf, low, high, target)
    good_e = _goodput(wall_e, done_e, hf_e, target)
    p95_e = _pct([r.ttft for r in done_e if r.rid in hf_e], 95)
    t1 = edf.stats.totals

    fifo = _overload_fleet(cfg, params, "fifo")
    _overload_drain(fifo, low, high, None)  # warmup
    _flush_fleet(fifo)
    wall_f, done_f, hf_f = _overload_drain(fifo, low, high, target)
    good_f = _goodput(wall_f, done_f, hf_f, target)
    p95_f = _pct([r.ttft for r in done_f if r.rid in hf_f], 95)

    bitexact = _preempt_bitexact(cfg, params)
    return [
        (f"serving/{ARCH}/overload/goodput_edf_tok_s",
         1e6 / max(good_e, 1e-9), good_e),
        (f"serving/{ARCH}/overload/goodput_fifo_tok_s",
         1e6 / max(good_f, 1e-9), good_f),
        (f"serving/{ARCH}/goodput_2x_over_fifo", 0.0,
         good_e / max(good_f, 1e-9)),
        (f"serving/{ARCH}/overload/high_ttft_p95_unloaded_s", 0.0, p95_u),
        (f"serving/{ARCH}/overload/high_ttft_p95_edf_s", 0.0, p95_e),
        (f"serving/{ARCH}/overload/high_ttft_p95_fifo_s", 0.0, p95_f),
        (f"serving/{ARCH}/overload/high_ttft_edf_over_2x_unloaded", 0.0,
         p95_e / max(2.0 * p95_u, 1e-9)),
        (f"serving/{ARCH}/overload/preemptions", 0.0,
         float(t1.preemptions - pre0)),
        (f"serving/{ARCH}/overload/restores", 0.0,
         float(t1.restores - res0)),
        (f"serving/{ARCH}/overload/stolen", 0.0,
         float(edf.stats.stolen - stl0)),
        (f"serving/{ARCH}/preempt_bitexact", 0.0, bitexact),
    ]


# RAG: the SAME paged scheduler, retrieval overlapped with decode (the
# default: the search starts on the I/O worker at submit and is
# collected after the next segment dispatch) vs the
# retrieve-then-decode pipeline (rag_overlap=False quiesces enqueued
# device work and searches inline on the dispatch thread). Retrieval
# cost is exact top-k scoring over the whole corpus plus a modeled
# 20ms payload fetch (io_latency_s — the disk/network stall a
# CPU-resident toy corpus doesn't otherwise exhibit; it sleeps with
# the GIL released, so the worker genuinely runs while the dispatch
# thread is inside XLA). Queries arrive in waves while long-running
# leads keep every slot decoding: the overlap arm's searches run
# behind the segment dispatch (synchronous on the CPU backend — the
# donated cache makes the seg() call block for the whole segment, a
# window far wider than one wave's retrieval), the serial arm stalls
# on every search before staging. Two scheduler properties carry the
# margin, and this row exists to catch regressions in them: (a) the
# submit-time kickoff onto the I/O worker, and (b) parked queries
# capping the next segment at ``segment`` steps (see
# ``_segment_steps``) so retrieved prompts stage at a near boundary
# instead of waiting out an uncapped power-of-two run — without the
# cap the overlap arm LOSES (admission latency eats more than
# retrieval hiding saves). The model is deliberately larger than the
# other serving rows' smoke cfg (d_model 512, 8 layers): the hiding
# window is the segment's compute, so it must cost real milliseconds.
# Lead lengths are staggered so retirements spread across boundaries
# and wave queries keep being admitted mid-flight. Queries concentrate
# on a few hot documents, so distinct queries retrieve overlapping
# chunk sets and the canonical-order pipeline turns that into
# chunk-block KV hits (the gated rag_chunk_hit_rate). Interleaved
# paired trials as in _measure_mix.
RAG_DOCS, RAG_DOC_LEN, RAG_HOT = 2048, 128, 4
RAG_IO_LATENCY = 0.020
RAG_LEAD_GENS = (72, 64, 56, 48)        # one per slot, staggered
RAG_WAVES, RAG_PER_WAVE, RAG_WAVE_GEN = 8, 2, 12


def _rag_cfg():
    # beefed-up smoke config: enough per-step compute that a segment's
    # in-flight window is worth hiding retrieval behind
    return dataclasses.replace(_continuous_cfg(), d_model=512,
                               num_heads=8, num_kv_heads=2,
                               d_ff=2048, num_layers=8)


def _rag_setup(cfg):
    from repro.retrieval import ChunkedCorpus, EmbeddingIndex, RagPipeline
    from repro.retrieval import make_toy_corpus

    docs = make_toy_corpus(cfg.vocab_size, n_docs=RAG_DOCS,
                           doc_len=RAG_DOC_LEN, seed=0)
    corpus = ChunkedCorpus(docs, chunk_tokens=2 * PAGED_BLOCK)
    index = EmbeddingIndex(corpus, vocab_size=cfg.vocab_size, seed=0,
                           io_latency_s=RAG_IO_LATENCY)
    pipe = RagPipeline(index, system_prefix=list(range(5, 5 + PAGED_BLOCK)),
                       block_size=PAGED_BLOCK, top_k=2)
    rng = np.random.RandomState(7)

    def q(i):
        d = docs[int(rng.randint(RAG_HOT))]
        lo = int(rng.randint(0, d.size - 8))
        return d[lo:lo + 4 + (i % 3)].copy()

    leads = [q(i) for i in range(len(RAG_LEAD_GENS))]
    waves = [[q(w * RAG_PER_WAVE + j) for j in range(RAG_PER_WAVE)]
             for w in range(RAG_WAVES)]
    return pipe, leads, waves


def rag_rows():
    cfg = _rag_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    pipe, leads, waves = _rag_setup(cfg)
    useful = (sum(RAG_LEAD_GENS)
              + RAG_WAVES * RAG_PER_WAVE * RAG_WAVE_GEN)
    max_len = pipe.prompt_len_for + 8 + max(RAG_LEAD_GENS)

    def make(overlap):
        return PagedContinuousBatchingServer(
            cfg, params, num_slots=CONT_SLOTS, max_len=max_len,
            block_size=PAGED_BLOCK, prefill_chunk=PAGED_BLOCK, segment=8,
            rag=pipe, rag_overlap=overlap)

    def run(server):
        # leads first (one per slot) so every wave lands mid-decode
        # with zero free slots; the drive is identical in both arms,
        # only where each wave's retrieval stall lands differs
        t0 = time.perf_counter()
        for q, g in zip(leads, RAG_LEAD_GENS):
            server.submit_query(q, g)
        server.step()
        for wave in waves:
            for q in wave:
                server.submit_query(q, RAG_WAVE_GEN)
            server.step()
        server.run()
        return time.perf_counter() - t0

    over, serial = make(True), make(False)
    for _ in range(2):          # compile + cover both segment shapes
        run(over), run(serial)
    hits0 = over.stats.retrieval_chunk_hits     # measured trials only
    blocks0 = over.stats.retrieval_chunk_blocks
    ratios, ov, se = [], [], []
    for _ in range(PAGED_TRIALS):
        ow = run(over)
        sw = run(serial)
        ratios.append(sw / ow)
        ov.append(useful / ow)
        se.append(useful / sw)
    mid = int(np.argsort(ratios)[len(ratios) // 2])
    hit_rate = (over.stats.retrieval_chunk_hits - hits0) / max(
        over.stats.retrieval_chunk_blocks - blocks0, 1)
    return [
        (f"serving/{ARCH}/rag/tok_s", 1e6 / ov[mid], ov[mid]),
        (f"serving/{ARCH}/rag_serial/tok_s", 1e6 / se[mid], se[mid]),
        (f"serving/{ARCH}/rag_overlap_over_serial", 0.0, ratios[mid]),
        (f"serving/{ARCH}/rag_chunk_hit_rate", 0.0, hit_rate),
        (f"serving/{ARCH}/rag/overlap_frac", 0.0,
         over.stats.retrieval_overlap_frac),
    ]


def rows():
    return (loop_vs_scan_rows() + flat_vs_plan_rows() + continuous_rows()
            + paged_rows() + paged_kernel_rows() + mesh_rows()
            + router_rows() + spec_rows() + overload_rows() + rag_rows())
