import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Perf hillclimb driver: lower one cell with config overrides and report
loop-aware roofline terms (EXPERIMENTS.md §Perf).

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb llama3-405b train_4k \
      --set seq_shard_acts=true --tset microbatch_per_device=2

Reports, per run:
  * loop-aware dot FLOPs (global) vs the analytic exact count,
  * loop-aware collective bytes per kind (per device),
  * the three roofline terms + roofline fraction,
  * memory_analysis temp bytes per device.
"""

import argparse
import json
import time


def parse_kv(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def measure(arch: str, shape: str, multi_pod: bool, cfg_over: dict,
            tcfg_over: dict) -> dict:
    from repro import configs as cfglib
    from repro.core import constants
    from repro.launch.dryrun import lower_cell
    from repro.launch.flops import analytic_step_bytes, analytic_step_flops
    from repro.launch.hlo_analysis import analyze_hlo

    t0 = time.time()
    lowered, compiled, ctx = lower_cell(arch, shape, multi_pod,
                                        cfg_over or None, tcfg_over or None)
    hlo = analyze_hlo(compiled.as_text())
    cell = cfglib.get_shape(shape)
    chips = ctx["chips"]
    chip = constants.V5E
    mem = compiled.memory_analysis()
    n_micro = tcfg_over.get("n_micro_effective")
    if cell.kind == "train":
        dp = 32 if multi_pod else 16
        mbpd = tcfg_over.get("microbatch_per_device", 1)
        n_micro = max(1, cell.global_batch // (mbpd * dp))
    else:
        n_micro = 1

    t_compute = hlo.dot_flops / chip.peak_flops
    t_coll = hlo.coll_bytes_total / chip.ici_bytes_per_s
    t_mem_ideal = analytic_step_bytes(
        cfglib.get_config(arch), cell, n_micro
    ) / (chips * chip.hbm_bytes_per_s)
    t_ideal = analytic_step_flops(cfglib.get_config(arch), cell) / (
        chips * chip.peak_flops
    )
    terms = {"compute": t_compute, "memory": t_mem_ideal,
             "collective": t_coll}
    return {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "overrides": {**cfg_over, **tcfg_over},
        "compile_s": round(time.time() - t0, 1),
        "dot_flops_global_P": round(hlo.dot_flops * chips / 1e15, 2),
        "analytic_flops_P": round(
            analytic_step_flops(cfglib.get_config(arch), cell) / 1e15, 2),
        "coll_GB_per_dev": {k: round(v / 1e9, 2)
                            for k, v in hlo.coll_bytes.items() if v > 0},
        "t_compute_s": t_compute,
        "t_mem_ideal_s": t_mem_ideal,
        "t_collective_s": t_coll,
        "bottleneck": max(terms, key=terms.get),
        "step_s": max(terms.values()),
        "roofline_fraction": t_ideal / max(terms.values()),
        "temp_GiB_per_dev": round(mem.temp_size_in_bytes / 2**30, 2),
        "arg_GiB_per_dev": round(mem.argument_size_in_bytes / 2**30, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--set", action="append", help="cfg override k=v")
    ap.add_argument("--tset", action="append", help="train-cfg override k=v")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    rec = measure(args.arch, args.shape, args.mesh == "multi",
                  parse_kv(args.set), parse_kv(args.tset))
    print(json.dumps(rec, indent=1))
    if args.tag:
        os.makedirs("results/hillclimb", exist_ok=True)
        with open(f"results/hillclimb/{args.tag}.json", "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
