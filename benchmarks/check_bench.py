"""Schema gate for the serving bench artifact (BENCH_serving.json).

CI generates the bench JSON fresh every run, but perf numbers on shared
runners are noise — so the gate validates STRUCTURE, not speed: the
sections and rows the trajectory file promises must exist, every
throughput row must carry a real (finite, positive) tokens/s value, and
the one sanity invariant that is about mechanism rather than machine —
scan-compiled decode beats the per-token dispatch loop — must hold
(``loop-vs-scan > 1.0x`` survives any CPU; it only breaks if someone
re-introduces a per-token host round-trip).

Run: python benchmarks/check_bench.py [path]   (default BENCH_serving.json)
Exit code 0 = schema valid; 1 = violation (each printed with its rule).
"""

from __future__ import annotations

import json
import math
import sys

# rows every serving bench must emit (name suffixes, per serving_bench.py)
REQUIRED_ROWS = (
    "loop/tok_s",
    "scan/tok_s",
    "scan_over_loop_speedup",
    "plan_flat/tok_s",
    "plan_per_layer/tok_s",
    "continuous/tok_s",
    "static_batch/tok_s",
    "continuous_over_static",
    "continuous_crossover_mix",
    "continuous/wasted_step_frac",
    "paged/tok_s",
    "sync_admission/tok_s",
    "paged_over_sync_admission",
    "paged/prefix_hit_rate",
    "paged_kernel/tok_s",
    "paged_slab/tok_s",
    "paged_kernel_over_slab",
    "tp2/tok_s",
    "tp_solo/tok_s",
    "tp2_over_solo",
    "tp_tokens_match",
    "fleet_prefix_hit_rate",
    "fleet_random_hit_rate",
    "router_affinity_over_random",
    "spec/tok_s",
    "spec_plain/tok_s",
    "spec_over_plain",
    "spec_tokens_match",
    "spec/acceptance_rate",
    "overload/goodput_edf_tok_s",
    "overload/goodput_fifo_tok_s",
    "goodput_2x_over_fifo",
    "overload/high_ttft_p95_edf_s",
    "overload/preemptions",
    "preempt_bitexact",
    "rag/tok_s",
    "rag_serial/tok_s",
    "rag_overlap_over_serial",
    "rag_chunk_hit_rate",
    "rag/overlap_frac",
)
# rows whose derived value is a throughput and must be a positive number
TOK_S_ROWS = tuple(r for r in REQUIRED_ROWS if r.endswith("tok_s"))


def check(records: list) -> list[str]:
    errors = []
    if not isinstance(records, list) or not records:
        return ["bench JSON must be a non-empty list of row objects"]
    by_suffix: dict[str, dict] = {}
    for i, row in enumerate(records):
        if not isinstance(row, dict):
            errors.append(f"row {i}: not an object")
            continue
        missing = {"section", "name", "us_per_call", "derived"} - set(row)
        if missing:
            errors.append(f"row {i}: missing keys {sorted(missing)}")
            continue
        for suffix in REQUIRED_ROWS:
            if row["name"].endswith(suffix):
                by_suffix.setdefault(suffix, row)
    serving = [r for r in records
               if isinstance(r, dict) and r.get("section") == "serving"]
    if not serving:
        errors.append('no rows with section == "serving"')
    for suffix in REQUIRED_ROWS:
        if suffix not in by_suffix:
            errors.append(f"required row */{suffix} is absent")
    for suffix in TOK_S_ROWS:
        row = by_suffix.get(suffix)
        if row is None:
            continue
        v = row["derived"]
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            errors.append(
                f"{row['name']}: tokens/s must be a finite positive "
                f"number, got {v!r}"
            )
    speedup = by_suffix.get("scan_over_loop_speedup")
    if speedup is not None:
        v = speedup["derived"]
        if not isinstance(v, (int, float)) or not v > 1.0:
            errors.append(
                f"{speedup['name']}: scan-compiled decode must beat the "
                f"per-token loop (> 1.0x), got {v!r} — a regression here "
                "means a per-token host round-trip came back"
            )
    hit = by_suffix.get("paged/prefix_hit_rate")
    if hit is not None:
        v = hit["derived"]
        if not isinstance(v, (int, float)) or not 0 < v <= 1:
            errors.append(
                f"{hit['name']}: the shared-prefix mix must hit the "
                f"prefix cache (0 < rate <= 1), got {v!r} — zero means "
                "hash-consed blocks stopped being spliced"
            )
    kernel = by_suffix.get("paged_kernel_over_slab")
    if kernel is not None:
        v = kernel["derived"]
        if not isinstance(v, (int, float)) or not v >= 1.0:
            errors.append(
                f"{kernel['name']}: in-place paged decode must at least "
                f"match the gather/scatter slab segment (>= 1.0x) on the "
                f"boundary-heavy mix, got {v!r} — the pool round-trip "
                "came back, or the table-walking step grew a per-step "
                "cost the slab doesn't pay"
            )
    tp_match = by_suffix.get("tp_tokens_match")
    if tp_match is not None:
        v = tp_match["derived"]
        if v != 1:
            errors.append(
                f"{tp_match['name']}: tensor-parallel serving must be "
                f"token-identical to the solo server (== 1), got {v!r} — "
                "the shard_map partition stopped being a pure "
                "parallelization (psum placement, vocab offset, or KV "
                "sharding drifted)"
            )
    affinity = by_suffix.get("router_affinity_over_random")
    if affinity is not None:
        v = affinity["derived"]
        if not isinstance(v, (int, float)) or not v >= 1.0:
            errors.append(
                f"{affinity['name']}: prefix-affinity routing must at "
                f"least match random spray on shared-prefix waves "
                f"(>= 1.0), got {v!r} — the router stopped steering "
                "requests to the replica holding their prefix blocks"
            )
    goodput = by_suffix.get("goodput_2x_over_fifo")
    if goodput is not None:
        v = goodput["derived"]
        if not isinstance(v, (int, float)) or not v >= 1.0:
            errors.append(
                f"{goodput['name']}: EDF admission + preemption must at "
                f"least match FIFO goodput at 2x oversubscription "
                f"(>= 1.0), got {v!r} — high-priority requests stopped "
                "jumping the backlog (or preemption got expensive enough "
                "to eat the SLO wins)"
            )
    bitexact = by_suffix.get("preempt_bitexact")
    if bitexact is not None:
        v = bitexact["derived"]
        if v != 1:
            errors.append(
                f"{bitexact['name']}: a preempted-then-restored drain "
                f"must be token-identical to an unpressured one (== 1), "
                f"got {v!r} — the spill/restore round-trip (KV copy, "
                "position-keyed PRNG, resume splice) stopped being "
                "lossless"
            )
    spec_match = by_suffix.get("spec_tokens_match")
    if spec_match is not None:
        v = spec_match["derived"]
        if v != 1:
            errors.append(
                f"{spec_match['name']}: speculative decode must be "
                f"token-identical to plain paged decode (== 1), got "
                f"{v!r} — accept/rollback stopped being lossless (a "
                "rejected draft leaked into the stream, or the verify "
                "program's position-keyed sampling drifted from the "
                "decode path's)"
            )
    accept = by_suffix.get("spec/acceptance_rate")
    if accept is not None:
        v = accept["derived"]
        if not isinstance(v, (int, float)) or not 0 <= v <= 1:
            errors.append(
                f"{accept['name']}: acceptance must be a rate in [0, 1], "
                f"got {v!r}"
            )
    rag_hit = by_suffix.get("rag_chunk_hit_rate")
    if rag_hit is not None:
        v = rag_hit["derived"]
        if not isinstance(v, (int, float)) or not 0 < v <= 1:
            errors.append(
                f"{rag_hit['name']}: hot-document queries must share "
                f"chunk-addressed KV blocks (0 < rate <= 1), got {v!r} — "
                "zero means content-addressed chunk blocks stopped being "
                "spliced across queries (chained chunk keys broken, or "
                "canonical chunk ordering lost)"
            )
    rag_ratio = by_suffix.get("rag_overlap_over_serial")
    if rag_ratio is not None:
        v = rag_ratio["derived"]
        if not isinstance(v, (int, float)) or not v >= 1.0:
            errors.append(
                f"{rag_ratio['name']}: overlapped retrieval must at "
                f"least match the retrieve-then-decode pipeline "
                f"(>= 1.0x), got {v!r} — the submit-time kickoff onto "
                "the retrieval I/O worker stopped hiding the search "
                "behind decode, or parked queries stopped capping the "
                "segment (admission latency eats the win)"
            )
    ofrac = by_suffix.get("rag/overlap_frac")
    if ofrac is not None:
        v = ofrac["derived"]
        if not isinstance(v, (int, float)) or not 0 < v <= 1:
            errors.append(
                f"{ofrac['name']}: the wave-driven RAG mix must collect "
                f"most retrievals at the post-dispatch boundary "
                f"(0 < frac <= 1), got {v!r} — zero means every query "
                "drained on the serial path and nothing overlapped"
            )
    paged = by_suffix.get("paged_over_sync_admission")
    if paged is not None:
        v = paged["derived"]
        if not isinstance(v, (int, float)) or not v >= 1.0:
            errors.append(
                f"{paged['name']}: prefill-ahead through the paged pool "
                f"must at least match synchronous admission (>= 1.0x) on "
                f"the shared-prefix heavy-tail mix, got {v!r} — the "
                "prefix splice + staged admission stopped paying for the "
                "block bookkeeping"
            )
    return errors


def main(argv: list[str]) -> int:
    path = argv[1] if len(argv) > 1 else "BENCH_serving.json"
    try:
        with open(path) as f:
            records = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read {path}: {e}", file=sys.stderr)
        return 1
    errors = check(records)
    for e in errors:
        print(f"check_bench: {path}: {e}", file=sys.stderr)
    if not errors:
        print(f"check_bench: {path}: {len(records)} rows, schema OK")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
