"""Measured (wall-clock) four-mode sidebar microbenchmark on this host.

The sidebar principle — fuse the flexible function into the producer so
the intermediate never leaves near-compute memory — is measurable on ANY
backend as fused-one-dispatch vs three-dispatches-with-materialization.
This bench times the same f(x@W1)@W2 computation under all four designs:

  monolithic : one jitted program (XLA fuses the activation)
  flexible_dma : three jitted programs with block_until_ready between
               them (forced materialization = the DMA round-trip)
  sidebar    : one jitted program with the activation looked up in the
               FunctionTable at trace time (the hot-swappable fused path)
  sidebar_pipelined : one jitted program running the ping-pong schedule —
               the f-axis is split into blocks and the activation of
               block j-1 is interleaved with the producer matmul of
               block j, mirroring kernels/sidebar_mlp.sidebar_mlp_pipelined

CPU numbers are not TPU numbers, but the RATIO demonstrates the paper's
mechanism with real measured time. The ``derived`` column is the
analytical model's latency for the same task on the target chip
(core.engine.account -> core.energy.estimate), where the pipelined
overlap win is visible even when XLA fuses the serial variants equally.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (
    DEFAULT_TABLE,
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    StaticOp,
    account,
    estimate,
)

SHAPES = [(256, 512, 2048), (512, 1024, 4096)]
ACTS = ["relu", "softplus"]
MODES = list(ExecutionMode)
F_BLOCKS = 4  # ring schedule granularity for the pipelined variant
DEPTHS = (2, 3, 4, 8)  # ring depths swept by depth_sweep_rows


def _time(fn, *args, repeats=5) -> float:
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def _mlp_graph(m: int, d: int, f: int, act: str) -> LayerGraph:
    def mm(w, x):
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)

    return LayerGraph(
        name=f"mlp{m}x{d}x{f}",
        ops=(
            StaticOp("w1", mm, (m, f), flops=2 * m * d * f,
                     weight_bytes=d * f * 4),
            FlexibleOp(act, (m, f)),
            StaticOp("w2", mm, (m, d), flops=2 * m * f * d,
                     weight_bytes=f * d * 4),
        ),
        in_shape=(m, d),
    )


def _variants(act_name: str):
    """Measured implementations, one dispatch count per mode."""
    act = DEFAULT_TABLE.lookup(act_name)

    fused = jax.jit(lambda x, w1, w2: act(x @ w1) @ w2)

    mm1 = jax.jit(lambda x, w1: x @ w1)
    act_j = jax.jit(act)
    mm2 = jax.jit(lambda h, w2: h @ w2)

    def dma_style(x, w1, w2):
        h = jax.block_until_ready(mm1(x, w1))   # DMA out
        h = jax.block_until_ready(act_j(h))     # host step
        return mm2(h, w2)                        # DMA in

    # sidebar: identical fusion, but the flexible fn comes from the table
    # at trace time (register a new activation -> re-jit, no source change)
    sidebar = jax.jit(
        lambda x, w1, w2: DEFAULT_TABLE.lookup(act_name)(x @ w1) @ w2
    )

    return {
        ExecutionMode.MONOLITHIC: fused,
        ExecutionMode.FLEXIBLE_DMA: dma_style,
        ExecutionMode.SIDEBAR: sidebar,
        ExecutionMode.SIDEBAR_PIPELINED: _pipelined_impl(act_name, F_BLOCKS),
    }


def _pipelined_impl(act_name: str, f_blocks: int):
    """Jitted T-deep ring schedule: the activation of f-block j-1
    interleaves with the producer matmul of f-block j (one fused
    dispatch); a ceil block size plus explicit spans covers any
    remainder exactly."""
    act = DEFAULT_TABLE.lookup(act_name)

    def pipelined(x, w1, w2):
        f = w1.shape[1]
        bf = -(-f // f_blocks)
        spans = [(s, min(s + bf, f)) for s in range(0, f, bf)]
        y = jnp.zeros((x.shape[0], w2.shape[1]), jnp.float32)
        h_prev = x @ w1[:, spans[0][0]:spans[0][1]]
        for j in range(1, len(spans) + 1):
            h_next = (
                x @ w1[:, spans[j][0]:spans[j][1]] if j < len(spans) else None
            )
            lo, hi = spans[j - 1]
            y = y + act(h_prev) @ w2[lo:hi]
            h_prev = h_next
        return y.astype(x.dtype)

    return jax.jit(pipelined)


def _uneven_graph(m: int, d: int, f: int, d2: int, act: str) -> LayerGraph:
    """MLP with deliberately uneven producer/consumer cost: the producer
    matmul (d -> f) dwarfs the consumer (f -> d2, d2 << d), so the
    consumer prologue's donation saturates early and deeper rings keep
    winning — the regime where T matters."""
    def mm(w, x):
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)

    return LayerGraph(
        name=f"uneven{m}x{d}x{f}x{d2}",
        ops=(
            StaticOp("w1", mm, (m, f), flops=2 * m * d * f,
                     weight_bytes=d * f * 4),
            FlexibleOp(act, (m, f)),
            StaticOp("w2", mm, (m, d2), flops=2 * m * f * d2,
                     weight_bytes=f * d2 * 4),
        ),
        in_shape=(m, d),
    )


def depth_sweep_rows() -> list[tuple[str, float, float]]:
    """Ring-depth sweep (T in DEPTHS) on the uneven-cost graph: for each
    depth, the measured wall time of the T-block ring schedule plus the
    engine-run (measured) and schedule-model (modeled) stall/overlap
    cycle counts — emitted as (tag, measured, modeled) rows."""
    import numpy as np

    from repro.core import run

    m, d, f, d2 = 256, 512, 2048, 4
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, d), jnp.float32) * 0.1
    w1 = jax.random.normal(k2, (d, f), jnp.float32) * 0.02
    w2 = jax.random.normal(k3, (f, d2), jnp.float32) * 0.02
    out = []
    for act_name in ACTS:
        graph = _uneven_graph(m, d, f, d2, act_name)
        params = {"w1": np.asarray(w1), "w2": np.asarray(w2)}
        tag = f"depth/{m}x{d}x{f}x{d2}/{act_name}"
        for t in DEPTHS:
            acct = account(graph, ExecutionMode.SIDEBAR_PIPELINED,
                           DEFAULT_TABLE, depth=t)
            res = run(graph, params, x, ExecutionMode.SIDEBAR_PIPELINED,
                      DEFAULT_TABLE, depth=t)
            st = res.sidebar.stats
            us = _time(_pipelined_impl(act_name, t), x, w1, w2)
            lat = estimate(acct).latency_s
            out.append((f"{tag}/T{t}_us", us, lat))
            out.append((f"{tag}/T{t}_stall_cycles",
                        float(st.stall_cycles), float(acct.stall_cycles)))
            out.append((f"{tag}/T{t}_overlap_cycles",
                        float(st.overlap_cycles), float(acct.overlap_cycles)))
    return out


def rows() -> list[tuple[str, float, float]]:
    out = []
    for m, d, f in SHAPES:
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (m, d), jnp.float32) * 0.1
        w1 = jax.random.normal(k2, (d, f), jnp.float32) * 0.02
        w2 = jax.random.normal(k3, (f, d), jnp.float32) * 0.02
        for act_name in ACTS:
            impls = _variants(act_name)
            graph = _mlp_graph(m, d, f, act_name)
            tag = f"fusion/{m}x{d}x{f}/{act_name}"
            for mode in MODES:
                us = _time(impls[mode], x, w1, w2)
                model_lat = estimate(account(graph, mode, DEFAULT_TABLE)).latency_s
                out.append((f"{tag}/{mode.value}_us", us, model_lat))
    return out
