"""Measured (wall-clock) sidebar-vs-DMA microbenchmark on this host.

The sidebar principle — fuse the flexible function into the producer so
the intermediate never leaves near-compute memory — is measurable on ANY
backend as fused-one-dispatch vs three-dispatches-with-materialization.
This bench times the same f(x@W1)@W2 computation:

  monolithic/sidebar : one jitted program (XLA fuses the activation)
  flexible_dma       : three jitted programs with block_until_ready
                       between them (forced materialization = the DMA)

CPU numbers are not TPU numbers, but the RATIO demonstrates the paper's
mechanism with real measured time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.function_table import DEFAULT_TABLE

SHAPES = [(256, 512, 2048), (512, 1024, 4096)]
ACTS = ["relu", "softplus"]


def _time(fn, *args, repeats=5) -> float:
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def rows() -> list[tuple[str, float, float]]:
    out = []
    for m, d, f in SHAPES:
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (m, d), jnp.float32) * 0.1
        w1 = jax.random.normal(k2, (d, f), jnp.float32) * 0.02
        w2 = jax.random.normal(k3, (f, d), jnp.float32) * 0.02
        for act_name in ACTS:
            act = DEFAULT_TABLE.lookup(act_name)

            fused = jax.jit(lambda x, w1, w2: act(x @ w1) @ w2)
            mm1 = jax.jit(lambda x, w1: x @ w1)
            act_j = jax.jit(act)
            mm2 = jax.jit(lambda h, w2: h @ w2)

            def dma_style(x, w1, w2):
                h = jax.block_until_ready(mm1(x, w1))   # DMA out
                h = jax.block_until_ready(act_j(h))     # host step
                return mm2(h, w2)                        # DMA in

            t_fused = _time(fused, x, w1, w2)
            t_dma = _time(dma_style, x, w1, w2)
            tag = f"fusion/{m}x{d}x{f}/{act_name}"
            out.append((f"{tag}/fused_us", t_fused, 1.0))
            out.append((f"{tag}/dma_us", t_dma, t_dma / t_fused))
    return out
