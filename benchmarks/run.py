# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  fig6_latency      — paper Figure 6 (inference latency, 3 designs)
  fig7_energy       — paper Figure 7 (communication energy split)
  fig8_edp          — paper Figure 8 (normalized EDP)
  table3_primitives — paper Table 3 (per-primitive cost/area analogue)
  activation_sweep  — paper §6.1 (gap vs activation cost)
  claims            — pass/fail of the paper's quantitative claims
  fusion            — measured wall-clock sidebar-vs-DMA on this host
  depth_sweep       — ring-depth sweep T in {2,3,4,8}: measured wall +
                      measured/modeled stall and overlap cycles
  roofline          — per-(arch x shape x mesh) dry-run roofline terms
  serving           — scan-vs-loop decode, per-layer plan dispatch, and
                      continuous-vs-static batching (tokens/s, p50/p95)

Run: PYTHONPATH=src python -m benchmarks.run [section ...] [--json out.json]

``--json PATH`` additionally writes the rows as a JSON list of
``{"section", "name", "us_per_call", "derived"}`` objects — the
machine-readable form committed as BENCH_*.json trajectory files.
"""

from __future__ import annotations

import json
import sys


def main() -> None:
    from benchmarks import (
        fusion_bench,
        paper_figures,
        roofline_report,
        serving_bench,
    )

    sections = {
        "fig6_latency": paper_figures.fig6_latency,
        "fig7_energy": paper_figures.fig7_energy,
        "fig8_edp": paper_figures.fig8_edp,
        "table3_primitives": paper_figures.table3_primitives,
        "activation_sweep": paper_figures.activation_sweep,
        "claims": paper_figures.validate_paper_claims,
        "fusion": fusion_bench.rows,
        "depth_sweep": fusion_bench.depth_sweep_rows,
        "roofline": roofline_report.rows,
        "serving": serving_bench.rows,
    }
    argv = sys.argv[1:]
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            raise SystemExit("--json requires an output path")
        argv = argv[:i] + argv[i + 2:]
    wanted = argv or list(sections)
    print("name,us_per_call,derived")
    failures = 0
    records = []
    for name in wanted:
        fn = sections[name]
        try:
            for row in fn():
                tag, us, derived = row
                print(f"{tag},{us:.3f},{derived:.6e}")
                records.append({
                    "section": name, "name": tag,
                    "us_per_call": round(float(us), 3),
                    "derived": float(derived),
                })
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}/ERROR,0,0  # {type(e).__name__}: {e}",
                  file=sys.stderr)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} rows to {json_path}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
