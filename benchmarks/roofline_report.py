"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh) cell this reports:

  raw terms        — straight from compiled.cost_analysis() (XLA counts
                     each while/scan body ONCE; verified in
                     tests/test_roofline.py),
  corrected terms  — raw x the known scan-trip product (layer scan +
                     microbatch scan; see launch/flops.scan_correction),
  t_ideal          — exact analytic flops / (chips x peak): the useful-
                     compute time this step fundamentally needs,
  roofline_frac    — t_ideal / max(corrected terms): the headline
                     "fraction of roofline" score (1.0 = at the roof).

KNOWN RESIDUAL: inner chunk loops (32k chunked attention, SSD/WKV chunks)
are still once-counted inside the measured body; t_ideal (analytic) is
exact and catches the gap — cells where corrected t_compute << t_ideal
are flagged with '*'.
"""

from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_records() -> list[dict]:
    if not os.path.isdir(RESULTS):
        return []
    out = []
    for name in sorted(os.listdir(RESULTS)):
        if name.endswith(".json"):
            with open(os.path.join(RESULTS, name)) as f:
                out.append(json.load(f))
    return out


def _n_micro(cell, mesh_name: str) -> int:
    if cell.kind != "train":
        return 1
    dp = 32 if mesh_name == "multi" else 16
    return max(1, cell.global_batch // dp)


def enrich(record: dict) -> dict | None:
    """Attach corrected terms + analytic ideal terms to a dry-run record."""
    if not record.get("ok"):
        return None
    from repro import configs as cfglib
    from repro.core import constants
    from repro.launch.flops import (
        analytic_step_bytes,
        analytic_step_flops,
        scan_correction,
    )

    cfg = cfglib.get_config(record["arch"])
    cell = cfglib.get_shape(record["shape"])
    chips = record["chips"]
    chip = constants.V5E
    n_micro = _n_micro(cell, record["mesh"])
    k = scan_correction(cfg, cell, n_micro)
    t = record["roofline"]
    la = record.get("loop_aware")
    corr = {
        "t_compute": (
            la["dot_flops_per_dev"] / chip.peak_flops
            if la else t["t_compute"] * k
        ),
        "t_memory": t["t_memory"] * k,
        "t_collective": (
            la["coll_bytes_total_per_dev"] / chip.ici_bytes_per_s
            if la else t["t_collective"] * k
        ),
    }
    # analytic (fused-TPU) terms — the honest roofline model; the measured
    # XLA:CPU "bytes accessed" is an unfused upper bound.
    t_ideal = analytic_step_flops(cfg, cell) / (chips * chip.peak_flops)
    t_mem_ideal = analytic_step_bytes(cfg, cell, n_micro) / (
        chips * chip.hbm_bytes_per_s
    )
    ideal = {
        "t_compute": t_ideal,
        "t_memory": t_mem_ideal,
        "t_collective": corr["t_collective"],  # measured (post-SPMD, real)
    }
    step = max(ideal.values())
    frac = t_ideal / step if step else 0.0
    return {
        **record,
        "kappa": k,
        "corrected": corr,
        "ideal": ideal,
        "bottleneck_corrected": max(corr, key=corr.get).replace("t_", ""),
        "bottleneck_ideal": max(ideal, key=ideal.get).replace("t_", ""),
        "t_ideal": t_ideal,
        "t_mem_ideal": t_mem_ideal,
        "roofline_fraction": frac,
        "undercounted": corr["t_compute"] < 0.5 * t_ideal,
    }


def rows() -> list[tuple[str, float, float]]:
    out = []
    recs = load_records()
    n_ok = sum(1 for r in recs if r.get("ok"))
    out.append(("dryrun/cells_ok", 0.0, float(n_ok)))
    out.append(("dryrun/cells_total", 0.0, float(len(recs))))
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        e = enrich(r)
        if e is None:
            out.append((f"{tag}/FAILED", 0.0, 0.0))
            continue
        c = e["corrected"]
        out.append((f"{tag}/t_compute_s", 0.0, c["t_compute"]))
        out.append((f"{tag}/t_memory_s", 0.0, c["t_memory"]))
        out.append((f"{tag}/t_collective_s", 0.0, c["t_collective"]))
        out.append((f"{tag}/t_ideal_s", 0.0, e["t_ideal"]))
        out.append((f"{tag}/roofline_fraction", 0.0, e["roofline_fraction"]))
        out.append((f"{tag}/bound_{e['bottleneck_corrected']}", 0.0, 1.0))
    return out


def markdown_table(mesh: str = "single") -> str:
    recs = [r for r in load_records() if r.get("mesh") == mesh]
    lines = [
        "| arch | shape | t_comp ideal | t_mem ideal | t_coll meas | "
        "t_comp HLO | t_mem HLO | bound | roofline frac | temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        e = enrich(r)
        if e is None:
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
            continue
        c, i = e["corrected"], e["ideal"]
        temp = r["roofline"]["bytes_per_device"]["temp"] / 2**30
        star = "*" if e["undercounted"] else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {i['t_compute']:.2e} | "
            f"{i['t_memory']:.2e} | {i['t_collective']:.2e} | "
            f"{c['t_compute']:.2e}{star} | {c['t_memory']:.2e} | "
            f"{e['bottleneck_ideal']} | "
            f"{e['roofline_fraction']:.3f} | {temp:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print("## single-pod (16x16 = 256 chips)\n")
    print(markdown_table("single"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(markdown_table("multi"))
