"""Checkpointing substrate: atomic, async, elastic.

Fault-tolerance contract:
  * **Atomic**: checkpoints are written to ``<dir>/tmp.<step>`` and
    ``os.replace``d into place — a crash mid-save never corrupts the
    latest valid checkpoint.
  * **Manifest**: every checkpoint carries step, config hash, mesh shape,
    and the flattened key paths, so restore validates compatibility and
    *resharding* is explicit, not accidental.
  * **Async**: ``save_async`` snapshots to host memory synchronously
    (cheap) and writes in a background thread — training continues while
    bytes hit disk. ``wait()`` joins before the next save or exit.
  * **Elastic**: ``restore(..., mesh=new_mesh, shardings=new_shardings)``
    re-device_puts the host arrays under a *different* mesh than the one
    that saved them (scale up/down across restarts) — tested both ways.
  * **Retention**: keeps the newest ``keep`` checkpoints, deletes older.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

SEP = "##"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz can't round-trip ml_dtypes extended types; fp32 is a
            # lossless container for bf16 (restore() casts back).
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, meta: dict | None = None) -> str:
        self.wait()
        return self._write(step, _flatten(tree), meta or {})

    def save_async(self, step: int, tree, *, meta: dict | None = None) -> None:
        self.wait()
        flat = _flatten(tree)  # host snapshot NOW (device -> host copy)
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, meta or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, meta: dict) -> str:
        tmp = os.path.join(self.directory, f"tmp.{step}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "meta": meta,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                # only completed (atomic-renamed) checkpoints count
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, *, shardings=None,
                expect_meta: dict | None = None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching tree of NamedShardings
        for elastic re-placement (may target a different mesh than saved)."""
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if expect_meta:
            for k, v in expect_meta.items():
                got = manifest["meta"].get(k)
                if got != v:
                    raise ValueError(
                        f"checkpoint meta mismatch for {k!r}: saved {got!r}, "
                        f"expected {v!r}"
                    )
        data = np.load(os.path.join(path, "arrays.npz"))
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None
            else [None] * len(paths)
        )
        for (path_keys, leaf), shard in zip(paths, shard_leaves):
            key = SEP.join(str(p) for p in path_keys)
            if key not in data:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key!r}: saved {arr.shape}, "
                    f"model wants {leaf.shape}"
                )
            if shard is not None:
                leaves.append(jax.device_put(arr.astype(leaf.dtype), shard))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest
