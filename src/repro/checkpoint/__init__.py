"""Substrate package."""
