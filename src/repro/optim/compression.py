"""Gradient compression with error feedback (distributed-optimization trick).

At 1000+ nodes the cross-pod (DCN) gradient bytes dominate step time for
FSDP reduce-scatters. Two codecs:

  * ``bf16``   — cast-down/cast-up (2x). With bf16 params this is already
                 the wire format; provided for fp32-master setups.
  * ``int8_ef`` — per-tensor-block int8 quantization with **error
                 feedback**: the quantization residual is carried in a
                 state buffer and added to the next step's gradient, so
                 the compression bias vanishes in expectation (1-bit-Adam /
                 EF-SGD lineage). 4x wire reduction.

The codec is applied at the gradient-sync boundary in the train step
(between accumulation and the optimizer). Under XLA SPMD the reduce
itself is compiler-inserted; the codec bounds the *bytes entering it*
(the quantized+dequantized values are what get reduced). Tests assert the
EF property: cumulative compressed updates track cumulative true
gradients to O(1) error, not O(steps).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class EFState(NamedTuple):
    residual: Any  # error-feedback buffer, same tree/dtype-class as grads


def init_ef(grads_like) -> EFState:
    return EFState(jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads_like))


def _quantize_int8(x: Array) -> tuple[Array, Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress(grads, kind: str, ef: EFState | None = None):
    """Returns (decoded_grads, new_ef). Decoded = what the reduce carries."""
    if kind == "none":
        return grads, ef
    if kind == "bf16":
        dec = jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32).astype(g.dtype),
            grads,
        )
        return dec, ef
    if kind == "int8_ef":
        assert ef is not None, "int8_ef needs an EFState"

        def one(g, r):
            gf = g.astype(jnp.float32) + r
            q, scale = _quantize_int8(gf)
            dec = q.astype(jnp.float32) * scale
            return dec.astype(g.dtype), gf - dec

        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(ef.residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        dec = jax.tree.unflatten(treedef, [o[0] for o in outs])
        res = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return dec, EFState(res)
    raise ValueError(f"unknown compression kind {kind!r}")
