"""Substrate package."""
