"""Optimizer substrate: AdamW + schedules + clipping, pure-pytree.

Built from scratch (no optax): the optimizer state is a pytree sharded
exactly like the parameters (FSDP/ZeRO-3 — the sharding tree for the
state mirrors the ParamSpec tree), so at 405B scale the moments live
sharded over all devices.

Large-scale knobs:
  * ``moment_dtype`` — bf16 moments for the largest configs (halves
    optimizer HBM; the update math still runs in fp32).
  * bf16 gradient reduction falls out of bf16 params (grads inherit param
    dtype; the FSDP reduce-scatter moves bf16 bytes) with fp32 update
    arithmetic here — the classic mixed-precision trick.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Array = jax.Array


class AdamState(NamedTuple):
    step: Array          # scalar int32
    mu: Any              # first moment tree
    nu: Any              # second moment tree


def init_state(params, tcfg: TrainConfig) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, tcfg.moment_dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def abstract_state(abstract_params, tcfg: TrainConfig) -> AdamState:
    """ShapeDtypeStruct state (dry-run path)."""
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, tcfg.moment_dtype)
    return AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(zeros, abstract_params),
        nu=jax.tree.map(zeros, abstract_params),
    )


def state_shardings(param_shardings, mesh) -> AdamState:
    """Optimizer-state sharding mirrors parameter sharding (ZeRO)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    return AdamState(
        step=NamedSharding(mesh, P()),
        mu=param_shardings,
        nu=param_shardings,
    )


def lr_schedule(tcfg: TrainConfig, step: Array) -> Array:
    """Linear warmup then inverse-sqrt decay (production default)."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(tcfg.warmup_steps, 1), 1.0)
    decay = jax.lax.rsqrt(
        jnp.maximum(step.astype(jnp.float32), float(tcfg.warmup_steps))
        / float(tcfg.warmup_steps)
    )
    return tcfg.learning_rate * warm * decay


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(params, grads, state: AdamState, tcfg: TrainConfig):
    """One AdamW step; fp32 math, params/moments keep their dtypes."""
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(tcfg, step)
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
