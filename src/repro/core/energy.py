"""Analytical latency / energy / EDP model (reproduces paper Figs. 6–8).

The model converts a ``TaskAccounting`` (exact byte/flop/protocol counts
produced by ``core.engine.account``) into seconds and joules using the
cited constants in ``core.constants``. It deliberately mirrors the paper's
cost structure:

  * static primitives run on the MXU, overlapped with HBM weight/IO
    streaming (``max(compute, memory)`` — the roofline kernel model);
  * flexible functions are *serial* with the accelerator (the accelerator
    stalls while the "host" computes — paper §4: the FSM polls until the
    CPU signals completion);
  * FLEXIBLE_DMA pays 4 HBM crossings of each intermediate + per-launch
    DMA flush/invalidate + a DRAM-fed host pipeline stall factor;
  * SIDEBAR: the accelerator's own sidebar writes/reads replace its
    private-buffer traffic (free in time, counted in energy); the HOST
    side streams its half of the bytes at VMEM-class bandwidth,
    overlapped with its VPU compute (max, not sum), plus 2 flag
    handshakes at L1 latency;
  * MONOLITHIC computes flexible functions in a dedicated pipelined
    stage: the FIRST vector-op per element rides the pipeline at
    peak/4; the remaining (cost-1) ops run at the same elementwise rate
    as any vector engine (peak/16) — this reproduces the paper's
    Table 3, where the softplus monolithic is 21% slower than the relu
    monolithic (dedicated HW is not magic for transcendentals);
  * SIDEBAR_PIPELINED keeps SIDEBAR's compute energy but the T-deep ring
    hides the overlapped fraction of the host work (``overlap_cycles /
    host_busy_cycles``, which grows with the ring depth the schedule was
    accounted at) behind accelerator compute — only the ``stall_cycles``
    fraction stays on the critical path, so latency (and leakage energy,
    which scales with it) drops. Fused runs of consecutive flexible ops
    also shrink ``sidebar_bytes`` (inter-op intermediates stay in host
    registers) and the exposed handshake count (one invoke + one return
    per *stage*). This is the model ``policy.AutoPolicy`` sweeps ring
    depth against, under the sidebar-capacity constraint.

Rates derived from the chip spec:
  vpu_rate        = peak_flops / 16   (vector unit vs systolic array)
  mono_pipe_rate  = peak_flops / 4    (in-pipeline simple-op stage)
  dma_stall       = 2.0               (DRAM-fed host pipeline stall factor)
"""

from __future__ import annotations

import dataclasses

from repro.core.constants import ChipSpec, V5E

VPU_RATE_DIV = 16.0
MONO_HW_RATE_DIV = 4.0
DMA_HOST_STALL = 2.0


@dataclasses.dataclass(frozen=True)
class TaskAccounting:
    """Exact counts for one accelerator task under one execution mode."""

    mode: str
    # data movement (bytes)
    hbm_io_bytes: int = 0          # task input + output activations
    hbm_weight_bytes: int = 0      # parameters streamed from HBM
    hbm_intermediate_bytes: int = 0  # FLEXIBLE_DMA: 4x crossings of operands
    sidebar_bytes: int = 0         # SIDEBAR: low-energy scratchpad crossings
    datapath_bytes: int = 0        # MONOLITHIC: internal pipeline traffic
    # compute (flops / vector-ops)
    mxu_flops: int = 0
    flex_vpu_ops: int = 0          # flexible work done on the host VPU
    flex_hw_ops: int = 0           # flexible work done in dedicated HW (mono)
    flex_elements: int = 0         # total elements through flexible ops
    # protocol events
    launches: int = 0              # accelerator invocations (kernel launches)
    dma_flushes: int = 0           # cache flush+invalidate events
    handshakes: int = 0            # sidebar flag transfers
    host_invocations: int = 0
    flex_stages: int = 0           # number of flexible ops (pipeline stages)
    # pipelined-overlap counters (abstract cycles, 1 cycle = one MXU
    # flop-time; see engine.pipeline_schedule)
    host_busy_cycles: int = 0      # host VPU busy on flexible functions
    acc_busy_cycles: int = 0       # accelerator MXU busy on static ops
    stall_cycles: int = 0          # accelerator serialized behind the host
    overlap_cycles: int = 0        # host work hidden behind acc compute

    def merge(self, other: "TaskAccounting") -> "TaskAccounting":
        assert self.mode == other.mode, (self.mode, other.mode)
        return TaskAccounting(
            self.mode,
            self.hbm_io_bytes + other.hbm_io_bytes,
            self.hbm_weight_bytes + other.hbm_weight_bytes,
            self.hbm_intermediate_bytes + other.hbm_intermediate_bytes,
            self.sidebar_bytes + other.sidebar_bytes,
            self.datapath_bytes + other.datapath_bytes,
            self.mxu_flops + other.mxu_flops,
            self.flex_vpu_ops + other.flex_vpu_ops,
            self.flex_hw_ops + other.flex_hw_ops,
            self.flex_elements + other.flex_elements,
            self.launches + other.launches,
            self.dma_flushes + other.dma_flushes,
            self.handshakes + other.handshakes,
            self.host_invocations + other.host_invocations,
            self.flex_stages + other.flex_stages,
            self.host_busy_cycles + other.host_busy_cycles,
            self.acc_busy_cycles + other.acc_busy_cycles,
            self.stall_cycles + other.stall_cycles,
            self.overlap_cycles + other.overlap_cycles,
        )

    @property
    def total_hbm_bytes(self) -> int:
        return self.hbm_io_bytes + self.hbm_weight_bytes + self.hbm_intermediate_bytes


@dataclasses.dataclass(frozen=True)
class Estimate:
    latency_s: float
    energy_j: float
    # breakdowns (for Fig. 7-style plots)
    e_hbm_j: float
    e_sidebar_j: float
    e_compute_j: float
    e_static_j: float
    t_static_s: float
    t_flexible_s: float
    t_protocol_s: float

    @property
    def edp(self) -> float:
        return self.latency_s * self.energy_j


def estimate(acct: TaskAccounting, chip: ChipSpec = V5E) -> Estimate:
    """Latency/energy/EDP for one task accounting."""
    vpu_rate = chip.peak_flops / VPU_RATE_DIV
    mono_hw_rate = chip.peak_flops / MONO_HW_RATE_DIV

    # --- latency ---------------------------------------------------------
    t_mxu = acct.mxu_flops / chip.peak_flops
    t_stream = (acct.hbm_io_bytes + acct.hbm_weight_bytes) / chip.hbm_bytes_per_s
    t_static = max(t_mxu, t_stream)  # weights/IO stream overlaps the MXU

    # flexible (serial with the accelerator in every mode)
    if acct.mode == "monolithic":
        # in-pipeline stage: 1st op/element rides the pipe at peak/4;
        # the remaining (cost-1) ops at the generic vector rate (Table 3:
        # HW softplus is 21% slower than HW relu, not free).
        extra_ops = max(0, acct.flex_hw_ops - acct.flex_elements)
        t_flex = acct.flex_elements / mono_hw_rate + extra_ops / vpu_rate
    elif acct.mode == "flexible_dma":
        # DRAM-fed host: stalled pipeline + 4 serial HBM crossings
        t_flex = acct.flex_vpu_ops * DMA_HOST_STALL / vpu_rate
        t_flex += acct.hbm_intermediate_bytes / chip.hbm_bytes_per_s
    else:
        # SIDEBAR: accelerator-side traffic replaces its private-buffer
        # writes (free in time); host-side half streams at VMEM-class
        # bandwidth, overlapped with the VPU compute.
        host_bytes = acct.sidebar_bytes / 2
        t_flex = max(acct.flex_vpu_ops / vpu_rate,
                     host_bytes / chip.vpu_bytes_per_s)
        if acct.mode == "sidebar_pipelined" and acct.host_busy_cycles > 0:
            # double buffering hides the overlapped fraction of the host's
            # busy time behind accelerator compute already paid in
            # t_static: only the stalled fraction remains on the critical
            # path (per-stage latency max(host, acc) instead of the sum)
            t_flex *= acct.stall_cycles / acct.host_busy_cycles

    exposed_handshakes = acct.handshakes
    if acct.mode == "sidebar_pipelined":
        # interior ping-pong flags are raised while the other half is
        # busy — only one invoke and one return per stage sit on the
        # critical path regardless of tile count (a degraded tiles=1
        # stage has exactly those two flags, so it gets no discount)
        exposed_handshakes = 2 * acct.flex_stages
    t_protocol = (
        acct.launches * chip.kernel_launch_s
        + acct.dma_flushes * chip.dma_flush_s
        + exposed_handshakes * chip.sidebar_handshake_s
    )
    latency = t_static + t_flex + t_protocol

    # --- energy ------------------------------------------------------------
    e_hbm = acct.total_hbm_bytes * chip.e_hbm_per_byte
    e_sidebar = (acct.sidebar_bytes + acct.datapath_bytes) * chip.e_sidebar_per_byte
    e_compute = (
        acct.mxu_flops * chip.e_mxu_per_flop
        + acct.flex_hw_ops * chip.e_mxu_per_flop   # dedicated HW unit
        + acct.flex_vpu_ops * chip.e_vpu_per_flop  # general-purpose host
    )
    e_static = chip.static_w * latency
    energy = e_hbm + e_sidebar + e_compute + e_static

    return Estimate(
        latency_s=latency,
        energy_j=energy,
        e_hbm_j=e_hbm,
        e_sidebar_j=e_sidebar,
        e_compute_j=e_compute,
        e_static_j=e_static,
        t_static_s=t_static,
        t_flexible_s=t_flex,
        t_protocol_s=t_protocol,
    )


def normalized_edp(estimates: dict[str, Estimate], baseline: str = "monolithic") -> dict[str, float]:
    """Fig. 8: EDP of each design normalized to the monolithic baseline."""
    base = estimates[baseline].edp
    return {k: v.edp / base for k, v in estimates.items()}
