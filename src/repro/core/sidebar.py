"""Software model of the Sidebar buffer and its access protocol (paper §3).

The paper's Sidebar is a physical SRAM with:
  * explicit, compile-time-agreed data placement (§3.1),
  * hardware-enforced mutual exclusion — accelerator and host never access
    it simultaneously; ownership is passed by writing a hardware register,
  * dedicated slots for call arguments (function pointer, data pointers) and
    the invoke/return flags (§3.3),
  * capacity at the L1 level (small; intermediates only).

On TPU the physical realization is a VMEM scratch buffer inside a fused
Pallas kernel (see kernels/sidebar_mlp.py) where the protocol is enforced
by program order. This module models the *protocol itself* so it is
testable and so the engine can account handshakes/bytes exactly:

  * ``SidebarBuffer`` tracks ownership, allocation map, and traffic stats;
    wrong-owner access raises ``SidebarProtocolError`` (the software
    analogue of the hardware mutex).
  * ``SidebarCall`` is the argument block the accelerator writes before
    raising the invoke flag: function-table key + region handles.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterator

import numpy as np


class Owner(enum.Enum):
    ACCELERATOR = "accelerator"
    HOST = "host"


class SidebarProtocolError(RuntimeError):
    """Raised on any violation of the ownership / placement protocol."""


@dataclasses.dataclass(frozen=True)
class Region:
    """A compile-time-agreed placement inside the sidebar."""

    name: str
    offset: int
    nbytes: int

    @property
    def end(self) -> int:
        return self.offset + self.nbytes


@dataclasses.dataclass(frozen=True)
class SidebarCall:
    """The argument block of one host invocation (paper §3.3)."""

    function: str          # function-table key ("function pointer")
    in_regions: tuple[str, ...]
    out_regions: tuple[str, ...]
    n_elements: int        # payload size (drives VPU cost)


@dataclasses.dataclass
class SidebarStats:
    """Traffic/protocol counters consumed by the energy model."""

    bytes_written_acc: int = 0   # accelerator -> sidebar
    bytes_read_acc: int = 0     # sidebar -> accelerator
    bytes_written_host: int = 0  # host -> sidebar
    bytes_read_host: int = 0    # sidebar -> host
    handshakes: int = 0          # ownership transfers (flag writes)
    host_invocations: int = 0    # complete invoke->return cycles
    peak_bytes: int = 0          # high-water allocation mark

    @property
    def total_bytes(self) -> int:
        return (
            self.bytes_written_acc
            + self.bytes_read_acc
            + self.bytes_written_host
            + self.bytes_read_host
        )

    def merge(self, other: "SidebarStats") -> "SidebarStats":
        return SidebarStats(
            self.bytes_written_acc + other.bytes_written_acc,
            self.bytes_read_acc + other.bytes_read_acc,
            self.bytes_written_host + other.bytes_written_host,
            self.bytes_read_host + other.bytes_read_host,
            self.handshakes + other.handshakes,
            self.host_invocations + other.host_invocations,
            max(self.peak_bytes, other.peak_bytes),
        )


# Reserved control area at the head of every sidebar: invoke flag, return
# flag, function pointer slot, and an argument block (paper §3.3 — "a
# specific set of Sidebar locations").
CONTROL_BYTES = 256


class SidebarBuffer:
    """Ownership-checked, capacity-checked sidebar with a bump allocator.

    ``capacity`` defaults to a VMEM-scale budget; kernels using the real
    VMEM scratch must keep their working set within this (the dry-run
    checks kernel BlockSpec footprints against the same constant).
    """

    def __init__(self, capacity: int, *, name: str = "sidebar") -> None:
        if capacity <= CONTROL_BYTES:
            raise ValueError("sidebar too small for its control area")
        self.name = name
        self.capacity = int(capacity)
        self.owner = Owner.ACCELERATOR
        self.stats = SidebarStats()
        self._regions: dict[str, Region] = {}
        self._cursor = CONTROL_BYTES
        self._data: dict[str, np.ndarray] = {}

    # -- placement (compile-time agreement, §3.1) -------------------------
    def allocate(self, name: str, nbytes: int) -> Region:
        if name in self._regions:
            raise SidebarProtocolError(f"region {name!r} already placed")
        nbytes = int(nbytes)
        aligned = (self._cursor + 127) // 128 * 128  # 128B lane alignment
        if aligned + nbytes > self.capacity:
            raise SidebarProtocolError(
                f"sidebar {self.name!r} overflow: need {nbytes} B at offset "
                f"{aligned}, capacity {self.capacity} B — intermediates must "
                "be tiled to fit (see kernels/sidebar_mlp.py BlockSpec)"
            )
        region = Region(name, aligned, nbytes)
        self._regions[name] = region
        self._cursor = region.end
        self.stats.peak_bytes = max(self.stats.peak_bytes, self._cursor)
        return region

    def free_all(self) -> None:
        """Reset placements between accelerator tasks (intermediates only —
        the sidebar never persists application state, §3.4)."""
        self._regions.clear()
        self._data.clear()
        self._cursor = CONTROL_BYTES

    def region(self, name: str) -> Region:
        try:
            return self._regions[name]
        except KeyError:
            raise SidebarProtocolError(f"no region {name!r} placed") from None

    # -- ownership (hardware mutex, §3.1) ---------------------------------
    def _check_owner(self, who: Owner) -> None:
        if self.owner is not who:
            raise SidebarProtocolError(
                f"{who.value} accessed sidebar owned by {self.owner.value}; "
                "ownership must be passed via the flag register first"
            )

    def pass_ownership(self, to: Owner) -> None:
        if to is self.owner:
            raise SidebarProtocolError(f"ownership already with {to.value}")
        self.owner = to
        self.stats.handshakes += 1

    # -- data movement ----------------------------------------------------
    def write(self, who: Owner, region_name: str, array: np.ndarray) -> None:
        self._check_owner(who)
        region = self.region(region_name)
        nbytes = int(array.nbytes)
        if nbytes > region.nbytes:
            raise SidebarProtocolError(
                f"write of {nbytes} B exceeds region {region_name!r} "
                f"({region.nbytes} B)"
            )
        self._data[region_name] = np.asarray(array)
        if who is Owner.ACCELERATOR:
            self.stats.bytes_written_acc += nbytes
        else:
            self.stats.bytes_written_host += nbytes

    def read(self, who: Owner, region_name: str) -> np.ndarray:
        self._check_owner(who)
        region = self.region(region_name)
        if region_name not in self._data:
            raise SidebarProtocolError(f"region {region_name!r} never written")
        arr = self._data[region_name]
        if who is Owner.ACCELERATOR:
            self.stats.bytes_read_acc += int(arr.nbytes)
        else:
            self.stats.bytes_read_host += int(arr.nbytes)
        return arr

    # -- full invocation cycle (paper §3.3) --------------------------------
    def invoke_host(self, call: SidebarCall, table, dtype=np.float32) -> None:
        """Run one accelerator->host->accelerator cycle through the sidebar.

        The accelerator must own the buffer and have written ``in_regions``.
        This models: write args -> raise flag (pass to host) -> host reads,
        computes via the function table, writes results -> lower flag (pass
        back to accelerator).
        """
        self._check_owner(Owner.ACCELERATOR)
        entry = table[call.function]
        self.pass_ownership(Owner.HOST)
        inputs = [self.read(Owner.HOST, r) for r in call.in_regions]
        out = np.asarray(entry.fn(*[i for i in inputs])).astype(dtype)
        outs = [out] if len(call.out_regions) == 1 else list(out)
        for region_name, arr in zip(call.out_regions, outs):
            self.write(Owner.HOST, region_name, arr)
        self.pass_ownership(Owner.ACCELERATOR)
        self.stats.host_invocations += 1

    # -- introspection ------------------------------------------------------
    def utilization(self) -> float:
        return self._cursor / self.capacity

    def regions(self) -> Iterator[Region]:
        return iter(self._regions.values())


def required_capacity(shape: tuple[int, ...], itemsize: int, copies: int = 1) -> int:
    """Capacity needed to stage an intermediate of ``shape``: control area
    plus ``copies`` regions, each rounded up to the 128 B lane alignment
    the allocator enforces."""
    nbytes = int(math.prod(shape)) * itemsize
    aligned = (nbytes + 127) // 128 * 128
    return CONTROL_BYTES + copies * aligned
