"""Software model of the Sidebar buffer and its access protocol (paper §3).

The paper's Sidebar is a physical SRAM with:
  * explicit, compile-time-agreed data placement (§3.1),
  * hardware-enforced mutual exclusion — accelerator and host never access
    the same location simultaneously; ownership is passed by writing a
    hardware register,
  * dedicated slots for call arguments (function pointer, data pointers) and
    the invoke/return flags (§3.3),
  * capacity at the L1 level (small; intermediates only).

On TPU the physical realization is a VMEM scratch buffer inside a fused
Pallas kernel (see kernels/sidebar_mlp.py) where the protocol is enforced
by program order. This module models the *protocol itself* so it is
testable and so the engine can account handshakes/bytes exactly:

  * ``SidebarBuffer`` tracks ownership, allocation map, and traffic stats;
    wrong-owner access raises ``SidebarProtocolError`` (the software
    analogue of the hardware mutex).
  * ``SidebarCall`` is the argument block the accelerator writes before
    raising the invoke flag: function-table key + region handles.

Pipelined protocol (ExecutionMode.SIDEBAR_PIPELINED)
----------------------------------------------------

Ownership is tracked **per region**, not per buffer: the mutual-exclusion
guarantee the hardware needs is per-location, so the host may own one set
of regions (one *slot*) while the accelerator concurrently fills another.
``SidebarRing`` packages the T-deep buffering discipline on top of that:
``depth`` slots, each an (operand, result) region pair with a four-state
lifecycle

    free -> filled -> at_host -> returned -> free
            (acc wrote   (invoke     (return    (acc read result,
             operand)     flag)       flag)      slot released)

Tile ``t`` maps onto slot ``t % depth``; acquiring a slot that has not
completed its previous cycle raises ``SidebarProtocolError`` ("reuse
before release") — the software analogue of clobbering a buffer the host
is still reading. The timeline the engine models at depth 2 (host
computes flexible op *i* tile t on slot A while the accelerator works
tile t+1 / the next static chain's prologue on slot B):

    acc : fill A | fill B         | prologue(A.res) | prologue(B.res) ...
    host:        | f(A) -> A.res  | f(B) -> B.res   |
    flag:   A->h   B->h  A->acc     B->acc

Deeper rings let the accelerator run up to ``depth`` tiles ahead of the
host, so a larger fraction of the host's busy time hides behind the
producer epilogue / consumer prologue (see ``engine.StageTiming``).
``PingPongPair`` survives as the fixed ``depth=2`` special case.

Regions are recycled through a first-fit **free list** (``free``), so a
task with many flexible ops reuses the same sidebar area without the
whole-buffer ``free_all`` teardown between ops.

``SidebarStats`` carries the overlap counters the energy model consumes:
``host_busy_cycles`` / ``acc_busy_cycles`` (abstract cycles, 1 cycle = one
MXU flop-time; host VPU work is scaled by the VPU/MXU rate ratio),
``overlap_cycles`` (both sides busy) and ``stall_cycles`` (accelerator
idle, polling the return flag).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterator, Sequence

import numpy as np


class Owner(enum.Enum):
    ACCELERATOR = "accelerator"
    HOST = "host"


class SidebarProtocolError(RuntimeError):
    """Raised on any violation of the ownership / placement protocol."""


@dataclasses.dataclass(frozen=True)
class Region:
    """A compile-time-agreed placement inside the sidebar."""

    name: str
    offset: int
    nbytes: int

    @property
    def end(self) -> int:
        return self.offset + self.nbytes


@dataclasses.dataclass(frozen=True)
class SidebarCall:
    """The argument block of one host invocation (paper §3.3).

    ``chain`` carries the *fused* tail of a run of consecutive flexible
    ops: the host applies ``function`` to the operand regions, then each
    chained function to the running result, and only the final result is
    written back — one ownership round-trip covers the whole run, and the
    inter-op intermediates never re-cross the sidebar.
    """

    function: str          # function-table key ("function pointer")
    in_regions: tuple[str, ...]
    out_regions: tuple[str, ...]
    n_elements: int        # payload size (drives VPU cost)
    chain: tuple[str, ...] = ()  # fused follow-on function-table keys

    @property
    def functions(self) -> tuple[str, ...]:
        return (self.function, *self.chain)


@dataclasses.dataclass
class SidebarStats:
    """Traffic/protocol counters consumed by the energy model."""

    bytes_written_acc: int = 0   # accelerator -> sidebar
    bytes_read_acc: int = 0     # sidebar -> accelerator
    bytes_written_host: int = 0  # host -> sidebar
    bytes_read_host: int = 0    # sidebar -> host
    handshakes: int = 0          # ownership transfers (flag writes)
    host_invocations: int = 0    # complete invoke->return cycles
    peak_bytes: int = 0          # high-water allocation mark
    # Overlap counters (abstract cycles; 1 cycle = one MXU flop-time).
    host_busy_cycles: int = 0    # host VPU busy on flexible functions
    acc_busy_cycles: int = 0     # accelerator MXU busy on static ops
    overlap_cycles: int = 0      # both sides busy simultaneously
    stall_cycles: int = 0        # accelerator idle, polling a flag

    @property
    def total_bytes(self) -> int:
        return (
            self.bytes_written_acc
            + self.bytes_read_acc
            + self.bytes_written_host
            + self.bytes_read_host
        )

    def merge(self, other: "SidebarStats") -> "SidebarStats":
        return SidebarStats(
            bytes_written_acc=self.bytes_written_acc + other.bytes_written_acc,
            bytes_read_acc=self.bytes_read_acc + other.bytes_read_acc,
            bytes_written_host=self.bytes_written_host + other.bytes_written_host,
            bytes_read_host=self.bytes_read_host + other.bytes_read_host,
            handshakes=self.handshakes + other.handshakes,
            host_invocations=self.host_invocations + other.host_invocations,
            peak_bytes=max(self.peak_bytes, other.peak_bytes),
            host_busy_cycles=self.host_busy_cycles + other.host_busy_cycles,
            acc_busy_cycles=self.acc_busy_cycles + other.acc_busy_cycles,
            overlap_cycles=self.overlap_cycles + other.overlap_cycles,
            stall_cycles=self.stall_cycles + other.stall_cycles,
        )


# Reserved control area at the head of every sidebar: invoke flag, return
# flag, function pointer slot, and an argument block (paper §3.3 — "a
# specific set of Sidebar locations").
CONTROL_BYTES = 256

_ALIGN = 128  # TPU lane alignment for every placement


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


class SidebarBuffer:
    """Ownership-checked, capacity-checked sidebar with a recycling
    (free-list + bump) allocator and per-region ownership.

    ``capacity`` defaults to a VMEM-scale budget; kernels using the real
    VMEM scratch must keep their working set within this (the dry-run
    checks kernel BlockSpec footprints against the same constant).

    Ownership model: ``self.owner`` is the buffer-level default — newly
    placed regions belong to it, and ``pass_ownership`` (the single
    buffer-wide flag of the serial protocol) moves the buffer *and* every
    region. ``pass_region`` is the pipelined refinement: one flag write
    transfers a named set of regions (a ping-pong half) while the rest of
    the sidebar stays with its current owner.
    """

    def __init__(self, capacity: int, *, name: str = "sidebar") -> None:
        if capacity <= CONTROL_BYTES:
            raise ValueError("sidebar too small for its control area")
        self.name = name
        self.capacity = int(capacity)
        self.owner = Owner.ACCELERATOR
        self.stats = SidebarStats()
        self._regions: dict[str, Region] = {}
        self._owners: dict[str, Owner] = {}
        self._cursor = CONTROL_BYTES
        self._free: list[tuple[int, int]] = []  # (offset, span) 128B-aligned
        self._data: dict[str, np.ndarray] = {}

    # -- placement (compile-time agreement, §3.1) -------------------------
    def allocate(self, name: str, nbytes: int) -> Region:
        if name in self._regions:
            raise SidebarProtocolError(f"region {name!r} already placed")
        nbytes = int(nbytes)
        span = _align(max(nbytes, 1))
        # first-fit from the free list (recycled placements)
        for idx, (off, sz) in enumerate(self._free):
            if sz >= span:
                if sz == span:
                    self._free.pop(idx)
                else:
                    self._free[idx] = (off + span, sz - span)
                region = Region(name, off, nbytes)
                self._regions[name] = region
                self._owners[name] = self.owner
                return region
        # bump allocation
        aligned = _align(self._cursor)
        if aligned + nbytes > self.capacity:
            raise SidebarProtocolError(
                f"sidebar {self.name!r} overflow: need {nbytes} B at offset "
                f"{aligned}, capacity {self.capacity} B — intermediates must "
                "be tiled to fit (see kernels/sidebar_mlp.py BlockSpec)"
            )
        region = Region(name, aligned, nbytes)
        self._regions[name] = region
        self._owners[name] = self.owner
        self._cursor = aligned + span
        self.stats.peak_bytes = max(self.stats.peak_bytes, region.end)
        return region

    def free(self, name: str) -> None:
        """Return one placement to the free list (recycled, unlike
        ``free_all`` which tears the whole map down between tasks)."""
        region = self.region(name)
        del self._regions[name]
        self._owners.pop(name, None)
        self._data.pop(name, None)
        span = (region.offset, _align(max(region.nbytes, 1)))
        self._free.append(span)
        self._free.sort()
        # coalesce adjacent spans
        merged: list[tuple[int, int]] = []
        for off, sz in self._free:
            if merged and merged[-1][0] + merged[-1][1] == off:
                merged[-1] = (merged[-1][0], merged[-1][1] + sz)
            else:
                merged.append((off, sz))
        # reclaim a free tail into the bump cursor (defragments the common
        # alternating allocate/free pattern completely)
        if merged and merged[-1][0] + merged[-1][1] >= self._cursor:
            self._cursor = merged.pop()[0]
        self._free = merged

    def free_all(self) -> None:
        """Reset placements between accelerator tasks (intermediates only —
        the sidebar never persists application state, §3.4)."""
        self._regions.clear()
        self._owners.clear()
        self._data.clear()
        self._free.clear()
        self._cursor = CONTROL_BYTES

    def region(self, name: str) -> Region:
        try:
            return self._regions[name]
        except KeyError:
            raise SidebarProtocolError(f"no region {name!r} placed") from None

    # -- ownership (hardware mutex, §3.1) ---------------------------------
    def region_owner(self, name: str) -> Owner:
        self.region(name)  # existence check
        return self._owners[name]

    def _check_owner(self, who: Owner, region_name: str) -> None:
        owner = self.region_owner(region_name)
        if owner is not who:
            raise SidebarProtocolError(
                f"{who.value} accessed region {region_name!r} owned by "
                f"{owner.value}; ownership must be passed via the flag "
                "register first"
            )

    def pass_ownership(self, to: Owner) -> None:
        """Serial protocol: one flag transfers the whole sidebar."""
        if to is self.owner:
            raise SidebarProtocolError(f"ownership already with {to.value}")
        self.owner = to
        for name in self._owners:
            self._owners[name] = to
        self.stats.handshakes += 1

    def pass_region(self, names: Sequence[str] | str, to: Owner) -> None:
        """Pipelined protocol: one flag write transfers a set of regions
        (a ping-pong half) while the rest of the sidebar stays put."""
        if isinstance(names, str):
            names = (names,)
        for name in names:
            if self.region_owner(name) is to:
                raise SidebarProtocolError(
                    f"region {name!r} ownership already with {to.value}"
                )
        for name in names:
            self._owners[name] = to
        self.stats.handshakes += 1

    # -- data movement ----------------------------------------------------
    def write(self, who: Owner, region_name: str, array: np.ndarray) -> None:
        self._check_owner(who, region_name)
        region = self.region(region_name)
        nbytes = int(array.nbytes)
        if nbytes > region.nbytes:
            raise SidebarProtocolError(
                f"write of {nbytes} B exceeds region {region_name!r} "
                f"({region.nbytes} B)"
            )
        self._data[region_name] = np.asarray(array)
        if who is Owner.ACCELERATOR:
            self.stats.bytes_written_acc += nbytes
        else:
            self.stats.bytes_written_host += nbytes

    def read(self, who: Owner, region_name: str) -> np.ndarray:
        self._check_owner(who, region_name)
        region = self.region(region_name)
        if region_name not in self._data:
            raise SidebarProtocolError(f"region {region_name!r} never written")
        arr = self._data[region_name]
        if who is Owner.ACCELERATOR:
            self.stats.bytes_read_acc += int(arr.nbytes)
        else:
            self.stats.bytes_read_host += int(arr.nbytes)
        return arr

    # -- host-side computation (paper §3.3) --------------------------------
    def host_call(self, call: SidebarCall, table, dtype=np.float32) -> None:
        """Host side of one invocation: read host-owned operand regions,
        compute via the function table, write host-owned result regions.
        Assumes the regions were already passed to the host (the pipelined
        path passes a ping-pong half; ``invoke_host`` passes the buffer)."""
        entry = table[call.function]
        inputs = [self.read(Owner.HOST, r) for r in call.in_regions]
        out = np.asarray(entry.fn(*[i for i in inputs]))
        for fused in call.chain:  # fused run: stays in host registers
            out = np.asarray(table[fused].fn(out))
        out = out.astype(dtype)
        outs = [out] if len(call.out_regions) == 1 else list(out)
        for region_name, arr in zip(call.out_regions, outs):
            self.write(Owner.HOST, region_name, arr)
        self.stats.host_invocations += 1

    def invoke_host(self, call: SidebarCall, table, dtype=np.float32) -> None:
        """Run one serial accelerator->host->accelerator cycle.

        The accelerator must own the buffer and have written ``in_regions``.
        This models: write args -> raise flag (pass to host) -> host reads,
        computes via the function table, writes results -> lower flag (pass
        back to accelerator). The accelerator stalls for the whole cycle —
        the pipelined path (``SidebarRing``) is the overlapped variant.
        """
        if self.owner is not Owner.ACCELERATOR:
            raise SidebarProtocolError(
                f"accelerator accessed sidebar owned by {self.owner.value}; "
                "ownership must be passed via the flag register first"
            )
        self.pass_ownership(Owner.HOST)
        self.host_call(call, table, dtype)
        self.pass_ownership(Owner.ACCELERATOR)

    # -- introspection ------------------------------------------------------
    def utilization(self) -> float:
        return self._cursor / self.capacity

    def regions(self) -> Iterator[Region]:
        return iter(self._regions.values())


# ---------------------------------------------------------------------------
# T-deep ring buffering (the pipelined protocol's region discipline).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RingSlot:
    """One slot of a sidebar ring: an (operand, result) region pair plus
    the lifecycle state the protocol enforces."""

    label: str
    operand: Region
    result: Region
    state: str = "free"  # free -> filled -> at_host -> returned -> free

    @property
    def region_names(self) -> tuple[str, str]:
        return (self.operand.name, self.result.name)


# Back-compat alias: PR 1 called a depth-2 slot a "half".
PingPongHalf = RingSlot


class SidebarRing:
    """``depth`` sidebar slots traded between accelerator and host.

    The accelerator fills slot ``t % depth`` with tile ``t`` while the
    host computes on earlier slots — per-region ownership makes the
    concurrent access legal; this class makes the *ordering* discipline
    checkable: a slot must complete free -> filled -> at_host ->
    returned -> free before it can be acquired again ("reuse before
    release" raises). ``depth=2`` is the classic ping-pong pair; deeper
    rings let the accelerator run further ahead of the host.
    """

    def __init__(self, sb: SidebarBuffer, name: str,
                 operand_nbytes: int, result_nbytes: int,
                 depth: int = 2) -> None:
        if depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {depth}")
        self._sb = sb
        self.name = name
        self.depth = depth
        self.slots = [
            RingSlot(
                f"slot{k}",
                sb.allocate(f"{name}.slot{k}.operand", operand_nbytes),
                sb.allocate(f"{name}.slot{k}.result", result_nbytes),
            )
            for k in range(depth)
        ]

    def slot(self, tile_index: int) -> RingSlot:
        return self.slots[tile_index % self.depth]

    # PR-1 vocabulary, kept so depth-2 call sites read naturally.
    half = slot

    @property
    def halves(self) -> list[RingSlot]:
        return self.slots

    def acquire(self, tile_index: int) -> RingSlot:
        s = self.slot(tile_index)
        if s.state != "free":
            raise SidebarProtocolError(
                f"ring slot {self.name}.{s.label} reused before release "
                f"(state={s.state!r}); the tile {self.depth} back must have "
                "its result read back and the slot released first"
            )
        s.state = "filled"
        return s

    def to_host(self, s: RingSlot) -> None:
        if s.state != "filled":
            raise SidebarProtocolError(
                f"slot {self.name}.{s.label} invoked in state {s.state!r} "
                "(operand not filled)"
            )
        self._sb.pass_region(s.region_names, Owner.HOST)
        s.state = "at_host"

    def to_accelerator(self, s: RingSlot) -> None:
        if s.state != "at_host":
            raise SidebarProtocolError(
                f"slot {self.name}.{s.label} returned in state {s.state!r}"
            )
        self._sb.pass_region(s.region_names, Owner.ACCELERATOR)
        s.state = "returned"

    def release(self, s: RingSlot) -> None:
        if s.state != "returned":
            raise SidebarProtocolError(
                f"slot {self.name}.{s.label} released in state {s.state!r} "
                "(result not returned to the accelerator)"
            )
        s.state = "free"

    def free(self) -> None:
        """Return every slot's placements to the buffer's free list."""
        for s in self.slots:
            if s.state not in ("free",):
                raise SidebarProtocolError(
                    f"slot {self.name}.{s.label} freed mid-flight "
                    f"(state={s.state!r})"
                )
            self._sb.free(s.operand.name)
            self._sb.free(s.result.name)


class PingPongPair(SidebarRing):
    """The fixed depth-2 ring of PR 1 — kept as the named special case."""

    def __init__(self, sb: SidebarBuffer, name: str,
                 operand_nbytes: int, result_nbytes: int) -> None:
        super().__init__(sb, name, operand_nbytes, result_nbytes, depth=2)


def required_capacity(shape: tuple[int, ...], itemsize: int, copies: int = 1) -> int:
    """Capacity needed to stage an intermediate of ``shape``: control area
    plus ``copies`` regions, each rounded up to the 128 B lane alignment
    the allocator enforces."""
    nbytes = int(math.prod(shape)) * itemsize
    return CONTROL_BYTES + copies * _align(nbytes)


def pipelined_capacity(
    operand_shape: tuple[int, ...],
    out_shape: tuple[int, ...],
    itemsize: int,
    tiles: int = 2,
    depth: int | None = None,
) -> int:
    """Capacity for one ring-buffered flexible op: ``depth`` slots, each an
    (operand-tile, result-tile) pair, tiles split along the leading axis.
    ``depth`` defaults to ``tiles`` (every in-flight tile gets a slot)."""
    depth = tiles if depth is None else depth

    def tile_bytes(shape: tuple[int, ...]) -> int:
        if not shape:
            return itemsize
        lead = -(-shape[0] // tiles)  # ceil: the larger tile
        return int(lead * math.prod(shape[1:])) * itemsize

    return CONTROL_BYTES + depth * (
        _align(tile_bytes(operand_shape)) + _align(tile_bytes(out_shape))
    )


class _SpillState(enum.Enum):
    STAGED = "staged"    # handle reserved, payload being written
    ACTIVE = "active"    # payload committed, restorable


class SidebarSpillRegion:
    """Host-side spill scratchpad for preempted serving requests.

    The serving layer's preemption path needs somewhere to put a
    victim's KV blocks while it waits to resume — this is the sidebar
    discipline once more, pointed the other way: instead of the host
    reading accelerator intermediates out of a shared scratchpad, the
    scheduler parks accelerator state (block payloads, as host numpy)
    in a host region with the same explicit ownership lifecycle the
    buffer above enforces per placement:

        stage(handle) -> commit(handle, payload) -> fetch -> release

    Any out-of-order transition — commit without stage, fetch of an
    uncommitted handle, staging a live handle twice — raises
    ``SidebarProtocolError``, exactly like reuse-before-release on a
    ``SidebarBuffer`` region. ``capacity_bytes`` bounds the region
    (None = unbounded); byte accounting mirrors ``SidebarStats``'
    high-water mark so the overload bench can report spill pressure.
    """

    def __init__(self, capacity_bytes: int | None = None) -> None:
        self.capacity_bytes = capacity_bytes
        self._entries: dict[int, tuple[_SpillState, object, int]] = {}
        self.in_use_bytes = 0
        self.peak_bytes = 0
        self.spills = 0      # commits
        self.restores = 0    # fetches

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, handle: int) -> bool:
        return handle in self._entries

    def stage(self, handle: int) -> None:
        """Reserve a handle (free -> staged)."""
        if handle in self._entries:
            st, _, _ = self._entries[handle]
            raise SidebarProtocolError(
                f"spill handle {handle} already {st.value} "
                "(stage before the previous owner released)"
            )
        self._entries[handle] = (_SpillState.STAGED, None, 0)

    def commit(self, handle: int, payload, nbytes: int) -> None:
        """staged -> active: the spill copy is complete and restorable."""
        entry = self._entries.get(handle)
        if entry is None or entry[0] is not _SpillState.STAGED:
            raise SidebarProtocolError(
                f"commit on spill handle {handle} "
                f"({'unstaged' if entry is None else entry[0].value})"
            )
        nbytes = int(nbytes)
        if (self.capacity_bytes is not None
                and self.in_use_bytes + nbytes > self.capacity_bytes):
            raise SidebarProtocolError(
                f"spill region over capacity: {self.in_use_bytes} + "
                f"{nbytes} > {self.capacity_bytes} bytes"
            )
        self._entries[handle] = (_SpillState.ACTIVE, payload, nbytes)
        self.in_use_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.in_use_bytes)
        self.spills += 1

    def fetch(self, handle: int):
        """Read an active entry's payload (restore path; non-consuming —
        the caller releases only once the restore has succeeded)."""
        entry = self._entries.get(handle)
        if entry is None or entry[0] is not _SpillState.ACTIVE:
            raise SidebarProtocolError(
                f"fetch on spill handle {handle} "
                f"({'unknown' if entry is None else entry[0].value})"
            )
        self.restores += 1
        return entry[1]

    def release(self, handle: int) -> None:
        """Drop an entry (staged or active) and reclaim its bytes."""
        entry = self._entries.pop(handle, None)
        if entry is None:
            raise SidebarProtocolError(
                f"release on unknown spill handle {handle}"
            )
        self.in_use_bytes -= entry[2]
