"""Hardware constants for the analytical latency/energy model and roofline.

Target hardware is a TPU v5e-class chip (the runtime here is CPU; the chip
is the *model*). Every constant is either given by the task spec or carries
a public citation so the energy model is auditable.

Roofline constants (task spec):
  * 197 TFLOP/s bf16 per chip
  * 819 GB/s HBM bandwidth per chip
  * ~50 GB/s per ICI link (we assume 3 usable links per chip on a 2-D/3-D
    torus slice and fold that into ``ICI_BYTES_PER_S_PER_CHIP``)

Energy constants (per byte / per flop):
  * HBM access energy: ~3.9 pJ/bit for HBM2-class stacks
    [O'Connor et al., "Fine-Grained DRAM", MICRO 2017; Micron HBM2 data]
    => 31.2 pJ/B.
  * Near-compute SRAM (VMEM-class, the "Sidebar"): large banked SRAM access
    is ~1-2 orders of magnitude cheaper than DRAM [Horowitz, ISSCC 2014:
    8KB SRAM 64b access ~10pJ => ~1.25 pJ/B; scaled bank-local]. We use
    1.2 pJ/B, a ~26x advantage over HBM — deliberately conservative vs the
    paper's L1-level scratchpad (which would be nearer 100x).
  * MXU bf16 MAC: ~0.3 pJ/flop [Horowitz ISSCC'14 fp16 mult 0.34 pJ scaled].
  * VPU vector op: ~1.5 pJ/flop (general-purpose lane, higher control
    overhead — this is the "host CPU computes the activation" cost).

Latency protocol constants:
  * Kernel-launch / DMA-descriptor overhead: ~2 us per launch (typical
    accelerator dispatch cost; the paper's DMA additionally pays cache
    flush+invalidate which we model as ``DMA_FLUSH_S``).
  * Sidebar handshake: flag write + poll observe, VMEM-latency scale —
    tens of ns. We use 100 ns per handshake (two per flexible call:
    invoke + return), faithful to the paper's "quick communication
    invisible to the rest of the memory system".
"""

from __future__ import annotations

import dataclasses

# ----------------------------------------------------------------------------
# Roofline (task-spec) constants — per chip.
# ----------------------------------------------------------------------------
PEAK_FLOPS_BF16: float = 197e12          # FLOP/s
HBM_BYTES_PER_S: float = 819e9           # B/s
ICI_BYTES_PER_S_PER_LINK: float = 50e9   # B/s per link
ICI_LINKS_PER_CHIP: int = 3              # usable links on a torus slice
ICI_BYTES_PER_S_PER_CHIP: float = ICI_BYTES_PER_S_PER_LINK * ICI_LINKS_PER_CHIP
HBM_BYTES_PER_CHIP: int = 16 * 1024**3   # 16 GiB (v5e)
VMEM_BYTES_PER_CHIP: int = 128 * 1024**2 # 128 MiB VMEM

# ----------------------------------------------------------------------------
# Energy model constants.
# ----------------------------------------------------------------------------
E_HBM_PER_BYTE: float = 31.2e-12     # J/B   (HBM2 ~3.9 pJ/bit)
E_SIDEBAR_PER_BYTE: float = 1.2e-12  # J/B   (VMEM-class banked SRAM)
E_MXU_PER_FLOP: float = 0.3e-12     # J/flop (systolic bf16 MAC)
E_VPU_PER_FLOP: float = 1.5e-12     # J/flop (general vector lane = "host")
E_STATIC_W: float = 75.0             # static+leakage power proxy (W/chip)

# ----------------------------------------------------------------------------
# Protocol latency constants.
# ----------------------------------------------------------------------------
KERNEL_LAUNCH_S: float = 2.0e-6      # per accelerator invocation (DMA descr.)
DMA_FLUSH_S: float = 3.0e-6          # cache flush + invalidate before DMA
                                     # (paper §5.3.1; zero for Sidebar mode)
SIDEBAR_HANDSHAKE_S: float = 20e-9   # flag write + poll observe (one way) —
                                     # L1-latency scale, paper §3
VPU_BYTES_PER_S: float = 22e12       # host<->sidebar streaming bandwidth
                                     # (VMEM-class banked SRAM, full rate —
                                     # "with prefetching reach cache level
                                     # latency", paper §5.2.2)

# VPU cost (vector-ops per element) of each flexible function. This encodes
# the paper's observation that softplus is far more expensive than relu.
FLEXIBLE_OP_COST: dict[str, float] = {
    "identity": 0.0,
    "heaviside": 1.0,
    "relu": 1.0,
    "leaky_relu": 2.0,
    "squared_relu": 2.0,
    "abs": 1.0,
    "elu": 8.0,
    "silu": 11.0,
    "sigmoid": 10.0,
    "tanh": 12.0,
    "gelu": 14.0,
    "softplus": 15.0,
    "softmax": 12.0,
    "rmsnorm": 6.0,
    "layernorm": 8.0,
    "exp_decay": 10.0,   # RWKV6 data-dependent decay exp(-exp(w))
    "router_topk": 16.0, # MoE router softmax + top-k select
    "max_pool": 1.0,
    "avg_pool": 1.0,
    "qk_rmsnorm": 6.0,
}
DEFAULT_FLEXIBLE_OP_COST: float = 8.0


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """A parameterizable chip model (defaults = TPU v5e-class target)."""

    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bytes_per_s: float = HBM_BYTES_PER_S
    ici_bytes_per_s: float = ICI_BYTES_PER_S_PER_CHIP
    hbm_bytes: int = HBM_BYTES_PER_CHIP
    vmem_bytes: int = VMEM_BYTES_PER_CHIP
    e_hbm_per_byte: float = E_HBM_PER_BYTE
    e_sidebar_per_byte: float = E_SIDEBAR_PER_BYTE
    e_mxu_per_flop: float = E_MXU_PER_FLOP
    e_vpu_per_flop: float = E_VPU_PER_FLOP
    static_w: float = E_STATIC_W
    kernel_launch_s: float = KERNEL_LAUNCH_S
    dma_flush_s: float = DMA_FLUSH_S
    sidebar_handshake_s: float = SIDEBAR_HANDSHAKE_S
    vpu_bytes_per_s: float = VPU_BYTES_PER_S


V5E = ChipSpec()
