"""The Sidebar execution engine.

Runs a ``LayerGraph`` (alternating static/flexible ops) under each of the
paper's three designs plus the double-buffered SIDEBAR_PIPELINED
refinement, producing *numerically identical results* (the math is
mode-invariant — tests assert this) while differing in:

  * how many accelerator launches happen,
  * where intermediates live (HBM round-trip vs sidebar scratch vs internal
    datapath),
  * who computes the flexible functions (host VPU vs dedicated HW),
  * which protocol events fire (DMA flush vs sidebar handshake).

Two layers of fidelity:

  1. ``run(...)`` — actually executes the graph in JAX, routing every
     flexible call through the mode's mechanism. In SIDEBAR mode the
     intermediate passes through a ``SidebarBuffer`` software model which
     enforces the ownership protocol and meters traffic. In MONOLITHIC
     mode the whole task is built into one compiled callable whose
     flexible functions were *frozen at build time* (hot-swapping the
     function table afterwards must not — and does not — change it).

  2. ``account(...)`` — pure analytic counts (no execution) feeding
     ``core.energy.estimate``. The dry-run/roofline path uses this at
     production scale where numeric execution is impossible on CPU.

Pipelined timeline (SIDEBAR_PIPELINED, per flexible stage, T=2 tiles):

    acc : write A.op | write B.op      | read A.res+prologue | read B.res
    host:            | f(A.op)->A.res  | f(B.op)->B.res      |
                  ^invoke A         ^ret A / invoke B     ^ret B

  At ring depth T the operand splits into T tiles and the accelerator
  runs up to T-1 tiles ahead of the host, so all but ``host/T`` of the
  host's busy time can hide behind the producer epilogue / consumer
  prologue (each adjacent static op still donates at most half its
  flops). Runs of consecutive flexible ops fuse into ONE host invocation
  per tile: the inter-op intermediate stays in host registers, saving
  both the per-op ownership round-trips and the extra sidebar crossings.
  ``pipeline_schedule`` is the single source of truth for those
  counters, shared by ``run`` and ``account`` at every depth.

The fused TPU fast path for the hot pattern (matmul → activation → matmul)
is ``kernels/sidebar_mlp.py``; the engine is the general mechanism and the
place where mode semantics are defined.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import VPU_RATE_DIV, TaskAccounting
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.core.modes import (
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    LayerPlan,
    StaticOp,
    flexible_runs,
    segment_static_chains,
)
from repro.core.sidebar import (
    Owner,
    SidebarBuffer,
    SidebarCall,
    SidebarRing,
    pipelined_capacity,
    required_capacity,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Pipeline schedule: the shared overlap model of SIDEBAR_PIPELINED.
#
# Abstract cycle unit: one MXU flop-time at peak. A host VPU op costs
# VPU_RATE_DIV cycles (the vector unit runs at peak/VPU_RATE_DIV), so the
# two sides' busy time is directly comparable. account() and run() both
# derive their stall/overlap counters from this one schedule, which is what
# lets tests assert they agree exactly.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageTiming:
    """Timing of one flexible *stage* (a fused run of one or more
    consecutive flexible ops) under the T-deep ring protocol.

    With T tiles, all but the first tile's host time can hide behind the
    producer chain's epilogue (the accelerator fills tiles t+1..T-1 while
    the host computes tile t), and all but the last tile's host time can
    hide behind the consumer chain's prologue (the accelerator eats
    returned results while the host finishes the tail). Each adjacent
    static op donates at most half its flops to one flexible neighbour,
    so overlap never double-counts MXU time; total overlap is capped at
    the host's busy time. T=2 reduces to PR 1's ping-pong math.
    """

    index: int             # position of the stage's first op in graph.ops
    host_cycles: int       # total host VPU time for this stage (all tiles)
    producer_cycles: int   # preceding static op's work (epilogue overlap)
    consumer_cycles: int   # following static op's work (prologue overlap)
    tiles: int             # ring depth T; 1 (serial) when unsplittable
    indices: tuple[int, ...] = ()   # all fused op positions (>= 1)
    functions: tuple[str, ...] = ()  # function-table keys, in order
    operand_bytes: int = 0  # stage input crossing acc -> sidebar -> host
    result_bytes: int = 0   # stage output crossing host -> sidebar -> acc

    @property
    def overlap_cycles(self) -> int:
        """Cycles where host and accelerator are busy simultaneously."""
        if self.tiles < 2:
            return 0
        ahead = self.host_cycles * (self.tiles - 1) // self.tiles
        return min(
            self.host_cycles,
            min(ahead, self.producer_cycles // 2)
            + min(ahead, self.consumer_cycles // 2),
        )

    @property
    def stall_cycles(self) -> int:
        """Accelerator cycles spent polling the return flag. Serial mode
        stalls for the whole host computation; pipelining hides the
        overlapped part behind adjacent static work."""
        return self.host_cycles - self.overlap_cycles


def host_cycles_of(op: FlexibleOp, operand_shape: tuple[int, ...],
                   table: FunctionTable) -> int:
    """Host VPU time of one flexible op, in MXU-flop-time cycles."""
    n = int(math.prod(operand_shape))
    return int(n * table.cost(op.function) * VPU_RATE_DIV)


def _splittable(operand_shape: tuple[int, ...],
                out_shape: tuple[int, ...]) -> bool:
    """A flexible op can be ring-buffered when its operand and result
    tile along a shared leading axis (elementwise, pooling, and rowwise
    functions all preserve the leading/batch axis)."""
    return (
        len(operand_shape) >= 1
        and len(out_shape) >= 1
        and operand_shape[0] >= 2
        and operand_shape[0] == out_shape[0]
    )


def pipeline_schedule(
    graph: LayerGraph,
    table: FunctionTable = DEFAULT_TABLE,
    *,
    depth: int = 2,
    fuse: bool = True,
) -> list[StageTiming]:
    """Per-flexible-stage overlap schedule for SIDEBAR_PIPELINED.

    ``depth`` is the sidebar ring depth T: each splittable stage tiles
    its operand into ``min(depth, leading_axis)`` chunks. ``fuse`` merges
    runs of consecutive flexible ops into one stage (one host invocation
    per tile). ``depth=2, fuse=True`` on an alternating graph reproduces
    PR 1's double-buffered schedule exactly.
    """
    if depth < 1:
        raise ValueError(f"ring depth must be >= 1, got {depth}")
    shapes = graph.shapes()
    stages = []
    for indices in flexible_runs(graph, fuse=fuse):
        first, last = indices[0], indices[-1]
        prev = graph.ops[first - 1] if first > 0 else None
        nxt = graph.ops[last + 1] if last + 1 < len(graph.ops) else None
        producer = prev.flops if isinstance(prev, StaticOp) else 0
        consumer = nxt.flops if isinstance(nxt, StaticOp) else 0
        # the whole run must tile along one shared leading axis: every
        # member's operand AND result keep the stage operand's lead
        lead = shapes[first][0] if shapes[first] else 0
        splittable = all(
            _splittable(shapes[i], graph.ops[i].out_shape)
            and shapes[i][0] == lead
            for i in indices
        )
        tiles = min(depth, lead) if splittable and depth >= 2 else 1
        stages.append(
            StageTiming(
                index=first,
                host_cycles=sum(
                    host_cycles_of(graph.ops[i], shapes[i], table)
                    for i in indices
                ),
                producer_cycles=int(producer),
                consumer_cycles=int(consumer),
                tiles=tiles,
                indices=indices,
                functions=tuple(graph.ops[i].function for i in indices),
                operand_bytes=graph.bytes_of(shapes[first]),
                result_bytes=graph.bytes_of(graph.ops[last].out_shape),
            )
        )
    return stages


# ---------------------------------------------------------------------------
# Numeric execution.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    output: Array
    accounting: TaskAccounting
    launches: int
    sidebar: SidebarBuffer | None = None


def _apply_static_chain(chain, params: dict[str, Any], x: Array, table: FunctionTable) -> Array:
    """Apply one maximal chain (static ops + at most one trailing flexible).

    Inside a chain, a trailing flexible op is fused with the statics —
    this only happens in MONOLITHIC mode where fusion is total.
    """
    for op in chain:
        if isinstance(op, StaticOp):
            x = op.fn(params[op.name], x)
        else:
            x = table.lookup(op.function)(x)
    return x


def build_monolithic(
    graph: LayerGraph, table: FunctionTable = DEFAULT_TABLE
) -> Callable[[dict[str, Any], Array], Array]:
    """Freeze the whole task into one compiled program (the fixed-function
    accelerator). Flexible functions are resolved NOW; later table edits
    don't reach the compiled artifact — that's the inflexibility the paper
    ascribes to monolithic hardware."""
    frozen = {
        op.function: table.lookup(op.function)
        for op in graph.ops
        if isinstance(op, FlexibleOp)
    }

    def task(params: dict[str, Any], x: Array) -> Array:
        for op in graph.ops:
            if isinstance(op, StaticOp):
                x = op.fn(params[op.name], x)
            else:
                x = frozen[op.function](x)
        return x

    return jax.jit(task)


def run(
    graph: LayerGraph,
    params: dict[str, Any],
    x: Array,
    mode: ExecutionMode | LayerPlan,
    table: FunctionTable = DEFAULT_TABLE,
    *,
    sidebar_capacity: int | None = None,
    depth: int = 2,
    fuse: bool = True,
) -> RunResult:
    """Execute the task under ``mode``; returns output + exact accounting.

    ``depth``/``fuse`` shape the SIDEBAR_PIPELINED ring (ignored by the
    other modes); passing a ``LayerPlan`` as ``mode`` supplies all three.
    """
    if isinstance(mode, LayerPlan):
        mode, depth, fuse = mode.mode, mode.depth, mode.fuse
    acct = account(graph, mode, table, depth=depth, fuse=fuse)

    if mode is ExecutionMode.MONOLITHIC:
        out = build_monolithic(graph, table)(params, x)
        return RunResult(out, acct, launches=1)

    if mode is ExecutionMode.FLEXIBLE_DMA:
        # One launch per static chain; flexible ops run "on the host" as
        # separate dispatches with the intermediate materialized both ways.
        launches = 0
        for chain in segment_static_chains(graph):
            static_part = [op for op in chain if isinstance(op, StaticOp)]
            if static_part:
                x = jax.jit(
                    functools.partial(_apply_static_chain, static_part, table=table)
                )(params, x)
                x = jax.block_until_ready(x)  # the DMA-out barrier
                launches += 1
            flex = [op for op in chain if isinstance(op, FlexibleOp)]
            for op in flex:
                x = jax.jit(table.lookup(op.function))(x)
                x = jax.block_until_ready(x)  # host writes back to DRAM
        return RunResult(x, acct, launches=launches)

    if mode is ExecutionMode.SIDEBAR:
        # Serial sidebar: single fused launch; every flexible op routes its
        # operand through the SidebarBuffer protocol model (ownership +
        # traffic checks). Regions are recycled through the free list — no
        # whole-buffer teardown between ops.
        capacity = sidebar_capacity or required_capacity(
            graph.shapes()[0], graph.itemsize, copies=2
        )
        for _, op, shape in graph.flexible_ops():
            need = required_capacity(shape, graph.itemsize, copies=2)
            capacity = max(
                capacity, need,
                required_capacity(op.out_shape, graph.itemsize, copies=2),
            )
        sb = SidebarBuffer(capacity, name=f"{graph.name}.sidebar")

        for i, op in enumerate(graph.ops):
            if isinstance(op, StaticOp):
                x = op.fn(params[op.name], x)
                sb.stats.acc_busy_cycles += int(op.flops)
            else:
                operand = np.asarray(x)
                opn, res = f"op{i}.operand", f"op{i}.result"
                sb.allocate(opn, operand.nbytes)
                out_nbytes = (
                    int(math.prod(op.out_shape)) * operand.dtype.itemsize
                )
                sb.allocate(res, out_nbytes)
                sb.write(Owner.ACCELERATOR, opn, operand)
                sb.invoke_host(
                    SidebarCall(
                        function=op.function,
                        in_regions=(opn,),
                        out_regions=(res,),
                        n_elements=int(operand.size),
                    ),
                    table,
                    dtype=operand.dtype,
                )
                x = jnp.asarray(sb.read(Owner.ACCELERATOR, res)).reshape(
                    op.out_shape
                )
                # the accelerator polled the return flag for the whole
                # host computation — fully serialized
                h = host_cycles_of(op, operand.shape, table)
                sb.stats.host_busy_cycles += h
                sb.stats.stall_cycles += h
                sb.free(opn)
                sb.free(res)
        return RunResult(x, acct, launches=1, sidebar=sb)

    # SIDEBAR_PIPELINED: single fused launch; each flexible stage's operand
    # is split into T tiles along the leading axis and traded through a
    # T-deep ring of region pairs — the accelerator fills slots up to T-1
    # tiles ahead (and consumes returned results) while the host computes.
    # Runs of consecutive flexible ops share one host invocation per tile.
    assert mode is ExecutionMode.SIDEBAR_PIPELINED, mode
    stages = pipeline_schedule(graph, table, depth=depth, fuse=fuse)
    schedule = {s.index: s for s in stages}
    shapes = graph.shapes()
    capacity = sidebar_capacity or 0
    for s in stages:
        capacity = max(
            capacity,
            pipelined_capacity(
                shapes[s.index], graph.ops[s.indices[-1]].out_shape,
                graph.itemsize, tiles=s.tiles,
            ),
        )
    sb = SidebarBuffer(max(capacity, 512), name=f"{graph.name}.sidebar2")
    fused_tail = {i for s in stages for i in s.indices[1:]}

    for i, op in enumerate(graph.ops):
        if isinstance(op, StaticOp):
            x = op.fn(params[op.name], x)
            sb.stats.acc_busy_cycles += int(op.flops)
            continue
        if i in fused_tail:
            continue  # already computed by its stage leader's invocation
        stage = schedule[i]
        chain = stage.functions[1:]
        out_shape = graph.ops[stage.indices[-1]].out_shape
        operand = np.asarray(x)
        itemsize = operand.dtype.itemsize
        if stage.tiles == 1:
            # unsplittable operand (leading axis too small or reshaped):
            # degrade to the serial handshake on a single recycled pair —
            # the fused chain still rides one invocation
            opn, res = f"op{i}.operand", f"op{i}.result"
            sb.allocate(opn, operand.nbytes)
            sb.allocate(res, int(math.prod(out_shape)) * itemsize)
            sb.write(Owner.ACCELERATOR, opn, operand)
            sb.invoke_host(
                SidebarCall(op.function, (opn,), (res,),
                            int(operand.size), chain=chain),
                table, dtype=operand.dtype,
            )
            x = jnp.asarray(sb.read(Owner.ACCELERATOR, res)).reshape(
                out_shape
            )
            sb.free(opn)
            sb.free(res)
        else:
            tiles = np.array_split(operand, stage.tiles, axis=0)
            res_rest = int(math.prod(out_shape[1:]))
            ring = SidebarRing(
                sb, f"op{i}",
                operand_nbytes=int(tiles[0].nbytes),
                result_nbytes=tiles[0].shape[0] * res_rest * itemsize,
                depth=stage.tiles,
            )
            results: list[np.ndarray | None] = [None] * stage.tiles

            def _retire(t: int, slot) -> None:
                # host finishes tile t: result written, return flag
                # raised; the accelerator reads it back (the next static
                # chain's prologue in the timeline) and frees the slot
                sb.host_call(
                    SidebarCall(op.function, (slot.operand.name,),
                                (slot.result.name,), int(tiles[t].size),
                                chain=chain),
                    table, dtype=operand.dtype,
                )
                ring.to_accelerator(slot)
                results[t] = np.asarray(
                    sb.read(Owner.ACCELERATOR, slot.result.name)
                )
                ring.release(slot)

            # ring depth == tile count, so every tile gets its own slot
            # and the accelerator can fill/invoke all T tiles ahead of
            # the host — legal only because ownership is per region.
            # Retirement then drains FIFO (slot-reuse at depth < tiles
            # is exercised by the ring protocol tests, not this path).
            window: list[tuple[int, Any]] = []
            for t in range(stage.tiles):
                slot = ring.acquire(t)
                sb.write(Owner.ACCELERATOR, slot.operand.name, tiles[t])
                ring.to_host(slot)
                window.append((t, slot))
            for entry in window:  # pipeline drain
                _retire(*entry)
            ring.free()
            x = jnp.asarray(np.concatenate(results, axis=0)).reshape(
                out_shape
            )
        sb.stats.host_busy_cycles += stage.host_cycles
        sb.stats.overlap_cycles += stage.overlap_cycles
        sb.stats.stall_cycles += stage.stall_cycles
    return RunResult(x, acct, launches=1, sidebar=sb)


# ---------------------------------------------------------------------------
# Analytic accounting (drives energy model, benchmarks, roofline).
# ---------------------------------------------------------------------------


def account(
    graph: LayerGraph,
    mode: ExecutionMode | LayerPlan,
    table: FunctionTable = DEFAULT_TABLE,
    *,
    depth: int = 2,
    fuse: bool = True,
) -> TaskAccounting:
    """Exact byte/flop/protocol counts for one task under ``mode``.

    Shared by all modes (paper: "the initial and final DMA processes must
    still take place"): task input DMA-in, task output DMA-out, weight
    streaming, and the MXU flops of the static ops. ``depth``/``fuse``
    shape the SIDEBAR_PIPELINED ring schedule; a ``LayerPlan`` supplies
    all three at once.
    """
    if isinstance(mode, LayerPlan):
        mode, depth, fuse = mode.mode, mode.depth, mode.fuse
    io_bytes = graph.in_bytes + graph.out_bytes
    weight_bytes = graph.weight_bytes
    mxu = graph.static_flops

    flex = graph.flexible_ops()
    flex_elems = [
        (int(math.prod(shape)), table.cost(op.function)) for _, op, shape in flex
    ]
    flex_ops_total = int(sum(n * c for n, c in flex_elems))
    flex_elems_total = int(sum(n for n, _ in flex_elems))
    flex_bytes_total = int(
        sum(graph.bytes_of(shape) for _, _, shape in flex)
        + sum(graph.bytes_of(op.out_shape) for _, op, _ in flex)
    )

    if mode is ExecutionMode.MONOLITHIC:
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            mxu_flops=mxu,
            flex_hw_ops=flex_ops_total,       # dedicated in-pipeline unit
            flex_elements=flex_elems_total,
            datapath_bytes=flex_bytes_total,  # internal registers/SRAM
            launches=1,
            flex_stages=len(flex),
            dma_flushes=2,                    # initial in + final out
        )

    if mode is ExecutionMode.FLEXIBLE_DMA:
        n_chains = len(segment_static_chains(graph))
        # Each flexible operand crosses the bus 4x: acc store, host load,
        # host store, next-acc load (paper §5.3.2).
        dma_intermediate = 2 * flex_bytes_total  # operand(2x) + result(2x)
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            hbm_intermediate_bytes=dma_intermediate,
            mxu_flops=mxu,
            flex_vpu_ops=flex_ops_total,
            flex_elements=flex_elems_total,
            launches=n_chains,
            dma_flushes=2 + 2 * len(flex),    # per-handoff flush+invalidate
            host_invocations=len(flex),
            flex_stages=len(flex),
        )

    # SIDEBAR / SIDEBAR_PIPELINED: the intermediate crosses the scratchpad
    # twice (acc<->sb and host<->sb) and never touches HBM. They differ in
    # the protocol-event counts, in how much of the host's busy time the
    # accelerator actually waits out, and — when pipelining fuses a run of
    # consecutive flexible ops — in the inter-op intermediates that stay
    # in host registers instead of re-crossing the sidebar.
    sidebar_bytes = 2 * flex_bytes_total
    stages = pipeline_schedule(graph, table, depth=depth, fuse=fuse)
    host_busy = sum(s.host_cycles for s in stages)

    if mode is ExecutionMode.SIDEBAR:
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            sidebar_bytes=sidebar_bytes,
            mxu_flops=mxu,
            flex_vpu_ops=flex_ops_total,
            flex_elements=flex_elems_total,
            launches=1,
            dma_flushes=2,
            handshakes=2 * len(flex),
            host_invocations=len(flex),
            flex_stages=len(flex),
            host_busy_cycles=host_busy,
            acc_busy_cycles=mxu,
            stall_cycles=host_busy,   # fully serialized (paper §4: the FSM
            overlap_cycles=0,         # polls until the CPU signals)
        )

    assert mode is ExecutionMode.SIDEBAR_PIPELINED, mode
    return TaskAccounting(
        mode=mode.value,
        hbm_io_bytes=io_bytes,
        hbm_weight_bytes=weight_bytes,
        # only each stage's input and final output cross the sidebar;
        # fused inter-op intermediates stay in host registers
        sidebar_bytes=2 * sum(s.operand_bytes + s.result_bytes
                              for s in stages),
        mxu_flops=mxu,
        flex_vpu_ops=flex_ops_total,
        flex_elements=flex_elems_total,
        launches=1,
        dma_flushes=2,
        # one flag per slot per direction: T tiles x (invoke + return)
        handshakes=sum(2 * s.tiles for s in stages),
        host_invocations=sum(s.tiles for s in stages),
        flex_stages=len(stages),
        host_busy_cycles=host_busy,
        acc_busy_cycles=mxu,
        stall_cycles=sum(s.stall_cycles for s in stages),
        overlap_cycles=sum(s.overlap_cycles for s in stages),
    )


def account_model(
    graphs: list[LayerGraph],
    mode: ExecutionMode | LayerPlan,
    table: FunctionTable = DEFAULT_TABLE,
    *,
    depth: int = 2,
    fuse: bool = True,
) -> TaskAccounting:
    """Accounting for a whole model = merged per-layer tasks."""
    accts = [account(g, mode, table, depth=depth, fuse=fuse) for g in graphs]
    total = accts[0]
    for a in accts[1:]:
        total = total.merge(a)
    return total
