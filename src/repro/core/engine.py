"""The Sidebar execution engine.

Runs a ``LayerGraph`` (alternating static/flexible ops) under each of the
paper's three designs plus the double-buffered SIDEBAR_PIPELINED
refinement, producing *numerically identical results* (the math is
mode-invariant — tests assert this) while differing in:

  * how many accelerator launches happen,
  * where intermediates live (HBM round-trip vs sidebar scratch vs internal
    datapath),
  * who computes the flexible functions (host VPU vs dedicated HW),
  * which protocol events fire (DMA flush vs sidebar handshake).

Two layers of fidelity:

  1. ``run(...)`` — actually executes the graph in JAX, routing every
     flexible call through the mode's mechanism. In SIDEBAR mode the
     intermediate passes through a ``SidebarBuffer`` software model which
     enforces the ownership protocol and meters traffic. In MONOLITHIC
     mode the whole task is built into one compiled callable whose
     flexible functions were *frozen at build time* (hot-swapping the
     function table afterwards must not — and does not — change it).

  2. ``account(...)`` — pure analytic counts (no execution) feeding
     ``core.energy.estimate``. The dry-run/roofline path uses this at
     production scale where numeric execution is impossible on CPU.

Pipelined timeline (SIDEBAR_PIPELINED, per flexible op, 2 tiles):

    acc : write A.op | write B.op      | read A.res+prologue | read B.res
    host:            | f(A.op)->A.res  | f(B.op)->B.res      |
                  ^invoke A         ^ret A / invoke B     ^ret B

  The accelerator's wait shrinks from the host's full busy time to
  ``host - min(host/2, prologue/2)``; ``pipeline_schedule`` is the single
  source of truth for those counters, shared by ``run`` and ``account``.

The fused TPU fast path for the hot pattern (matmul → activation → matmul)
is ``kernels/sidebar_mlp.py``; the engine is the general mechanism and the
place where mode semantics are defined.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants
from repro.core.energy import VPU_RATE_DIV, TaskAccounting
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.core.modes import (
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    StaticOp,
    segment_static_chains,
)
from repro.core.sidebar import (
    Owner,
    PingPongPair,
    SidebarBuffer,
    SidebarCall,
    pipelined_capacity,
    required_capacity,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# Pipeline schedule: the shared overlap model of SIDEBAR_PIPELINED.
#
# Abstract cycle unit: one MXU flop-time at peak. A host VPU op costs
# VPU_RATE_DIV cycles (the vector unit runs at peak/VPU_RATE_DIV), so the
# two sides' busy time is directly comparable. account() and run() both
# derive their stall/overlap counters from this one schedule, which is what
# lets tests assert they agree exactly.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageTiming:
    """Timing of one flexible op under the double-buffered protocol.

    With two tiles, each half of the host's busy time can hide behind a
    *different* piece of accelerator work: while the host computes tile 0,
    the producer chain's epilogue fills tile 1 into the other half; while
    the host computes tile 1, the consumer chain's prologue eats tile 0's
    returned result. Each adjacent static op donates at most half its
    flops to one flexible neighbour, so overlap never double-counts MXU
    time.
    """

    index: int             # position of the flexible op in graph.ops
    host_cycles: int       # total host VPU time for this op (all tiles)
    producer_cycles: int   # preceding static op's work (epilogue overlap)
    consumer_cycles: int   # following static op's work (prologue overlap)
    tiles: int             # 2 when double-buffered, 1 (serial) when unsplit

    @property
    def overlap_cycles(self) -> int:
        """Cycles where host and accelerator are busy simultaneously."""
        if self.tiles < 2:
            return 0
        half = self.host_cycles // 2
        return min(half, self.producer_cycles // 2) + min(
            half, self.consumer_cycles // 2
        )

    @property
    def stall_cycles(self) -> int:
        """Accelerator cycles spent polling the return flag. Serial mode
        stalls for the whole host computation; pipelining hides the
        overlapped part behind adjacent static work."""
        return self.host_cycles - self.overlap_cycles


def host_cycles_of(op: FlexibleOp, operand_shape: tuple[int, ...],
                   table: FunctionTable) -> int:
    """Host VPU time of one flexible op, in MXU-flop-time cycles."""
    n = int(math.prod(operand_shape))
    return int(n * table.cost(op.function) * VPU_RATE_DIV)


def _splittable(operand_shape: tuple[int, ...],
                out_shape: tuple[int, ...]) -> bool:
    """A flexible op can be double-buffered when its operand and result
    tile along a shared leading axis (elementwise, pooling, and rowwise
    functions all preserve the leading/batch axis)."""
    return (
        len(operand_shape) >= 1
        and len(out_shape) >= 1
        and operand_shape[0] >= 2
        and operand_shape[0] == out_shape[0]
    )


def pipeline_schedule(
    graph: LayerGraph, table: FunctionTable = DEFAULT_TABLE
) -> list[StageTiming]:
    """Per-flexible-op overlap schedule for SIDEBAR_PIPELINED."""
    shapes = graph.shapes()
    stages = []
    for i, op in enumerate(graph.ops):
        if not isinstance(op, FlexibleOp):
            continue
        prev = graph.ops[i - 1] if i > 0 else None
        nxt = graph.ops[i + 1] if i + 1 < len(graph.ops) else None
        producer = prev.flops if isinstance(prev, StaticOp) else 0
        consumer = nxt.flops if isinstance(nxt, StaticOp) else 0
        tiles = 2 if _splittable(shapes[i], op.out_shape) else 1
        stages.append(
            StageTiming(
                index=i,
                host_cycles=host_cycles_of(op, shapes[i], table),
                producer_cycles=int(producer),
                consumer_cycles=int(consumer),
                tiles=tiles,
            )
        )
    return stages


# ---------------------------------------------------------------------------
# Numeric execution.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    output: Array
    accounting: TaskAccounting
    launches: int
    sidebar: SidebarBuffer | None = None


def _apply_static_chain(chain, params: dict[str, Any], x: Array, table: FunctionTable) -> Array:
    """Apply one maximal chain (static ops + at most one trailing flexible).

    Inside a chain, a trailing flexible op is fused with the statics —
    this only happens in MONOLITHIC mode where fusion is total.
    """
    for op in chain:
        if isinstance(op, StaticOp):
            x = op.fn(params[op.name], x)
        else:
            x = table.lookup(op.function)(x)
    return x


def build_monolithic(
    graph: LayerGraph, table: FunctionTable = DEFAULT_TABLE
) -> Callable[[dict[str, Any], Array], Array]:
    """Freeze the whole task into one compiled program (the fixed-function
    accelerator). Flexible functions are resolved NOW; later table edits
    don't reach the compiled artifact — that's the inflexibility the paper
    ascribes to monolithic hardware."""
    frozen = {
        op.function: table.lookup(op.function)
        for op in graph.ops
        if isinstance(op, FlexibleOp)
    }

    def task(params: dict[str, Any], x: Array) -> Array:
        for op in graph.ops:
            if isinstance(op, StaticOp):
                x = op.fn(params[op.name], x)
            else:
                x = frozen[op.function](x)
        return x

    return jax.jit(task)


def run(
    graph: LayerGraph,
    params: dict[str, Any],
    x: Array,
    mode: ExecutionMode,
    table: FunctionTable = DEFAULT_TABLE,
    *,
    sidebar_capacity: int | None = None,
) -> RunResult:
    """Execute the task under ``mode``; returns output + exact accounting."""
    acct = account(graph, mode, table)

    if mode is ExecutionMode.MONOLITHIC:
        out = build_monolithic(graph, table)(params, x)
        return RunResult(out, acct, launches=1)

    if mode is ExecutionMode.FLEXIBLE_DMA:
        # One launch per static chain; flexible ops run "on the host" as
        # separate dispatches with the intermediate materialized both ways.
        launches = 0
        for chain in segment_static_chains(graph):
            static_part = [op for op in chain if isinstance(op, StaticOp)]
            if static_part:
                x = jax.jit(
                    functools.partial(_apply_static_chain, static_part, table=table)
                )(params, x)
                x = jax.block_until_ready(x)  # the DMA-out barrier
                launches += 1
            flex = [op for op in chain if isinstance(op, FlexibleOp)]
            for op in flex:
                x = jax.jit(table.lookup(op.function))(x)
                x = jax.block_until_ready(x)  # host writes back to DRAM
        return RunResult(x, acct, launches=launches)

    if mode is ExecutionMode.SIDEBAR:
        # Serial sidebar: single fused launch; every flexible op routes its
        # operand through the SidebarBuffer protocol model (ownership +
        # traffic checks). Regions are recycled through the free list — no
        # whole-buffer teardown between ops.
        capacity = sidebar_capacity or required_capacity(
            graph.shapes()[0], graph.itemsize, copies=2
        )
        for _, op, shape in graph.flexible_ops():
            need = required_capacity(shape, graph.itemsize, copies=2)
            capacity = max(
                capacity, need,
                required_capacity(op.out_shape, graph.itemsize, copies=2),
            )
        sb = SidebarBuffer(capacity, name=f"{graph.name}.sidebar")

        for i, op in enumerate(graph.ops):
            if isinstance(op, StaticOp):
                x = op.fn(params[op.name], x)
                sb.stats.acc_busy_cycles += int(op.flops)
            else:
                operand = np.asarray(x)
                opn, res = f"op{i}.operand", f"op{i}.result"
                sb.allocate(opn, operand.nbytes)
                out_nbytes = (
                    int(math.prod(op.out_shape)) * operand.dtype.itemsize
                )
                sb.allocate(res, out_nbytes)
                sb.write(Owner.ACCELERATOR, opn, operand)
                sb.invoke_host(
                    SidebarCall(
                        function=op.function,
                        in_regions=(opn,),
                        out_regions=(res,),
                        n_elements=int(operand.size),
                    ),
                    table,
                    dtype=operand.dtype,
                )
                x = jnp.asarray(sb.read(Owner.ACCELERATOR, res)).reshape(
                    op.out_shape
                )
                # the accelerator polled the return flag for the whole
                # host computation — fully serialized
                h = host_cycles_of(op, operand.shape, table)
                sb.stats.host_busy_cycles += h
                sb.stats.stall_cycles += h
                sb.free(opn)
                sb.free(res)
        return RunResult(x, acct, launches=1, sidebar=sb)

    # SIDEBAR_PIPELINED: single fused launch; each flexible op's operand is
    # split into two tiles along the leading axis and traded through a
    # ping-pong region pair — the accelerator fills half B (and consumes
    # half A's returned result) while the host computes half A.
    assert mode is ExecutionMode.SIDEBAR_PIPELINED, mode
    schedule = {s.index: s for s in pipeline_schedule(graph, table)}
    capacity = sidebar_capacity or 0
    for _, op, shape in graph.flexible_ops():
        capacity = max(
            capacity, pipelined_capacity(shape, op.out_shape, graph.itemsize)
        )
    sb = SidebarBuffer(max(capacity, 512), name=f"{graph.name}.sidebar2")

    for i, op in enumerate(graph.ops):
        if isinstance(op, StaticOp):
            x = op.fn(params[op.name], x)
            sb.stats.acc_busy_cycles += int(op.flops)
            continue
        stage = schedule[i]
        operand = np.asarray(x)
        itemsize = operand.dtype.itemsize
        if stage.tiles == 1:
            # unsplittable operand (leading axis too small or reshaped):
            # degrade to the serial handshake on a single recycled pair
            opn, res = f"op{i}.operand", f"op{i}.result"
            sb.allocate(opn, operand.nbytes)
            sb.allocate(res, int(math.prod(op.out_shape)) * itemsize)
            sb.write(Owner.ACCELERATOR, opn, operand)
            sb.invoke_host(
                SidebarCall(op.function, (opn,), (res,), int(operand.size)),
                table, dtype=operand.dtype,
            )
            x = jnp.asarray(sb.read(Owner.ACCELERATOR, res)).reshape(
                op.out_shape
            )
            sb.free(opn)
            sb.free(res)
        else:
            split = operand.shape[0] - operand.shape[0] // 2  # ceil half
            tiles = (operand[:split], operand[split:])
            lead = (split, operand.shape[0] - split)
            res_rest = int(math.prod(op.out_shape[1:]))
            pair = PingPongPair(
                sb, f"op{i}",
                operand_nbytes=int(tiles[0].nbytes),
                result_nbytes=lead[0] * res_rest * itemsize,
            )
            results = [None, None]
            # t=0: fill ping, raise its invoke flag
            h0 = pair.acquire(0)
            sb.write(Owner.ACCELERATOR, h0.operand.name, tiles[0])
            pair.to_host(h0)
            # while the "host computes" ping, the accelerator fills pong —
            # legal only because ownership is per region
            h1 = pair.acquire(1)
            sb.write(Owner.ACCELERATOR, h1.operand.name, tiles[1])
            # host finishes ping: result written, return flag raised
            sb.host_call(
                SidebarCall(op.function, (h0.operand.name,),
                            (h0.result.name,), int(tiles[0].size)),
                table, dtype=operand.dtype,
            )
            pair.to_accelerator(h0)
            # host takes pong; accelerator concurrently consumes ping's
            # result (the next static chain's prologue in the timeline)
            pair.to_host(h1)
            results[0] = np.asarray(
                sb.read(Owner.ACCELERATOR, h0.result.name)
            )
            pair.release(h0)
            sb.host_call(
                SidebarCall(op.function, (h1.operand.name,),
                            (h1.result.name,), int(tiles[1].size)),
                table, dtype=operand.dtype,
            )
            pair.to_accelerator(h1)
            results[1] = np.asarray(
                sb.read(Owner.ACCELERATOR, h1.result.name)
            )
            pair.release(h1)
            pair.free()
            x = jnp.asarray(np.concatenate(results, axis=0)).reshape(
                op.out_shape
            )
        sb.stats.host_busy_cycles += stage.host_cycles
        sb.stats.overlap_cycles += stage.overlap_cycles
        sb.stats.stall_cycles += stage.stall_cycles
    return RunResult(x, acct, launches=1, sidebar=sb)


# ---------------------------------------------------------------------------
# Analytic accounting (drives energy model, benchmarks, roofline).
# ---------------------------------------------------------------------------


def account(
    graph: LayerGraph,
    mode: ExecutionMode,
    table: FunctionTable = DEFAULT_TABLE,
) -> TaskAccounting:
    """Exact byte/flop/protocol counts for one task under ``mode``.

    Shared by all modes (paper: "the initial and final DMA processes must
    still take place"): task input DMA-in, task output DMA-out, weight
    streaming, and the MXU flops of the static ops.
    """
    io_bytes = graph.in_bytes + graph.out_bytes
    weight_bytes = graph.weight_bytes
    mxu = graph.static_flops

    flex = graph.flexible_ops()
    flex_elems = [
        (int(math.prod(shape)), table.cost(op.function)) for _, op, shape in flex
    ]
    flex_ops_total = int(sum(n * c for n, c in flex_elems))
    flex_elems_total = int(sum(n for n, _ in flex_elems))
    flex_bytes_total = int(
        sum(graph.bytes_of(shape) for _, _, shape in flex)
        + sum(graph.bytes_of(op.out_shape) for _, op, _ in flex)
    )

    if mode is ExecutionMode.MONOLITHIC:
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            mxu_flops=mxu,
            flex_hw_ops=flex_ops_total,       # dedicated in-pipeline unit
            flex_elements=flex_elems_total,
            datapath_bytes=flex_bytes_total,  # internal registers/SRAM
            launches=1,
            flex_stages=len(flex),
            dma_flushes=2,                    # initial in + final out
        )

    if mode is ExecutionMode.FLEXIBLE_DMA:
        n_chains = len(segment_static_chains(graph))
        # Each flexible operand crosses the bus 4x: acc store, host load,
        # host store, next-acc load (paper §5.3.2).
        dma_intermediate = 2 * flex_bytes_total  # operand(2x) + result(2x)
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            hbm_intermediate_bytes=dma_intermediate,
            mxu_flops=mxu,
            flex_vpu_ops=flex_ops_total,
            flex_elements=flex_elems_total,
            launches=n_chains,
            dma_flushes=2 + 2 * len(flex),    # per-handoff flush+invalidate
            host_invocations=len(flex),
            flex_stages=len(flex),
        )

    # SIDEBAR / SIDEBAR_PIPELINED share all data movement: the intermediate
    # crosses the scratchpad twice (acc<->sb and host<->sb) and never
    # touches HBM. They differ only in the protocol-event counts and in how
    # much of the host's busy time the accelerator actually waits out.
    sidebar_bytes = 2 * flex_bytes_total
    stages = pipeline_schedule(graph, table)
    host_busy = sum(s.host_cycles for s in stages)

    if mode is ExecutionMode.SIDEBAR:
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            sidebar_bytes=sidebar_bytes,
            mxu_flops=mxu,
            flex_vpu_ops=flex_ops_total,
            flex_elements=flex_elems_total,
            launches=1,
            dma_flushes=2,
            handshakes=2 * len(flex),
            host_invocations=len(flex),
            flex_stages=len(flex),
            host_busy_cycles=host_busy,
            acc_busy_cycles=mxu,
            stall_cycles=host_busy,   # fully serialized (paper §4: the FSM
            overlap_cycles=0,         # polls until the CPU signals)
        )

    assert mode is ExecutionMode.SIDEBAR_PIPELINED, mode
    return TaskAccounting(
        mode=mode.value,
        hbm_io_bytes=io_bytes,
        hbm_weight_bytes=weight_bytes,
        sidebar_bytes=sidebar_bytes,
        mxu_flops=mxu,
        flex_vpu_ops=flex_ops_total,
        flex_elements=flex_elems_total,
        launches=1,
        dma_flushes=2,
        # one flag per half per direction: 2 tiles x (invoke + return)
        handshakes=sum(2 * s.tiles for s in stages),
        host_invocations=sum(s.tiles for s in stages),
        flex_stages=len(stages),
        host_busy_cycles=host_busy,
        acc_busy_cycles=mxu,
        stall_cycles=sum(s.stall_cycles for s in stages),
        overlap_cycles=sum(s.overlap_cycles for s in stages),
    )


def account_model(
    graphs: list[LayerGraph],
    mode: ExecutionMode,
    table: FunctionTable = DEFAULT_TABLE,
) -> TaskAccounting:
    """Accounting for a whole model = merged per-layer tasks."""
    accts = [account(g, mode, table) for g in graphs]
    total = accts[0]
    for a in accts[1:]:
        total = total.merge(a)
    return total
