"""The Sidebar execution engine.

Runs a ``LayerGraph`` (alternating static/flexible ops) under each of the
paper's three designs, producing *numerically identical results* (the math
is mode-invariant — tests assert this) while differing in:

  * how many accelerator launches happen,
  * where intermediates live (HBM round-trip vs sidebar scratch vs internal
    datapath),
  * who computes the flexible functions (host VPU vs dedicated HW),
  * which protocol events fire (DMA flush vs sidebar handshake).

Two layers of fidelity:

  1. ``run(...)`` — actually executes the graph in JAX, routing every
     flexible call through the mode's mechanism. In SIDEBAR mode the
     intermediate passes through a ``SidebarBuffer`` software model which
     enforces the ownership protocol and meters traffic. In MONOLITHIC
     mode the whole task is built into one compiled callable whose
     flexible functions were *frozen at build time* (hot-swapping the
     function table afterwards must not — and does not — change it).

  2. ``account(...)`` — pure analytic counts (no execution) feeding
     ``core.energy.estimate``. The dry-run/roofline path uses this at
     production scale where numeric execution is impossible on CPU.

The fused TPU fast path for the hot pattern (matmul → activation → matmul)
is ``kernels/sidebar_mlp.py``; the engine is the general mechanism and the
place where mode semantics are defined.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants
from repro.core.energy import TaskAccounting
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.core.modes import (
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    StaticOp,
    segment_static_chains,
)
from repro.core.sidebar import Owner, SidebarBuffer, SidebarCall, required_capacity

Array = jax.Array


# ---------------------------------------------------------------------------
# Numeric execution.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    output: Array
    accounting: TaskAccounting
    launches: int
    sidebar: SidebarBuffer | None = None


def _apply_static_chain(chain, params: dict[str, Any], x: Array, table: FunctionTable) -> Array:
    """Apply one maximal chain (static ops + at most one trailing flexible).

    Inside a chain, a trailing flexible op is fused with the statics —
    this only happens in MONOLITHIC mode where fusion is total.
    """
    for op in chain:
        if isinstance(op, StaticOp):
            x = op.fn(params[op.name], x)
        else:
            x = table.lookup(op.function)(x)
    return x


def build_monolithic(
    graph: LayerGraph, table: FunctionTable = DEFAULT_TABLE
) -> Callable[[dict[str, Any], Array], Array]:
    """Freeze the whole task into one compiled program (the fixed-function
    accelerator). Flexible functions are resolved NOW; later table edits
    don't reach the compiled artifact — that's the inflexibility the paper
    ascribes to monolithic hardware."""
    frozen = {
        op.function: table.lookup(op.function)
        for op in graph.ops
        if isinstance(op, FlexibleOp)
    }

    def task(params: dict[str, Any], x: Array) -> Array:
        for op in graph.ops:
            if isinstance(op, StaticOp):
                x = op.fn(params[op.name], x)
            else:
                x = frozen[op.function](x)
        return x

    return jax.jit(task)


def run(
    graph: LayerGraph,
    params: dict[str, Any],
    x: Array,
    mode: ExecutionMode,
    table: FunctionTable = DEFAULT_TABLE,
    *,
    sidebar_capacity: int | None = None,
) -> RunResult:
    """Execute the task under ``mode``; returns output + exact accounting."""
    acct = account(graph, mode, table)

    if mode is ExecutionMode.MONOLITHIC:
        out = build_monolithic(graph, table)(params, x)
        return RunResult(out, acct, launches=1)

    if mode is ExecutionMode.FLEXIBLE_DMA:
        # One launch per static chain; flexible ops run "on the host" as
        # separate dispatches with the intermediate materialized both ways.
        launches = 0
        for chain in segment_static_chains(graph):
            static_part = [op for op in chain if isinstance(op, StaticOp)]
            if static_part:
                x = jax.jit(
                    functools.partial(_apply_static_chain, static_part, table=table)
                )(params, x)
                x = jax.block_until_ready(x)  # the DMA-out barrier
                launches += 1
            flex = [op for op in chain if isinstance(op, FlexibleOp)]
            for op in flex:
                x = jax.jit(table.lookup(op.function))(x)
                x = jax.block_until_ready(x)  # host writes back to DRAM
        return RunResult(x, acct, launches=launches)

    # SIDEBAR: single fused launch; every flexible op routes its operand
    # through the SidebarBuffer protocol model (ownership + traffic checks).
    capacity = sidebar_capacity or required_capacity(
        graph.shapes()[0], graph.itemsize, copies=2
    )
    for _, op, shape in graph.flexible_ops():
        need = required_capacity(shape, graph.itemsize, copies=2)
        capacity = max(capacity, need)
    sb = SidebarBuffer(capacity, name=f"{graph.name}.sidebar")

    for op in graph.ops:
        if isinstance(op, StaticOp):
            x = op.fn(params[op.name], x)
        else:
            operand = np.asarray(x)
            sb.free_all()
            in_region = sb.allocate("operand", operand.nbytes)
            out_nbytes = int(math.prod(op.out_shape)) * operand.dtype.itemsize
            sb.allocate("result", out_nbytes)
            sb.write(Owner.ACCELERATOR, "operand", operand)
            sb.invoke_host(
                SidebarCall(
                    function=op.function,
                    in_regions=("operand",),
                    out_regions=("result",),
                    n_elements=int(operand.size),
                ),
                table,
                dtype=operand.dtype,
            )
            x = jnp.asarray(sb.read(Owner.ACCELERATOR, "result")).reshape(op.out_shape)
    return RunResult(x, acct, launches=1, sidebar=sb)


# ---------------------------------------------------------------------------
# Analytic accounting (drives energy model, benchmarks, roofline).
# ---------------------------------------------------------------------------


def account(
    graph: LayerGraph,
    mode: ExecutionMode,
    table: FunctionTable = DEFAULT_TABLE,
) -> TaskAccounting:
    """Exact byte/flop/protocol counts for one task under ``mode``.

    Shared by all modes (paper: "the initial and final DMA processes must
    still take place"): task input DMA-in, task output DMA-out, weight
    streaming, and the MXU flops of the static ops.
    """
    io_bytes = graph.in_bytes + graph.out_bytes
    weight_bytes = graph.weight_bytes
    mxu = graph.static_flops

    flex = graph.flexible_ops()
    flex_elems = [
        (int(math.prod(shape)), table.cost(op.function)) for _, op, shape in flex
    ]
    flex_ops_total = int(sum(n * c for n, c in flex_elems))
    flex_elems_total = int(sum(n for n, _ in flex_elems))
    flex_bytes_total = int(
        sum(graph.bytes_of(shape) for _, _, shape in flex)
        + sum(graph.bytes_of(op.out_shape) for _, op, _ in flex)
    )

    if mode is ExecutionMode.MONOLITHIC:
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            mxu_flops=mxu,
            flex_hw_ops=flex_ops_total,       # dedicated in-pipeline unit
            flex_elements=flex_elems_total,
            datapath_bytes=flex_bytes_total,  # internal registers/SRAM
            launches=1,
            dma_flushes=2,                    # initial in + final out
        )

    if mode is ExecutionMode.FLEXIBLE_DMA:
        n_chains = len(segment_static_chains(graph))
        # Each flexible operand crosses the bus 4x: acc store, host load,
        # host store, next-acc load (paper §5.3.2).
        dma_intermediate = 2 * flex_bytes_total  # operand(2x) + result(2x)
        return TaskAccounting(
            mode=mode.value,
            hbm_io_bytes=io_bytes,
            hbm_weight_bytes=weight_bytes,
            hbm_intermediate_bytes=dma_intermediate,
            mxu_flops=mxu,
            flex_vpu_ops=flex_ops_total,
            flex_elements=flex_elems_total,
            launches=n_chains,
            dma_flushes=2 + 2 * len(flex),    # per-handoff flush+invalidate
            host_invocations=len(flex),
        )

    # SIDEBAR
    sidebar_bytes = 2 * flex_bytes_total      # acc<->sb and host<->sb
    return TaskAccounting(
        mode=mode.value,
        hbm_io_bytes=io_bytes,
        hbm_weight_bytes=weight_bytes,
        sidebar_bytes=sidebar_bytes,
        mxu_flops=mxu,
        flex_vpu_ops=flex_ops_total,
        flex_elements=flex_elems_total,
        launches=1,
        dma_flushes=2,
        handshakes=2 * len(flex),
        host_invocations=len(flex),
    )


def account_model(
    graphs: list[LayerGraph],
    mode: ExecutionMode,
    table: FunctionTable = DEFAULT_TABLE,
) -> TaskAccounting:
    """Accounting for a whole model = merged per-layer tasks."""
    accts = [account(g, mode, table) for g in graphs]
    total = accts[0]
    for a in accts[1:]:
        total = total.merge(a)
    return total
