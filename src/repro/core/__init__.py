"""Core: the paper's contribution — Sidebar-based CPU/accelerator cooperation.

Public surface:
  * ``FunctionTable`` / ``DEFAULT_TABLE`` — the host function table.
  * ``SidebarBuffer`` — ownership-checked scratchpad protocol model.
  * ``LayerGraph`` / ``StaticOp`` / ``FlexibleOp`` — static/flexible IR.
  * ``ExecutionMode`` — MONOLITHIC | FLEXIBLE_DMA | SIDEBAR.
  * ``engine.run`` / ``engine.account`` — execute / meter a task.
  * ``energy.estimate`` — latency/energy/EDP model.
  * ``policy.AutoPolicy`` — per-layer mode selection.
"""

from repro.core.constants import V5E, ChipSpec
from repro.core.energy import Estimate, TaskAccounting, estimate, normalized_edp
from repro.core.engine import (
    StageTiming,
    account,
    account_model,
    build_monolithic,
    pipeline_schedule,
    run,
)
from repro.core.function_table import DEFAULT_TABLE, FunctionTable, make_default_table
from repro.core.modes import (
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    OpKind,
    StaticOp,
    segment_static_chains,
)
from repro.core.policy import AutoPolicy, fixed, plan
from repro.core.sidebar import (
    Owner,
    PingPongPair,
    Region,
    SidebarBuffer,
    SidebarCall,
    SidebarProtocolError,
    SidebarStats,
    pipelined_capacity,
)

__all__ = [
    "V5E",
    "ChipSpec",
    "Estimate",
    "TaskAccounting",
    "estimate",
    "normalized_edp",
    "account",
    "account_model",
    "build_monolithic",
    "run",
    "DEFAULT_TABLE",
    "FunctionTable",
    "make_default_table",
    "ExecutionMode",
    "FlexibleOp",
    "LayerGraph",
    "OpKind",
    "StaticOp",
    "segment_static_chains",
    "AutoPolicy",
    "fixed",
    "plan",
    "Owner",
    "PingPongPair",
    "Region",
    "SidebarBuffer",
    "SidebarCall",
    "SidebarProtocolError",
    "SidebarStats",
    "StageTiming",
    "pipeline_schedule",
    "pipelined_capacity",
]
