"""Core: the paper's contribution — Sidebar-based CPU/accelerator cooperation.

Public surface:
  * ``FunctionTable`` / ``DEFAULT_TABLE`` — the host function table.
  * ``SidebarBuffer`` — ownership-checked scratchpad protocol model.
  * ``LayerGraph`` / ``StaticOp`` / ``FlexibleOp`` — static/flexible IR.
  * ``ExecutionMode`` — MONOLITHIC | FLEXIBLE_DMA | SIDEBAR.
  * ``engine.run`` / ``engine.account`` — execute / meter a task.
  * ``energy.estimate`` — latency/energy/EDP model.
  * ``policy.AutoPolicy`` — per-layer mode selection.
"""

from repro.core.constants import V5E, ChipSpec
from repro.core.energy import Estimate, TaskAccounting, estimate, normalized_edp
from repro.core.engine import (
    StageTiming,
    account,
    account_model,
    build_monolithic,
    pipeline_schedule,
    run,
)
from repro.core.function_table import DEFAULT_TABLE, FunctionTable, make_default_table
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    FlexibleOp,
    LayerGraph,
    LayerPlan,
    OpKind,
    StaticOp,
    flexible_runs,
    segment_static_chains,
)
from repro.core.policy import (
    AutoPolicy,
    PlanDiagnostics,
    PlanResult,
    fixed,
    plan,
)
from repro.core.sidebar import (
    Owner,
    PingPongPair,
    Region,
    RingSlot,
    SidebarBuffer,
    SidebarCall,
    SidebarProtocolError,
    SidebarRing,
    SidebarStats,
    pipelined_capacity,
)

__all__ = [
    "V5E",
    "ChipSpec",
    "Estimate",
    "TaskAccounting",
    "estimate",
    "normalized_edp",
    "account",
    "account_model",
    "build_monolithic",
    "run",
    "DEFAULT_TABLE",
    "FunctionTable",
    "make_default_table",
    "ExecutionMode",
    "ExecutionPlan",
    "FlexibleOp",
    "LayerGraph",
    "LayerPlan",
    "OpKind",
    "StaticOp",
    "flexible_runs",
    "segment_static_chains",
    "AutoPolicy",
    "PlanDiagnostics",
    "PlanResult",
    "fixed",
    "plan",
    "Owner",
    "PingPongPair",
    "Region",
    "RingSlot",
    "SidebarBuffer",
    "SidebarCall",
    "SidebarProtocolError",
    "SidebarRing",
    "SidebarStats",
    "StageTiming",
    "pipeline_schedule",
    "pipelined_capacity",
]
