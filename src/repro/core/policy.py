"""Per-layer execution-mode policies.

A production deployment doesn't pick one mode globally: the paper itself
notes the trade depends on the intermediate size and the flexible-function
cost. A ``Policy`` maps each layer graph to an ``ExecutionMode``; the
``auto`` policy picks a sidebar mode (SIDEBAR or the double-buffered
SIDEBAR_PIPELINED, whichever the EDP model prefers — pipelined wins
whenever the graph exposes overlap) when the intermediate fits the
sidebar, falling back to FLEXIBLE_DMA for oversized intermediates (with a
warning counter) — monolithic is only chosen when the layer has no
flexible ops at all (nothing to flex).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import constants
from repro.core.energy import estimate
from repro.core.engine import account
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.core.modes import ExecutionMode, LayerGraph

Policy = Callable[[LayerGraph], ExecutionMode]


def fixed(mode: ExecutionMode) -> Policy:
    def policy(graph: LayerGraph) -> ExecutionMode:
        return mode

    return policy


@dataclasses.dataclass
class AutoPolicy:
    """EDP-minimizing mode choice with a sidebar-capacity constraint."""

    table: FunctionTable = dataclasses.field(default_factory=lambda: DEFAULT_TABLE)
    sidebar_capacity: int = constants.VMEM_BYTES_PER_CHIP // 2
    chip: constants.ChipSpec = constants.V5E
    fallbacks: int = 0  # count of layers forced off SIDEBAR by capacity

    def __call__(self, graph: LayerGraph) -> ExecutionMode:
        if not graph.flexible_ops():
            return ExecutionMode.MONOLITHIC
        candidates = [ExecutionMode.FLEXIBLE_DMA]
        if graph.max_intermediate_bytes() <= self.sidebar_capacity:
            candidates.append(ExecutionMode.SIDEBAR)
            candidates.append(ExecutionMode.SIDEBAR_PIPELINED)
        else:
            self.fallbacks += 1
        best = min(
            candidates,
            key=lambda m: estimate(account(graph, m, self.table), self.chip).edp,
        )
        return best


def plan(graphs: list[LayerGraph], policy: Policy) -> dict[str, ExecutionMode]:
    """Resolve a mode per layer (the 'compilation tool' of paper §3.1)."""
    return {g.name: policy(g) for g in graphs}
