"""Per-layer execution planning.

A production deployment doesn't pick one mode globally: the paper itself
notes the trade depends on the intermediate size and the flexible-function
cost, and FlexNN-style dataflow tuning shows the *buffer depth* matters as
much as the mode. ``AutoPolicy`` therefore plans per layer:

  * mode — a sidebar mode when the intermediate fits the sidebar (SIDEBAR
    or SIDEBAR_PIPELINED, whichever the EDP model prefers), falling back
    to FLEXIBLE_DMA for oversized intermediates; MONOLITHIC only when the
    layer has no flexible ops at all (nothing to flex);
  * ring depth — swept over ``depth_candidates`` under the
    sidebar-capacity constraint (a T-deep ring needs T slot pairs), EDP
    scored via ``core.energy.estimate``;
  * fusion — runs of consecutive flexible ops share one host invocation
    per tile (always beneficial in the model: fewer exposed handshakes
    and fewer sidebar crossings for identical compute).

``AutoPolicy.plan`` returns a ``PlanResult`` — the ``ExecutionPlan`` plus
``PlanDiagnostics`` — rather than mutating policy state, so a policy
object can be shared/reused concurrently. Calling the policy like a plain
``Policy`` (``policy(graph) -> ExecutionMode``) remains supported.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Callable, Sequence

from repro.core import constants
from repro.core.energy import estimate
from repro.core.engine import account
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    LayerGraph,
    LayerPlan,
)
from repro.core.sidebar import pipelined_capacity

Policy = Callable[[LayerGraph], ExecutionMode]

DEFAULT_DEPTH_CANDIDATES = (1, 2, 3, 4, 8)


def fixed(mode: ExecutionMode) -> Policy:
    def policy(graph: LayerGraph) -> ExecutionMode:
        return mode

    return policy


@dataclasses.dataclass(frozen=True)
class PlanDiagnostics:
    """What the planner saw while choosing — returned, never mutated in.

    ``fallbacks`` lists layers forced off the sidebar modes by capacity;
    ``edp`` maps layer name -> the chosen plan's modeled EDP (J*s);
    ``depth_sweep`` maps layer name -> {depth: EDP} for every capacity-
    feasible SIDEBAR_PIPELINED depth that was scored.
    """

    fallbacks: tuple[str, ...] = ()
    edp: dict[str, float] = dataclasses.field(default_factory=dict)
    depth_sweep: dict[str, dict[int, float]] = dataclasses.field(
        default_factory=dict
    )


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """An ``ExecutionPlan`` plus the diagnostics of producing it."""

    plan: ExecutionPlan
    diagnostics: PlanDiagnostics

    def for_layer(self, name: str) -> LayerPlan:
        return self.plan.for_layer(name)


@dataclasses.dataclass(frozen=True)
class AutoPolicy:
    """EDP-minimizing per-layer (mode, ring depth, fusion) choice under a
    sidebar-capacity constraint. Stateless: diagnostics come back in the
    ``PlanResult``, not as instance mutation."""

    table: FunctionTable = dataclasses.field(
        default_factory=lambda: DEFAULT_TABLE
    )
    sidebar_capacity: int = constants.VMEM_BYTES_PER_CHIP // 2
    chip: constants.ChipSpec = constants.V5E
    depth_candidates: Sequence[int] = DEFAULT_DEPTH_CANDIDATES

    # -- per-layer planning ------------------------------------------------
    def _ring_fits(self, graph: LayerGraph, depth: int) -> bool:
        """A T-deep ring stages T (operand, result) slot pairs per stage;
        the largest stage's ring must fit the sidebar."""
        need = max(
            (
                pipelined_capacity(
                    shape, op.out_shape, graph.itemsize, tiles=depth
                )
                for _, op, shape in graph.flexible_ops()
            ),
            default=0,
        )
        return need <= self.sidebar_capacity

    def plan_layer(self, graph: LayerGraph) -> tuple[LayerPlan, dict]:
        """Choose (mode, depth, fuse) for one layer; returns the plan and
        a diagnostics dict: {"fallback": bool, "edp": float,
        "depth_sweep": {depth: edp}}."""
        if not graph.flexible_ops():
            plan = LayerPlan(ExecutionMode.MONOLITHIC, depth=1)
            edp = estimate(account(graph, plan.mode, self.table),
                           self.chip).edp
            return plan, {"fallback": False, "edp": edp, "depth_sweep": {}}

        candidates: list[LayerPlan] = [
            LayerPlan(ExecutionMode.FLEXIBLE_DMA, depth=1)
        ]
        sweep: dict[int, float] = {}
        fallback = graph.max_intermediate_bytes() > self.sidebar_capacity
        if not fallback:
            candidates.append(LayerPlan(ExecutionMode.SIDEBAR, depth=1))
            for d in self.depth_candidates:
                if d >= 1 and self._ring_fits(graph, d):
                    candidates.append(
                        LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=d)
                    )

        scored: list[tuple[float, LayerPlan]] = []
        for plan in candidates:
            edp = estimate(account(graph, plan, self.table), self.chip).edp
            if plan.mode is ExecutionMode.SIDEBAR_PIPELINED:
                sweep[plan.depth] = edp
            scored.append((edp, plan))
        # stable min: ties keep candidate order (DMA < SIDEBAR < deeper)
        best_edp, best = min(scored, key=lambda t: t[0])
        return best, {
            "fallback": fallback, "edp": best_edp, "depth_sweep": sweep,
        }

    # -- whole-model planning ----------------------------------------------
    def plan(self, graphs: Sequence[LayerGraph]) -> PlanResult:
        """Resolve an ``ExecutionPlan`` over ``graphs`` ('compilation
        tool' of paper §3.1), plus the diagnostics of choosing it.

        The plan's ``default`` is the modal per-layer choice: consumers
        that can only apply one plan globally (``Server`` traces kernels
        layer-agnostically and uses ``plan.default``) then follow what
        the sweep actually chose for most layers, not a hardcoded one.
        """
        layers: dict[str, LayerPlan] = {}
        fallbacks: list[str] = []
        edp: dict[str, float] = {}
        depth_sweep: dict[str, dict[int, float]] = {}
        for g in graphs:
            lp, diag = self.plan_layer(g)
            layers[g.name] = lp
            edp[g.name] = diag["edp"]
            if diag["depth_sweep"]:
                depth_sweep[g.name] = diag["depth_sweep"]
            if diag["fallback"]:
                fallbacks.append(g.name)
        if layers:
            counts = Counter(layers.values())
            default = counts.most_common(1)[0][0]
        else:
            default = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED)
        return PlanResult(
            plan=ExecutionPlan(default=default, layers=layers),
            diagnostics=PlanDiagnostics(
                fallbacks=tuple(fallbacks), edp=edp,
                depth_sweep=depth_sweep,
            ),
        )

    # -- Policy-callable compatibility --------------------------------------
    def __call__(self, graph: LayerGraph) -> ExecutionMode:
        return self.plan_layer(graph)[0].mode


def plan(graphs: Sequence[LayerGraph],
         policy: Policy | AutoPolicy | None = None) -> PlanResult:
    """Resolve a plan per layer. With an ``AutoPolicy`` (the default) the
    full (mode, depth, fuse) sweep runs; a plain ``Policy`` callable only
    chooses modes and gets default ring parameters."""
    if policy is None:
        policy = AutoPolicy()
    if isinstance(policy, AutoPolicy):
        return policy.plan(graphs)
    layers = {g.name: LayerPlan(policy(g)) for g in graphs}
    return PlanResult(
        plan=ExecutionPlan(
            default=LayerPlan(ExecutionMode.SIDEBAR_PIPELINED),
            layers=layers,
        ),
        diagnostics=PlanDiagnostics(),
    )
