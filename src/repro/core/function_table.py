"""The host function table (paper §3.3).

The paper's host CPU keeps "a table of functions the accelerator may call
on the CPU to perform. These functions will be part of the accelerator's
driver and will therefore be written and compiled ahead of time". The
accelerator invokes them by writing a function pointer + arguments into
dedicated Sidebar slots.

Here the table is the single source of truth for every *flexible* function
in the system. Static primitives (matmuls, convs, scans) are fixed; flexible
functions are looked up by name at trace time. Swapping an activation is a
table operation — **no kernel source changes** — which is exactly the
flexibility the paper claims over fixed-function (monolithic) designs.

Entries are pure jnp callables so the same table serves:
  * the analytical engine (core/engine.py),
  * the Pallas kernel epilogues (kernels/sidebar_mlp.py traces the entry
    into the kernel body on the VPU),
  * the FLEXIBLE_DMA standalone activation kernel,
  * the reference oracles (kernels/ref.py).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import constants

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FunctionEntry:
    """One row of the function table.

    Attributes:
      name: table key (the "function pointer" written into the Sidebar).
      fn: pure elementwise/rowwise jnp callable.
      vpu_ops_per_element: host-side vector-op cost (drives the energy and
        latency model; encodes relu-vs-softplus asymmetry from the paper).
      rowwise: True if the function needs a full row (softmax, norms) —
        affects how kernels may tile it (last dim must be resident).
    """

    name: str
    fn: Callable[..., Array]
    vpu_ops_per_element: float
    rowwise: bool = False


class FunctionTable:
    """Driver-style registry of host ("flexible") functions.

    Thread-safe; versioned. The version increments on any mutation so jitted
    consumers can key compilation caches on ``(name, version)`` — mirroring
    "re-register + re-jit, no hardware change".
    """

    def __init__(self) -> None:
        self._entries: dict[str, FunctionEntry] = {}
        self._lock = threading.Lock()
        self._version = 0

    # -- registration ---------------------------------------------------
    def register(
        self,
        name: str,
        fn: Callable[..., Array],
        *,
        vpu_ops_per_element: float | None = None,
        rowwise: bool = False,
        overwrite: bool = False,
    ) -> FunctionEntry:
        with self._lock:
            if name in self._entries and not overwrite:
                raise ValueError(
                    f"function {name!r} already registered; pass overwrite=True "
                    "to hot-swap (the paper's 'new activation function' path)"
                )
            cost = (
                vpu_ops_per_element
                if vpu_ops_per_element is not None
                else constants.FLEXIBLE_OP_COST.get(
                    name, constants.DEFAULT_FLEXIBLE_OP_COST
                )
            )
            entry = FunctionEntry(name, fn, cost, rowwise)
            self._entries[name] = entry
            self._version += 1
            return entry

    def unregister(self, name: str) -> None:
        with self._lock:
            del self._entries[name]
            self._version += 1

    # -- lookup ----------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __getitem__(self, name: str) -> FunctionEntry:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"flexible function {name!r} not in the function table; "
                f"known: {sorted(self._entries)}"
            ) from None

    def lookup(self, name: str) -> Callable[..., Array]:
        return self[name].fn

    def cost(self, name: str) -> float:
        return self[name].vpu_ops_per_element

    def names(self) -> list[str]:
        return sorted(self._entries)

    @property
    def version(self) -> int:
        return self._version


# ---------------------------------------------------------------------------
# Default table: the paper's Table 1 activations + the flexible functions
# the assigned architectures need.
# ---------------------------------------------------------------------------

def _heaviside(x: Array) -> Array:
    return (x > 0).astype(x.dtype)


def _leaky_relu(x: Array) -> Array:
    return jnp.where(x > 0, x, 0.01 * x)


def _elu(x: Array, a: float = 1.0) -> Array:
    safe = jnp.minimum(x, 0.0)
    return jnp.where(x > 0, x, a * (jnp.exp(safe) - 1.0))


def _softplus(x: Array) -> Array:
    # log(1+e^x), numerically stable.
    return jnp.logaddexp(x, 0.0).astype(x.dtype)


def _squared_relu(x: Array) -> Array:
    r = jnp.maximum(x, 0.0)
    return (r * r).astype(x.dtype)


def _silu(x: Array) -> Array:
    return (x * jax.nn.sigmoid(x.astype(jnp.float32))).astype(x.dtype)


def _gelu(x: Array) -> Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def _softmax(x: Array) -> Array:
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def _rmsnorm(x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype)


def _exp_decay(x: Array) -> Array:
    # RWKV6 data-dependent decay: w = exp(-exp(x)).
    return jnp.exp(-jnp.exp(x.astype(jnp.float32))).astype(x.dtype)


def make_default_table() -> FunctionTable:
    t = FunctionTable()
    t.register("identity", lambda x: x)
    t.register("heaviside", _heaviside)
    t.register("relu", lambda x: jnp.maximum(x, 0.0).astype(x.dtype))
    t.register("leaky_relu", _leaky_relu)
    t.register("elu", _elu)
    t.register("tanh", lambda x: jnp.tanh(x))
    t.register("sigmoid", lambda x: jax.nn.sigmoid(x))
    t.register("softplus", _softplus)
    t.register("squared_relu", _squared_relu)
    t.register("silu", _silu)
    t.register("gelu", _gelu)
    t.register("abs", lambda x: jnp.abs(x))
    t.register("softmax", _softmax, rowwise=True)
    t.register("rmsnorm", _rmsnorm, rowwise=True)
    t.register("exp_decay", _exp_decay)
    return t


# Process-wide default table (drivers may build their own).
DEFAULT_TABLE = make_default_table()
