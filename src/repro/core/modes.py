"""Execution modes and the static/flexible layer-graph IR.

The paper's design space is three ways to run an accelerator task whose
dataflow alternates *static* tensor primitives with *flexible* functions:

  MONOLITHIC   — everything in one fixed-function accelerator; the flexible
                 functions are frozen into the hardware (here: baked into
                 one compiled program at build time; hot-swapping the
                 function table has NO effect on an already-built program).
  FLEXIBLE_DMA — static primitives as separate accelerators; each flexible
                 function runs on the host with the intermediate DMA'd out
                 to DRAM and back (here: separate kernel launches with the
                 intermediate materialized to HBM both ways).
  SIDEBAR      — static primitives as separate accelerators; flexible
                 functions run on the host through the sidebar scratchpad
                 (here: fused kernel with the intermediate resident in a
                 VMEM scratch; the flexible function is looked up in the
                 function table at trace time).

plus the overlapped refinement this repo adds on top of the paper:

  SIDEBAR_PIPELINED — SIDEBAR with the scratchpad split into a T-deep
                 ring of (operand, result) region pairs and ownership
                 tracked per region: the host computes flexible op *i*
                 tile t on one slot while the accelerator fills /
                 consumes up to T-1 other slots (tiles t+1..t+T-1, or
                 the next static chain's prologue). Latency per stage
                 becomes max(host, accelerator) instead of host +
                 accelerator; the numerics are bit-identical. Runs of
                 *consecutive* flexible ops fuse into one host
                 invocation per tile (one ownership round-trip for the
                 whole run).

The IR below expresses a layer as an alternating op list. Models in
``repro.models`` emit these graphs; ``core.engine`` executes/accounts them;
``kernels/`` provides the fused TPU implementations for the hot shapes.

``LayerPlan``/``ExecutionPlan`` carry the *deployment* choice — which
mode, how deep a ring, whether to fuse — per layer; ``core.policy``
produces them, ``core.engine`` and ``kernels.ops`` consume them.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Mapping, Sequence

import jax


class ExecutionMode(enum.Enum):
    MONOLITHIC = "monolithic"
    FLEXIBLE_DMA = "flexible_dma"
    SIDEBAR = "sidebar"
    SIDEBAR_PIPELINED = "sidebar_pipelined"


class OpKind(enum.Enum):
    STATIC = "static"      # MXU: matmul/conv/scan — fixed-function
    FLEXIBLE = "flexible"  # VPU/"host": activation/norm/softmax/router


@dataclasses.dataclass(frozen=True)
class StaticOp:
    """A fixed-function tensor primitive (one 'small accelerator', S1–S5).

    ``fn(params, x) -> y`` must be pure. ``flops`` and weight bytes are
    declared (not inferred) so accounting is exact and shape-checked in
    tests against the jitted cost analysis.
    """

    name: str
    fn: Callable[..., jax.Array]
    out_shape: tuple[int, ...]
    flops: int                    # MXU flops for one call
    weight_bytes: int             # parameter bytes streamed from HBM
    kind: OpKind = dataclasses.field(default=OpKind.STATIC, init=False)


@dataclasses.dataclass(frozen=True)
class FlexibleOp:
    """A host/function-table op applied to the previous intermediate."""

    function: str                 # function-table key
    out_shape: tuple[int, ...]
    kind: OpKind = dataclasses.field(default=OpKind.FLEXIBLE, init=False)


Op = StaticOp | FlexibleOp


@dataclasses.dataclass(frozen=True)
class LayerGraph:
    """One accelerator task: an alternating sequence of ops.

    ``in_shape``/``in_dtype`` describe the activation entering the task
    (DMA'd in at task start in every mode, per the paper: "the initial and
    final DMA processes must still take place").
    """

    name: str
    ops: tuple[Op, ...]
    in_shape: tuple[int, ...]
    itemsize: int = 4  # bytes per element of activations/intermediates

    def __post_init__(self) -> None:
        if not self.ops:
            raise ValueError(f"layer graph {self.name!r} has no ops")

    # -- shape/byte bookkeeping -------------------------------------------
    def shapes(self) -> list[tuple[int, ...]]:
        """[in_shape, op0.out, op1.out, ...]."""
        return [self.in_shape] + [op.out_shape for op in self.ops]

    def bytes_of(self, shape: Sequence[int]) -> int:
        return int(math.prod(shape)) * self.itemsize

    @property
    def in_bytes(self) -> int:
        return self.bytes_of(self.in_shape)

    @property
    def out_bytes(self) -> int:
        return self.bytes_of(self.ops[-1].out_shape)

    @property
    def static_flops(self) -> int:
        return sum(op.flops for op in self.ops if isinstance(op, StaticOp))

    @property
    def weight_bytes(self) -> int:
        return sum(op.weight_bytes for op in self.ops if isinstance(op, StaticOp))

    def flexible_ops(self) -> list[tuple[int, FlexibleOp, tuple[int, ...]]]:
        """(index, op, operand_shape) for each flexible op — the operand is
        the *previous* op's output (or the input for index 0)."""
        shapes = self.shapes()
        return [
            (i, op, shapes[i])
            for i, op in enumerate(self.ops)
            if isinstance(op, FlexibleOp)
        ]

    def max_intermediate_bytes(self) -> int:
        """Sidebar capacity the task needs (largest staged intermediate)."""
        flex = self.flexible_ops()
        if not flex:
            return 0
        return max(
            max(self.bytes_of(shape), self.bytes_of(op.out_shape))
            for _, op, shape in flex
        )


def flexible_runs(
    graph: LayerGraph, fuse: bool = True
) -> list[tuple[int, ...]]:
    """Indices of flexible ops grouped into maximal consecutive runs.

    A run of adjacent ``FlexibleOp``s shares one host invocation per tile
    under SIDEBAR_PIPELINED (the intermediate between fused ops stays in
    host registers and never re-crosses the sidebar). With ``fuse=False``
    every flexible op is its own singleton run.
    """
    runs: list[tuple[int, ...]] = []
    current: list[int] = []
    for i, op in enumerate(graph.ops):
        if isinstance(op, FlexibleOp):
            if current and (not fuse or current[-1] != i - 1):
                runs.append(tuple(current))
                current = []
            current.append(i)
        elif current:
            runs.append(tuple(current))
            current = []
    if current:
        runs.append(tuple(current))
    return runs


# ---------------------------------------------------------------------------
# Execution plans: the deployment knobs threaded from policy to engine,
# kernels, and serving.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """How one layer graph should execute: mode + ring depth + fusion.

    ``depth`` is the sidebar ring depth (= tile count T the overlap
    schedule uses); it only matters for SIDEBAR_PIPELINED. ``fuse``
    controls whether runs of consecutive flexible ops share one host
    invocation per tile.
    """

    mode: ExecutionMode
    depth: int = 2
    fuse: bool = True

    def __post_init__(self) -> None:
        if self.depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {self.depth}")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A per-layer mapping of ``LayerPlan``s plus a default.

    Produced by ``core.policy.AutoPolicy.plan`` (or built uniformly via
    ``ExecutionPlan.uniform``); consumed by ``core.engine`` (schedule /
    accounting), ``kernels.ops`` (ambient kernel-variant selection), and
    ``launch.serve.Server``.

    Layers are keyed by graph name (planner output) or by integer layer
    index (serving: ``models`` announce the index being traced through
    ``kernels.ops.layer_scope``). ``for_layer`` accepts either and falls
    back exact-key -> str(key) -> default, so a plan built from planner
    names and one built from model indices resolve the same way.
    """

    default: LayerPlan
    layers: Mapping[str | int, LayerPlan] = dataclasses.field(
        default_factory=dict
    )

    @classmethod
    def uniform(cls, mode: ExecutionMode | str, depth: int = 2,
                fuse: bool = True) -> "ExecutionPlan":
        if isinstance(mode, str):
            mode = ExecutionMode(mode)
        return cls(default=LayerPlan(mode, depth, fuse))

    @classmethod
    def by_index(cls, plans: Sequence[LayerPlan],
                 default: LayerPlan | None = None) -> "ExecutionPlan":
        """Plan for a model's layer stack: plans[i] applies to layer i."""
        if default is None:
            if not plans:
                raise ValueError("by_index needs at least one LayerPlan")
            counts: dict[LayerPlan, int] = {}
            for p in plans:
                counts[p] = counts.get(p, 0) + 1
            default = max(counts, key=counts.get)
        return cls(default=default, layers=dict(enumerate(plans)))

    def for_layer(self, key: str | int | None) -> LayerPlan:
        if key is None:
            return self.default
        hit = self.layers.get(key)
        if hit is None:
            if isinstance(key, int):
                hit = self.layers.get(str(key))
            elif isinstance(key, str) and key.lstrip("-").isdigit():
                hit = self.layers.get(int(key))
        return hit if hit is not None else self.default

    @property
    def is_uniform(self) -> bool:
        """True when every per-layer entry equals the default — a single
        trace (e.g. a scanned layer stack) can realize the whole plan."""
        return all(lp == self.default for lp in self.layers.values())

    def cache_key(self) -> tuple:
        """Hashable fingerprint for executable caches (``layers`` is a
        plain dict, so the dataclass itself is not hashable)."""
        return (
            self.default,
            tuple(sorted(((str(k), v) for k, v in self.layers.items()),
                         key=lambda kv: kv[0])),
        )


def coerce_layer_plan(
    plan: "LayerPlan | ExecutionPlan | ExecutionMode | str",
    depth: int | None = None,
) -> LayerPlan:
    """Normalize any plan spelling to a single ``LayerPlan`` — the one
    coercion shared by ``kernels.ops`` and ``launch.serve`` so the two
    entry points cannot drift. A whole ``ExecutionPlan`` collapses to its
    default (kernels are layer-agnostic); a bare mode gets depth 2 when
    pipelined, else the ring-less depth 1; ``depth`` overrides either.
    """
    if isinstance(plan, ExecutionPlan):
        plan = plan.default
    if isinstance(plan, str):
        plan = ExecutionMode(plan)
    if isinstance(plan, ExecutionMode):
        base = 2 if plan is ExecutionMode.SIDEBAR_PIPELINED else 1
        plan = LayerPlan(plan, depth=depth if depth is not None else base)
    elif depth is not None and depth != plan.depth:
        plan = dataclasses.replace(plan, depth=depth)
    return plan


def segment_static_chains(graph: LayerGraph) -> list[list[Op]]:
    """Split the op list into maximal chains, breaking after flexible ops.

    FLEXIBLE_DMA launches one accelerator per *static chain* and one host
    call per flexible op; SIDEBAR fuses everything into one launch. The
    segmentation is what Figure 4 draws as S1..S5 for LeNet.
    """
    chains: list[list[Op]] = [[]]
    for op in graph.ops:
        chains[-1].append(op)
        if isinstance(op, FlexibleOp):
            chains.append([])
    if not chains[-1]:
        chains.pop()
    return chains
