"""Fault-tolerance substrate: straggler watchdog + restartable driver.

At pod scale the two dominant failure modes are (a) hard node loss —
handled by checkpoint/restart (checkpoint/manager.py + the auto-resume
loop in launch/train.py) — and (b) **stragglers**: a slow chip/host
stretching every synchronous step. The watchdog detects (b) from the
per-step wall-time series:

  * robust statistics (median / MAD — a single 10x step doesn't poison
    the baseline the way mean/std would),
  * a step is a straggler event when t > median + z * MAD (z=6 default)
    AND t > slack * median (so tiny-absolute-jitter steps never alarm),
  * ``policy()`` escalates: OK -> WARN (log) after ``warn_after`` events
    in the window -> EVICT (recommend removing the slow host & elastic
    restart) after ``evict_after``.

The driver hook in launch/train.py consumes EVICT by checkpointing and
re-entering with a reduced mesh (elastic restart), which the integration
test exercises with injected timings.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque


class Verdict(enum.Enum):
    OK = "ok"
    WARN = "warn"
    EVICT = "evict"


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    median: float
    threshold: float


class StragglerWatchdog:
    def __init__(self, *, window: int = 64, z: float = 6.0,
                 slack: float = 1.5, warn_after: int = 2,
                 evict_after: int = 5, min_samples: int = 8) -> None:
        self.window = window
        self.z = z
        self.slack = slack
        self.warn_after = warn_after
        self.evict_after = evict_after
        self.min_samples = min_samples
        self._times: deque[float] = deque(maxlen=window)
        self._events: deque[int] = deque(maxlen=window)
        self.history: list[StragglerEvent] = []
        self._step = 0
        self._t0: float | None = None

    # -- timing API ---------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> Verdict:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(dt)

    # -- core ---------------------------------------------------------------
    def observe(self, seconds: float) -> Verdict:
        """Feed one step time; returns the escalation verdict."""
        self._step += 1
        verdict = Verdict.OK
        if len(self._times) >= self.min_samples:
            med = _median(self._times)
            mad = _median([abs(t - med) for t in self._times]) or 1e-9
            threshold = max(med + self.z * 1.4826 * mad, self.slack * med)
            if seconds > threshold:
                self._events.append(self._step)
                self.history.append(
                    StragglerEvent(self._step, seconds, med, threshold)
                )
                n_recent = sum(
                    1 for s in self._events if s > self._step - self.window
                )
                if n_recent >= self.evict_after:
                    verdict = Verdict.EVICT
                elif n_recent >= self.warn_after:
                    verdict = Verdict.WARN
                # straggler steps don't enter the baseline
                return verdict
        self._times.append(seconds)
        return verdict

    @property
    def median_step_s(self) -> float:
        return _median(self._times) if self._times else float("nan")


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return 0.0
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclasses.dataclass
class SegmentEvent:
    """One serving-side watchdog trip: segment ``call`` took ``seconds``
    against a trailing ``median`` (threshold = k * median)."""

    call: int
    seconds: float
    median: float
    threshold: float


class SegmentWatchdog:
    """Straggler detection for the serving drain loop: a segment
    dispatch whose wall time exceeds ``k`` x the trailing median is a
    recorded, NON-fatal event (the request still completes — the point
    is that a wedged compile, a device hang limping through retries, or
    a pathological host stall becomes observable in ``SchedulerStats``
    instead of silently stretching every SLO).

    Differences from ``StragglerWatchdog`` deliberate and small: serving
    segments legitimately span several compiled shapes (admit_k, width,
    steps all key executables), so the baseline is a plain trailing
    median with a multiplicative ``k`` — no MAD band, no escalation
    ladder, no evict verdict. Trips are excluded from the baseline so a
    stall cannot poison its own detector."""

    def __init__(self, *, k: float = 8.0, window: int = 64,
                 min_samples: int = 8) -> None:
        if k <= 1.0:
            raise ValueError(f"k must be > 1.0, got {k}")
        self.k = k
        self.min_samples = min_samples
        self._times: deque[float] = deque(maxlen=window)
        self.events: list[SegmentEvent] = []
        self._call = 0

    def observe(self, seconds: float) -> bool:
        """Feed one segment wall time; True = straggler event (recorded
        in ``events``, excluded from the baseline)."""
        self._call += 1
        if len(self._times) >= self.min_samples:
            med = _median(self._times)
            threshold = self.k * med
            if med > 0.0 and seconds > threshold:
                self.events.append(
                    SegmentEvent(self._call, seconds, med, threshold))
                return True
        self._times.append(seconds)
        return False

    @property
    def median_segment_s(self) -> float:
        return _median(self._times) if self._times else float("nan")
