"""Substrate package."""
