"""Deterministic toy embedding index over a block-aligned chunked corpus.

The retrieval half of the RAG workload is deliberately a *toy* — no
learned encoder, no ANN structure — because what the serving stack
exercises is the SPLIT, not retrieval quality: retrieval is flexible
host work (numpy, data-dependent, cheap to change) feeding the
accelerator's static decode programs, exactly the Sidebar host/
accelerator division. Determinism is the one property the toy must
hold hard: the same query against the same corpus retrieves the same
chunks in the same order on every run, platform, and replica, because
assembled prompts feed bit-exactness tests downstream.

Three pieces:

  * ``make_toy_corpus`` — seeded synthetic documents (token arrays)
    with repeated per-document motifs, so queries built from a
    document's tokens genuinely rank its chunks first;
  * ``ChunkedCorpus`` — documents split into fixed-size chunks of
    ``chunk_tokens`` tokens each (the tail dropped, never padded).
    ``chunk_tokens`` is validated against the KV pool's ``block_size``
    by the pipeline layer: chunk boundaries MUST land on block
    boundaries for chunk-level KV sharing to be addressable;
  * ``EmbeddingIndex`` — seeded random-projection embeddings
    (bag-of-tokens -> fixed projection matrix -> L2 normalize) with
    exact top-k dot-product search, ties broken by chunk id so the
    ranking is a total order.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


def make_toy_corpus(vocab_size: int, *, n_docs: int, doc_len: int,
                    seed: int = 0) -> list[np.ndarray]:
    """Seeded synthetic corpus: each document draws from its own narrow
    token band plus a per-document motif repeated throughout, so
    bag-of-token embeddings separate documents cleanly and a query made
    of one document's tokens retrieves that document's chunks."""
    rng = np.random.RandomState(seed)
    docs = []
    band = max(2, vocab_size // max(n_docs, 1))
    for d in range(n_docs):
        lo = (d * band) % max(vocab_size - band, 1)
        toks = rng.randint(lo, lo + band, size=doc_len)
        # the motif: every 4th token is the document's signature token
        toks[::4] = lo + (d % band)
        docs.append(np.asarray(toks, np.int32) % vocab_size)
    return docs


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One corpus chunk: provenance plus its token content."""

    doc: int                  # document index in the corpus
    idx: int                  # chunk index within the document
    tokens: np.ndarray        # (chunk_tokens,) int32


class ChunkedCorpus:
    """Documents split into fixed ``chunk_tokens``-token chunks.

    The tail of a document shorter than one chunk is dropped — a
    partial chunk could never be block-aligned in an assembled prompt,
    and padding it would put pad tokens inside retrieved content.
    """

    def __init__(self, docs: list[np.ndarray], chunk_tokens: int) -> None:
        if chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        self.chunk_tokens = int(chunk_tokens)
        self.chunks: list[Chunk] = []
        for d, doc in enumerate(docs):
            doc = np.asarray(doc, np.int32).reshape(-1)
            for i in range(doc.size // self.chunk_tokens):
                lo = i * self.chunk_tokens
                self.chunks.append(Chunk(
                    doc=d, idx=i,
                    tokens=doc[lo:lo + self.chunk_tokens].copy()))
        if not self.chunks:
            raise ValueError(
                f"no document holds a full chunk of {chunk_tokens} tokens")

    def __len__(self) -> int:
        return len(self.chunks)


class EmbeddingIndex:
    """Exact top-k dot-product search over seeded projection embeddings.

    The embedding of a token sequence is the L2-normalized sum of
    per-token projection rows — a bag-of-tokens map through one fixed
    ``(vocab, dim)`` matrix drawn from ``seed``. Deterministic by
    construction: no learned state, float64 accumulation, and a stable
    (score desc, chunk id asc) ranking, so every replica of a fleet
    ranks identically.

    ``io_latency_s`` models the chunk-payload fetch (disk/network)
    behind a real index that a CPU-resident toy corpus doesn't
    otherwise exhibit: each ``search`` sleeps that long with the GIL
    released, so an overlapped scheduler can hide the fetch behind
    in-flight decode while a serial one stalls on it. Default 0 —
    purely a bench/modeling knob, never ranking-relevant.
    """

    def __init__(self, corpus: ChunkedCorpus, *, vocab_size: int,
                 dim: int = 64, seed: int = 0,
                 io_latency_s: float = 0.0) -> None:
        self.corpus = corpus
        self.dim = int(dim)
        self.io_latency_s = float(io_latency_s)
        rng = np.random.RandomState(seed)
        self._proj = rng.standard_normal((int(vocab_size), self.dim))
        self._emb = np.stack([self.embed(c.tokens)
                              for c in corpus.chunks])   # (n_chunks, dim)

    def embed(self, tokens: np.ndarray) -> np.ndarray:
        """(dim,) float64 unit vector for a token sequence."""
        toks = np.asarray(tokens, np.int64).reshape(-1)
        v = self._proj[toks].sum(axis=0)
        n = np.linalg.norm(v)
        return v / n if n > 0 else v

    def search(self, query_tokens: np.ndarray,
               k: int) -> list[tuple[int, float]]:
        """Exact top-k: ``[(chunk_id, score), ...]`` by descending
        dot-product score, chunk id ascending on ties."""
        if k < 1:
            raise ValueError("k must be >= 1")
        k = min(k, len(self.corpus))
        if self.io_latency_s > 0:
            time.sleep(self.io_latency_s)   # modeled payload fetch
        scores = self._emb @ self.embed(query_tokens)
        # stable sort on (-score, id): exact, total, deterministic
        order = np.lexsort((np.arange(scores.size), -scores))[:k]
        return [(int(i), float(scores[i])) for i in order]
