"""Host-side retrieval: the Sidebar flexible-op split at serving scale.

Embedding lookup, similarity search, and prompt assembly are flexible
host work; decode is the accelerator's static matrix work. This package
provides the host half: a deterministic toy embedding index over a
block-aligned chunked corpus (``index``) and the prompt-assembly
pipeline (``rag``). ``launch.scheduler.PagedContinuousBatchingServer.
submit_query`` runs it between segment dispatches so retrieval for
request N+1 overlaps accelerator decode of active requests.
"""

from repro.retrieval.index import (
    ChunkedCorpus,
    EmbeddingIndex,
    make_toy_corpus,
)
from repro.retrieval.rag import RagPipeline, RagPrompt, RetrievedChunk

__all__ = [
    "ChunkedCorpus",
    "EmbeddingIndex",
    "make_toy_corpus",
    "RagPipeline",
    "RagPrompt",
    "RetrievedChunk",
]
