"""RAG prompt assembly: query -> (system prefix + chunks + question).

``RagPipeline.assemble`` is the host-side flexible op the scheduler
runs between segment dispatches: embed the query, exact top-k search,
lay the retrieved chunks out block-aligned, and return the assembled
prompt plus per-chunk provenance. Everything here is plain numpy —
it never touches the accelerator, which is the point.

Layout rules (the chunk-addressing contract, see ``docs/rag.md``):

  * the system prefix is right-padded with ``pad_token`` to a multiple
    of ``block_size``, so the first retrieved chunk starts ON a block
    boundary;
  * ``chunk_tokens`` must be a multiple of ``block_size``, so every
    chunk covers whole blocks and chunk boundaries are block
    boundaries;
  * with ``canonical_order=True`` (default) retrieved chunks are laid
    out by ascending corpus chunk id rather than by score. Two queries
    whose retrieved sets overlap then share a *leading* run of chunks
    wherever their sorted sets agree — and leading runs are exactly
    what the KV chunk index can reuse, because a transformer block's
    KV depends on its whole preceding context, not just the chunk's
    own tokens. Score order is available (``canonical_order=False``)
    for workloads where chunk precedence matters more than KV reuse.

Provenance (``RetrievedChunk.offset``) records where each chunk landed
in the prompt; the scheduler uses ``RagPrompt.chunk_blocks`` to
account chunk-level KV hits against exactly the retrieved-chunk
blocks, not the system prefix or the question tail.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.retrieval.index import EmbeddingIndex


@dataclasses.dataclass(frozen=True)
class RetrievedChunk:
    """Provenance of one retrieved chunk inside an assembled prompt."""

    doc: int                  # source document
    idx: int                  # chunk index within the document
    chunk_id: int             # corpus-global chunk id
    score: float              # dot-product retrieval score
    offset: int               # token offset of the chunk in the prompt
    tokens: np.ndarray        # (chunk_tokens,) int32 — the content


@dataclasses.dataclass(frozen=True)
class RagPrompt:
    """One assembled prompt plus everything needed to audit it."""

    tokens: np.ndarray               # (S,) int32 — the full prompt
    chunks: tuple[RetrievedChunk, ...]
    query: np.ndarray                # (Q,) int32 — as submitted

    def chunk_blocks(self, block_size: int) -> list[int]:
        """Block indices (of the assembled prompt's block grid) covered
        by retrieved chunks — the denominator of chunk-reuse stats."""
        out = []
        for c in self.chunks:
            lo = c.offset // block_size
            hi = (c.offset + c.tokens.size) // block_size
            out.extend(range(lo, hi))
        return out


class RagPipeline:
    """Query -> assembled prompt, deterministically.

    >>> pipe = RagPipeline(index, system_prefix=[7, 8, 9],
    ...                    block_size=8, top_k=2)
    >>> rp = pipe.assemble([42, 43, 44])
    >>> rp.tokens           # [sys..pad][chunk][chunk][42, 43, 44]
    """

    def __init__(self, index: EmbeddingIndex, *, system_prefix,
                 block_size: int, top_k: int = 2, pad_token: int = 0,
                 canonical_order: bool = True) -> None:
        if top_k < 1:
            raise ValueError("top_k must be >= 1")
        bs = int(block_size)
        if bs < 1:
            raise ValueError("block_size must be >= 1")
        if index.corpus.chunk_tokens % bs:
            raise ValueError(
                f"chunk_tokens {index.corpus.chunk_tokens} must be a "
                f"multiple of block_size {bs}: chunk boundaries must "
                "land on KV block boundaries to be chunk-addressable"
            )
        self.index = index
        self.block_size = bs
        self.top_k = int(top_k)
        self.canonical_order = bool(canonical_order)
        sys_toks = np.asarray(system_prefix, np.int32).reshape(-1)
        pad = (-sys_toks.size) % bs
        self.system_prefix = np.concatenate(
            [sys_toks, np.full((pad,), int(pad_token), np.int32)])

    @property
    def prompt_len_for(self) -> int:
        """Assembled-prompt length minus the query length (the fixed
        part) — lets callers validate capacity before retrieval runs."""
        return (self.system_prefix.size
                + self.top_k * self.index.corpus.chunk_tokens)

    def retrieve(self, query) -> list[tuple[int, float]]:
        """The expensive half on its own: exact top-k search (plus the
        index's modeled payload fetch, if any). Pure function of the
        query — thread-safe over the read-only index, so a scheduler
        can run it on a background I/O worker and ``assemble`` later
        with the ranked result."""
        query = np.asarray(query, np.int32).reshape(-1)
        if query.size < 1:
            raise ValueError("empty query")
        return self.index.search(query, self.top_k)

    def assemble(self, query, *,
                 ranked: list[tuple[int, float]] | None = None
                 ) -> RagPrompt:
        """Retrieve and lay out: ``[system | chunks... | query]``.
        Pass ``ranked`` (a prior ``retrieve`` result for the SAME
        query) to skip the search and only lay out."""
        query = np.asarray(query, np.int32).reshape(-1)
        if query.size < 1:
            raise ValueError("empty query")
        if ranked is None:
            ranked = self.index.search(query, self.top_k)
        if self.canonical_order:
            # ascending chunk id: overlapping retrieval sets become
            # shared leading chunk runs — the shareable-KV layout
            ranked = sorted(ranked, key=lambda t: t[0])
        parts = [self.system_prefix]
        chunks = []
        offset = self.system_prefix.size
        for cid, score in ranked:
            c = self.index.corpus.chunks[cid]
            chunks.append(RetrievedChunk(
                doc=c.doc, idx=c.idx, chunk_id=cid, score=score,
                offset=offset, tokens=c.tokens))
            parts.append(c.tokens)
            offset += c.tokens.size
        parts.append(query)
        return RagPrompt(tokens=np.concatenate(parts).astype(np.int32),
                         chunks=tuple(chunks), query=query)
