"""Substrate package."""
