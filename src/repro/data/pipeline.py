"""Synthetic deterministic data pipeline.

Production properties this substrate actually provides:

  * **Step-keyed determinism**: batch(step) is a pure function of
    (seed, step) — restart/resume at step k reproduces the exact batch
    stream, which the fault-tolerance tests rely on.
  * **Shard-awareness**: batches are produced with the global logical
    shape and device_put against the mesh batch sharding, so each host
    would only materialize its shard in a multi-host deployment
    (here: single host, full array).
  * **LM-shaped distribution**: Zipfian token draw (vocab-scale realistic
    branching factor) rather than uniform noise, so losses/perplexities
    behave qualitatively like text.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2     # Zipf exponent


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int, a: float) -> np.ndarray:
    # inverse-CDF Zipf truncated to vocab (cheap + deterministic)
    u = rng.random(shape)
    ranks = np.clip((u ** (-1.0 / (a - 1.0))), 1, vocab).astype(np.int64)
    # hash ranks into the vocab so ids aren't ordered by frequency
    ids = (ranks * 2654435761) % vocab
    return ids.astype(np.int32)


def make_batch(cfg: ModelConfig, cell: ShapeCell, step: int,
               dcfg: DataConfig = DataConfig(), *,
               batch_override: int | None = None) -> dict:
    """One global batch for `step` (pure function of (seed, step))."""
    rng = np.random.default_rng(np.random.SeedSequence([dcfg.seed, step]))
    b = batch_override or cell.global_batch
    s = cell.seq_len
    tokens = _zipf_tokens(rng, (b, s), cfg.vocab_size, dcfg.zipf_a)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}
    if cfg.family == "audio":
        frames = rng.standard_normal((b, cfg.encoder_seq, cfg.d_model), np.float32)
        batch["frames"] = jnp.asarray(frames, cfg.dtype)
    if cfg.family == "vlm":
        img = rng.standard_normal((b, cfg.num_image_tokens, cfg.d_model), np.float32)
        batch["image_embeds"] = jnp.asarray(img, cfg.dtype)
    return batch


def stream(cfg: ModelConfig, cell: ShapeCell, start_step: int = 0,
           dcfg: DataConfig = DataConfig(), *,
           batch_override: int | None = None) -> Iterator[tuple[int, dict]]:
    step = start_step
    while True:
        yield step, make_batch(cfg, cell, step, dcfg, batch_override=batch_override)
        step += 1


def shard_batch(batch: dict, mesh, minfo) -> dict:
    """device_put the batch against the mesh batch axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    batch_axes = tuple(a for a in minfo.fsdp if a in mesh.axis_names) or None

    def put(x):
        spec = P(batch_axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, batch)
