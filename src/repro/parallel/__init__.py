"""Parallelism substrate: sharding hints + jax mesh-API compat."""

from repro.parallel.compat import AxisType, auto_mesh, make_mesh, shard_map

__all__ = ["AxisType", "auto_mesh", "make_mesh", "shard_map"]
