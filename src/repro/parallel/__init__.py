"""Parallelism substrate: sharding hints."""
