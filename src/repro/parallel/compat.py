"""jax version compatibility for mesh construction.

``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
``jax.make_mesh`` first appeared after jax 0.4.37; this environment pins
0.4.37. Everything in the repo that builds a mesh goes through
``make_mesh`` below, which:

  * accepts ``axis_types`` and forwards it when the installed jax
    supports it,
  * silently drops it otherwise (pre-explicit-axis-type jax treats every
    mesh axis as "auto", which is exactly what all call sites request),
  * exposes an ``AxisType`` alias (the real enum when present, a small
    stand-in enum otherwise) so call sites can still spell
    ``AxisType.Auto`` uniformly.

Keep this the ONLY place that feature-detects the mesh API.
"""

from __future__ import annotations

import enum
import inspect
from typing import Sequence

import jax


class _AxisTypeFallback(enum.Enum):
    """Stand-in for jax.sharding.AxisType on jax versions without it."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypeFallback)

_MAKE_MESH_HAS_AXIS_TYPES = "axis_types" in inspect.signature(
    jax.make_mesh
).parameters


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Sequence | None = None,
    devices=None,
):
    """``jax.make_mesh`` that tolerates jax versions without axis_types."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES:
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across jax versions.

    jax < 0.5 exposes it as ``jax.experimental.shard_map.shard_map`` and
    spells the replication-check kwarg ``check_rep``; newer jax promotes
    it to ``jax.shard_map`` with ``check_vma``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


def auto_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Mesh with every axis in 'auto' sharding mode (the repo default)."""
    return make_mesh(
        axis_shapes,
        axis_names,
        axis_types=(AxisType.Auto,) * len(tuple(axis_names)),
    )
