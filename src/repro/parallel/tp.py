"""Ambient tensor-parallel context for shard_map'ped step programs.

The serving TP design wraps the WHOLE serve/prefill step in one
``shard_map`` (see ``launch/serve.make_tp_spec``) instead of sprinkling
inner shard_maps through the model code. Inside that manual region the
model functions need to know (a) that partial results must be psum'd
over the model axis and (b) which vocab/expert rows the local shard
owns. Threading a "tp" argument through every layer signature would
touch every model family for a serving-only concern, so — exactly like
the execution-plan state in ``kernels/ops`` and the sharding hints in
``parallel/hints`` — the context rides a thread-local that is active
while the shard_map body is being traced.

Every helper is an identity when no context is installed, so the model
code stays single-source: the same ``mlp()``/``attention()`` body runs
un-sharded, under GSPMD auto-partitioning (training), and under manual
shard_map (TP serving). The context is installed even for a size-1
"model" axis (a ``(1, 1)`` host mesh): a size-1 psum is an exact
identity, which is what makes the host-mesh serving path bit-exact
against the solo server while compiling the very same collective
program shape the multi-device mesh runs.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@dataclass(frozen=True)
class TpContext:
    axis: str  # mesh axis name the step is shard_mapped over ("model")
    size: int  # number of shards on that axis


def active() -> TpContext | None:
    """The installed TP context, or None outside shard_map serving."""
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def tensor_parallel(axis: str, size: int):
    """Install the ambient TP context while tracing a shard_map body."""
    prev = active()
    _STATE.ctx = TpContext(axis, int(size))
    try:
        yield
    finally:
        _STATE.ctx = prev


def psum_partial(x):
    """Sum a row-parallel partial over the model axis (identity when no
    TP context is active — the single-source-model contract)."""
    ctx = active()
    if ctx is None:
        return x
    return jax.lax.psum(x, ctx.axis)


def all_gather_cols(x):
    """Gather column-parallel shards along the LAST dim (tiled), so each
    shard leaves with the full-width array. Identity outside TP."""
    ctx = active()
    if ctx is None:
        return x
    return jax.lax.all_gather(x, ctx.axis, axis=x.ndim - 1, tiled=True)


def shard_offset(n_local):
    """Global offset of this shard's slice given its local extent
    (vocab rows, expert ids, ...). 0 outside TP."""
    ctx = active()
    if ctx is None:
        return 0
    return jax.lax.axis_index(ctx.axis) * n_local


def model_only_pspec(pspec) -> P:
    """Project a param/cache PartitionSpec onto the model axis only.

    Serving TP shards exactly one thing — the head/latent ("model")
    axis; batch/fsdp entries from the training-oriented specs are
    dropped (those dims stay replicated across the serving mesh's data
    axis). Tuple entries like ``("pod", "data")`` reduce to their
    "model" member or None.
    """
    entries = []
    for e in tuple(pspec):
        if e == "model":
            entries.append("model")
        elif isinstance(e, (tuple, list)) and "model" in e:
            entries.append("model")
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)
