"""Activation-sharding hints (with_sharding_constraint at hot boundaries).

XLA's SPMD sharding propagation loses the batch sharding of attention
activations through the reshape -> transpose -> scan-slice chain (found
via loop-aware HLO analysis: attention ran batch-REPLICATED over the data
axis — a 16x compute blowup on the production mesh; EXPERIMENTS.md §Perf
iteration 2). Step builders install the mesh here; models call
``constrain`` at layout boundaries. No-op when no mesh is installed
(single-device smoke tests).

Slots: "batch" -> the fsdp/batch axes, "model" -> the TP axis, None.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def sharding_hints(mesh, minfo):
    prev = _current()
    _STATE.ctx = (mesh, minfo) if mesh is not None else None
    try:
        yield
    finally:
        _STATE.ctx = prev


def constrain(x, dims: tuple):
    """dims: per-axis slot names ("batch" | "model" | None)."""
    ctx = _current()
    if ctx is None or x is None:
        return x
    mesh, minfo = ctx
    from repro.models.layers import sanitize_pspec

    entries = []
    for d in dims:
        if d == "batch" or d == "fsdp":
            entries.append(tuple(minfo.fsdp) or None)
        elif d == "model":
            entries.append("model" if "model" in minfo.axis_names else None)
        else:
            entries.append(None)
    spec = sanitize_pspec(mesh, P(*entries), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
