"""Model registry: family -> uniform model API."""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs.base import ModelConfig
from repro.models import rwkv_model, transformer, whisper, zamba


@dataclasses.dataclass(frozen=True)
class ModelApi:
    param_specs: Callable
    init: Callable
    forward: Callable
    loss: Callable
    cache_specs: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    # decode_step accepts a per-row (B,) position vector (RoPE, causal
    # masks, and KV-cache writes key off each row's own position). The
    # continuous-batching scheduler requires this to run ONE batched
    # segment program over slots at unaligned positions; recurrent-state
    # stacks (ssm/hybrid) and the audio decoder only take scalar pos.
    rowwise_decode_pos: bool = False


def _api(mod, *, rowwise_decode_pos: bool = False) -> ModelApi:
    return ModelApi(
        param_specs=mod.param_specs,
        init=mod.init,
        forward=mod.forward,
        loss=mod.loss,
        cache_specs=mod.cache_specs,
        init_cache=mod.init_cache,
        prefill=mod.prefill,
        decode_step=mod.decode_step,
        rowwise_decode_pos=rowwise_decode_pos,
    )


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "hybrid":
        return _api(zamba)
    if cfg.family == "ssm":
        return _api(rwkv_model)
    if cfg.family == "audio":
        return _api(whisper)
    # dense / moe / vlm all route through the generic transformer
    return _api(transformer, rowwise_decode_pos=True)
