"""Whisper-medium backbone: encoder-decoder transformer.

The conv audio frontend is a STUB per the assignment — ``input_specs``
provides precomputed frame embeddings (B, encoder_seq, D). The encoder is
a bidirectional transformer (GELU MLP); the decoder adds causal self-attn
and cross-attn to the encoder memory. Pre-LN blocks, learned-sinusoid-free
(rope used for decoder self-attn positions; encoder uses its own rope —
a documented deviation from Whisper's learned absolute embeddings that
keeps the backbone uniform; FLOP/byte-identical for roofline purposes).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.function_table import DEFAULT_TABLE
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models.layers import MeshInfo, ParamSpec, _maybe
from repro.models.mlp import mlp, mlp_param_specs

Array = jax.Array


def _enc_block_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    return {
        "attn_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "mlp_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "attn": attn_lib.gqa_param_specs(cfg, m),
        "mlp": mlp_param_specs(cfg, m),
    }


def _dec_block_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    specs = _enc_block_specs(cfg, m)
    specs["xattn"] = attn_lib.gqa_param_specs(cfg, m)
    specs["xattn_norm"] = ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones")
    return specs


def param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    fsdp = tuple(m.fsdp) or None
    return {
        "embed": ParamSpec((L.padded_vocab(cfg.vocab_size), cfg.d_model),
                           cfg.dtype, _maybe(m, "model", fsdp), "embed"),
        "enc_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "dec_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "encoder": L.stack_specs(_enc_block_specs(cfg, m), cfg.encoder_layers),
        "decoder": L.stack_specs(_dec_block_specs(cfg, m), cfg.num_layers),
    }


def init(key, cfg: ModelConfig, m: MeshInfo = L.HOST) -> dict:
    return L.materialize(key, param_specs(cfg, m))


def _remat(fn, cfg):
    return fn if cfg.remat == "none" else jax.checkpoint(fn)


def encode(params, cfg: ModelConfig, frames: Array, *, table=DEFAULT_TABLE):
    """frames (B, T_enc, D) — stub frontend output."""
    b, t, d = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
    x = frames.astype(cfg.dtype)

    def body(x, p_l):
        h = L.rms_norm(x, p_l["attn_norm"], cfg.norm_eps)
        a, _ = attn_lib.gqa_attention(p_l["attn"], cfg, h, positions,
                                      causal=False)
        x = x + a
        h = L.rms_norm(x, p_l["mlp_norm"], cfg.norm_eps)
        return x + mlp(p_l["mlp"], cfg, h, table=table), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decode_stack(params, cfg, x, positions, memory, *, table,
                  caches=None, cache_pos=None):
    def body(x, xs):
        p_l, c_l = xs
        h = L.rms_norm(x, p_l["attn_norm"], cfg.norm_eps)
        a, nc = attn_lib.gqa_attention(
            p_l["attn"], cfg, h, positions, cache=c_l, cache_pos=cache_pos,
        )
        x = x + a
        h = L.rms_norm(x, p_l["xattn_norm"], cfg.norm_eps)
        xa, _ = attn_lib.gqa_attention(
            p_l["xattn"], cfg, h, positions, causal=False, memory=memory,
        )
        x = x + xa
        h = L.rms_norm(x, p_l["mlp_norm"], cfg.norm_eps)
        return x + mlp(p_l["mlp"], cfg, h, table=table), nc

    x, new_caches = jax.lax.scan(
        _remat(body, cfg), x, (params["decoder"], caches),
    )
    return L.rms_norm(x, params["dec_norm"], cfg.norm_eps), new_caches


def forward(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
            minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    """batch: {"tokens": (B,S) decoder tokens, "frames": (B,T_enc,D)}."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    memory = encode(params, cfg, batch["frames"], table=table)
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, _ = _decode_stack(params, cfg, x, positions, memory, table=table)
    return L.unembed(x, params["embed"])


def loss(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
         minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    logits = forward(params, cfg, batch, table=table, minfo=minfo, mesh=mesh)
    return L.softmax_cross_entropy(
        logits[:, :-1, :].reshape(-1, logits.shape[-1]),
        batch["labels"][:, 1:].reshape(-1),
        vocab=cfg.vocab_size,
    )


def cache_specs(cfg: ModelConfig, m: MeshInfo, batch: int, max_len: int) -> dict:
    return attn_lib.kv_cache_specs(cfg, m, batch, max_len, cfg.num_layers)


def init_cache(cfg, m, batch, max_len):
    return L.materialize(jax.random.PRNGKey(0), cache_specs(cfg, m, batch, max_len))


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict, *,
            table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST, mesh=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    memory = encode(params, cfg, batch["frames"], table=table)
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, new_cache = _decode_stack(
        params, cfg, x, positions, memory, table=table,
        caches=cache, cache_pos=jnp.int32(0),
    )
    return L.unembed(x[:, -1:, :], params["embed"]), new_cache


def decode_step(params, cfg: ModelConfig, tokens: Array, cache: dict,
                pos: Array, *, table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST,
                mesh=None, memory: Array | None = None):
    """memory: precomputed encoder output (B, T_enc, D)."""
    b = tokens.shape[0]
    if memory is None:
        raise ValueError("whisper decode needs the encoder memory")
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    x, new_cache = _decode_stack(
        params, cfg, x, positions, memory, table=table,
        caches=cache, cache_pos=pos,
    )
    return L.unembed(x, params["embed"]), new_cache
