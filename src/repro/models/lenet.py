"""LeNet-style CIFAR-10 CNN — the paper's own workload (§5.2, Figure 4).

Two conv layers (each followed by activation + pooling) and three fully
connected layers with activations in between — "adapted from one in the
Pytorch documentation" exactly as the paper did. This model is what the
paper-figure benchmarks (Figs. 6-8, Table 3) run through the engine in
all three modes.

``to_layer_graphs`` emits the static/flexible IR:
  * Monolithic = the whole net in one LayerGraph (one accelerator).
  * Small primitives S1..S5 (Figure 4) = the 5 static chains the
    FLEXIBLE_DMA / SIDEBAR segmentation produces — conv1, conv2, fc1,
    fc2, fc3 with activations (and pools) between them on the host.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.modes import FlexibleOp, LayerGraph, StaticOp

Array = jax.Array

# Paper's LeNet (pytorch CIFAR-10 tutorial): conv(3->6,k5) pool conv(6->16,k5)
# pool fc(400->120) fc(120->84) fc(84->10).
CONV1 = dict(cin=3, cout=6, k=5)
CONV2 = dict(cin=6, cout=16, k=5)
FC1 = (16 * 5 * 5, 120)
FC2 = (120, 84)
FC3 = (84, 10)
IMG = 32


def init(key: Array, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)

    def conv_w(k, c):
        w = jax.random.normal(k, (c["cout"], c["cin"], c["k"], c["k"]), dtype)
        return w / math.sqrt(c["cin"] * c["k"] * c["k"])

    def fc_w(k, shape):
        return jax.random.normal(k, shape, dtype) / math.sqrt(shape[0])

    return {
        "conv1": conv_w(ks[0], CONV1),
        "conv2": conv_w(ks[1], CONV2),
        "fc1": fc_w(ks[2], FC1),
        "fc2": fc_w(ks[3], FC2),
        "fc3": fc_w(ks[4], FC3),
    }


def _conv(w: Array, x: Array) -> Array:
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def _pool(x: Array) -> Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _flatten(w_unused: Array, x: Array) -> Array:
    return x.reshape(x.shape[0], -1)


def _fc(w: Array, x: Array) -> Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def forward(params: dict, x: Array, activation, *, pool=_pool) -> Array:
    """Plain forward (oracle for engine-mode equivalence tests)."""
    x = pool(activation(_conv(params["conv1"], x)))
    x = pool(activation(_conv(params["conv2"], x)))
    x = x.reshape(x.shape[0], -1)
    x = activation(_fc(params["fc1"], x))
    x = activation(_fc(params["fc2"], x))
    return _fc(params["fc3"], x)


def _conv_flops(c, hout: int, wout: int, batch: int) -> int:
    return 2 * batch * c["cout"] * c["cin"] * c["k"] * c["k"] * hout * wout


def to_layer_graphs(batch: int, activation: str = "relu",
                    itemsize: int = 4) -> list[LayerGraph]:
    """The paper's Figure-4 decomposition as engine IR (one task here —
    segmentation into S1..S5 happens per execution mode in the engine)."""
    h1 = IMG - CONV1["k"] + 1            # 28
    p1 = h1 // 2                          # 14
    h2 = p1 - CONV2["k"] + 1              # 10
    p2 = h2 // 2                          # 5

    ops = (
        StaticOp("conv1", _conv, (batch, CONV1["cout"], h1, h1),
                 flops=_conv_flops(CONV1, h1, h1, batch),
                 weight_bytes=CONV1["cout"] * CONV1["cin"] * 25 * itemsize),
        FlexibleOp(activation, (batch, CONV1["cout"], h1, h1)),
        FlexibleOp("max_pool", (batch, CONV1["cout"], p1, p1)),
        StaticOp("conv2", _conv, (batch, CONV2["cout"], h2, h2),
                 flops=_conv_flops(CONV2, h2, h2, batch),
                 weight_bytes=CONV2["cout"] * CONV2["cin"] * 25 * itemsize),
        FlexibleOp(activation, (batch, CONV2["cout"], h2, h2)),
        FlexibleOp("max_pool", (batch, CONV2["cout"], p2, p2)),
        StaticOp("flatten", _flatten, (batch, FC1[0]), flops=0, weight_bytes=0),
        StaticOp("fc1", _fc, (batch, FC1[1]),
                 flops=2 * batch * FC1[0] * FC1[1],
                 weight_bytes=FC1[0] * FC1[1] * itemsize),
        FlexibleOp(activation, (batch, FC1[1])),
        StaticOp("fc2", _fc, (batch, FC2[1]),
                 flops=2 * batch * FC2[0] * FC2[1],
                 weight_bytes=FC2[0] * FC2[1] * itemsize),
        FlexibleOp(activation, (batch, FC2[1])),
        StaticOp("fc3", _fc, (batch, FC3[1]),
                 flops=2 * batch * FC3[0] * FC3[1],
                 weight_bytes=FC3[0] * FC3[1] * itemsize),
    )
    return [LayerGraph("lenet", ops, (batch, 3, IMG, IMG), itemsize)]


def engine_params(params: dict) -> dict:
    """Map model params onto LayerGraph StaticOp names."""
    return {
        "conv1": params["conv1"],
        "conv2": params["conv2"],
        "flatten": jnp.zeros(()),
        "fc1": params["fc1"],
        "fc2": params["fc2"],
        "fc3": params["fc3"],
    }


def register_pooling(table) -> None:
    """The pooling layers are flexible (host) ops in the paper's Figure 4."""
    if "max_pool" not in table:
        table.register("max_pool", _pool)
