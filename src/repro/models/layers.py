"""Shared building blocks: param specs, norms, rope, embeddings, linears.

Parameters are plain nested dicts. Every leaf is declared as a ``ParamSpec``
(shape, dtype, PartitionSpec) so the same tree drives:

  * ``materialize``  — RNG init for smoke tests / real training,
  * ``abstract``     — ShapeDtypeStruct stand-ins for the dry-run
                        (no allocation at 405B scale),
  * ``shardings``    — NamedSharding tree for pjit in_shardings.

Sharding convention (see DESIGN.md §5): ``fsdp`` axes = ("pod","data")
when present — parameters are sharded over them and all-gathered by the
XLA SPMD partitioner at use (ZeRO-3); "model" is Megatron-style TP.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    dtype: Any
    pspec: P  # PartitionSpec over the production mesh axes
    init: str = "normal"  # "normal" | "zeros" | "ones" | "embed"


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    """Which mesh axes exist, how they're used, and how big they are."""

    axis_names: tuple[str, ...]
    fsdp: tuple[str, ...]   # parameter/optimizer sharding axes ("pod","data")
    tp: str = "model"       # tensor-parallel axis
    sizes: tuple[tuple[str, int], ...] = ()

    @classmethod
    def from_axes(cls, axis_names: tuple[str, ...],
                  sizes: dict[str, int] | None = None) -> "MeshInfo":
        fsdp = tuple(a for a in ("pod", "data") if a in axis_names)
        size_map = tuple(sorted((sizes or {}).items()))
        return cls(tuple(axis_names), fsdp, sizes=size_map)

    def size(self, axes) -> int:
        """Product of the sizes of `axes` (1 for unknown axes)."""
        m = dict(self.sizes)
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= m.get(a, 1)
        return n

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return self.fsdp  # data parallel over the same axes


SINGLE_POD = MeshInfo.from_axes(("data", "model"))
MULTI_POD = MeshInfo.from_axes(("pod", "data", "model"))
HOST = MeshInfo.from_axes(())  # single-device smoke tests: fully replicated


def _maybe(minfo: MeshInfo, *axes):
    """Build a PartitionSpec entry, dropping axes absent from the mesh."""
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        elif isinstance(a, tuple):
            present = tuple(x for x in a if x in minfo.axis_names)
            out.append(present if present else None)
        else:
            out.append(a if a in minfo.axis_names else None)
    return P(*out)


# ---------------------------------------------------------------------------
# Tree utilities.
# ---------------------------------------------------------------------------

def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def materialize(key: Array, tree, scale_override: float | None = None):
    """Initialize every ParamSpec leaf with its declared initializer."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        # second-to-last dim is the contraction (fan-in) dim; leading dims
        # are layer-stack / expert dims and must not affect the scale.
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        elif spec.init == "embed":
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * 0.02).astype(spec.dtype)
        else:
            std = scale_override or (1.0 / math.sqrt(fan_in))
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract(tree):
    """ShapeDtypeStruct stand-ins (dry-run: no device allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree, is_leaf=is_spec
    )


def sanitize_pspec(mesh: Mesh, pspec: P, shape: tuple[int, ...]) -> P:
    """Drop axis assignments whose dimension isn't divisible by the axis
    size on this mesh (e.g. batch=1 on a 16-way data axis). The safety
    net behind every explicit in_sharding."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        out.append(entry if (n and dim % n == 0) else None)
    return P(*out)


def shardings(mesh: Mesh, tree):
    """NamedSharding tree matching the ParamSpec tree (divisibility-safe)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, sanitize_pspec(mesh, s.pspec, s.shape)),
        tree, is_leaf=is_spec,
    )


def param_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def stack_specs(tree, n: int, axis_name=None):
    """Stack a per-layer spec tree n times (scan-over-layers layout).

    The leading (layer) dimension is never sharded.
    """
    def stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n, *s.shape), s.dtype, P(None, *s.pspec), s.init)

    return jax.tree.map(stack, tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Numerics.
# ---------------------------------------------------------------------------

def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    """RMSNorm — a *flexible* op (rowwise) in the sidebar decomposition."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, weight: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """Rotary embedding. x: (..., S, H, Dh) or (..., S, Dh); positions (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if x.ndim == angles.ndim + 1:                      # head dim present
        angles = angles[..., None, :]                  # (..., S, 1, Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., ::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def linear(x: Array, w: Array) -> Array:
    """x (..., D) @ w (D, F) — a *static* primitive.

    bf16 inputs keep a bf16 dot OUTPUT (the MXU still accumulates fp32
    internally): under tensor parallelism the partial-sum all-reduce then
    moves bf16, not fp32 — this halved the TP collective bytes on the
    llama3-405b train cell (EXPERIMENTS.md §Perf iteration 4). fp32
    inputs keep explicit fp32 accumulation.
    """
    if x.dtype == jnp.float32:
        return jnp.dot(x, w, preferred_element_type=jnp.float32)
    return jnp.dot(x, w, preferred_element_type=x.dtype)


VOCAB_PAD = 16  # embeddings padded so the vocab dim shards over "model"


def padded_vocab(v: int) -> int:
    """Megatron-style vocab padding to the TP degree (16 on both meshes)."""
    return -(-v // VOCAB_PAD) * VOCAB_PAD


def embed_lookup(table: Array, tokens: Array, *, sharded: bool = False) -> Array:
    """Embedding lookup.

    Sharded (vocab-parallel) path: one-hot einsum — the SPMD partitioner
    turns it into a local contraction + psum instead of the 'involuntary
    full rematerialization' (whole-table all-gather) a sharded gather
    triggers. Unsharded path: plain take().

    Under ambient TP (manual shard_map) the local table holds vocab rows
    [offset, offset + V_local): ids are rebased, off-shard ids one-hot to
    all-zero rows, and the fp32 partials are psum'd BEFORE the dtype
    cast — summing exact zeros with one exact row keeps the lookup
    bit-identical to the unsharded take() at any shard count.
    """
    if not sharded:
        return jnp.take(table, tokens, axis=0)
    from repro.parallel import tp

    ids = tokens - tp.shard_offset(table.shape[0]) \
        if tp.active() else tokens
    onehot = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
    out = jnp.einsum("...v,vd->...d", onehot, table,
                     preferred_element_type=jnp.float32)
    return tp.psum_partial(out).astype(table.dtype)


def unembed(x: Array, table: Array) -> Array:
    """Logits = x @ E^T (tied); fp32 out; width = padded vocab.

    Under ambient TP the table holds a vocab-row shard, so the local dot
    yields a column slice of the logits — exact per column, the
    contraction dim d is never split — which the tiled all-gather
    reassembles to full width once per step (identity outside TP).
    """
    from repro.parallel import tp

    return tp.all_gather_cols(
        jnp.dot(x, table.T, preferred_element_type=jnp.float32))


def mask_pad_logits(logits: Array, vocab: int) -> Array:
    """-inf the padded vocab columns (zero-init rows would otherwise bias
    softmax mass / argmax)."""
    if logits.shape[-1] == vocab:
        return logits
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(cols < vocab, logits, -1e30)


def softmax_cross_entropy(logits: Array, labels: Array,
                          vocab: int | None = None) -> Array:
    """Mean token NLL; logits fp32 (T, V_pad), labels int (T,)."""
    logits = logits.astype(jnp.float32)
    if vocab is not None:
        logits = mask_pad_logits(logits, vocab)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
