"""Generic transformer LM (dense / GQA / MLA / MoE / VLM) with
scan-over-layers, remat, KV caches, and the uniform model API.

Model API (shared by every arch family; see registry.py):

  param_specs(cfg, minfo)                     -> ParamSpec tree
  init(key, cfg, minfo)                       -> params
  forward(params, cfg, batch, ...)            -> logits (B,S,V) [training]
  loss(params, cfg, batch, ...)               -> scalar NLL
  cache_specs(cfg, minfo, batch, max_len)     -> cache ParamSpec tree
  prefill(params, cfg, batch, cache, ...)     -> (logits_last, cache)
  decode_step(params, cfg, tokens, cache, pos, ...) -> (logits, cache)

Layer stacking: homogeneous layers are scanned (params stacked on a
leading L dim — HLO size is depth-independent); heterogeneous archs scan
over *uniform groups* (VLM: [4 self + 1 cross] × G). Remat policy wraps
the scanned body (cfg.remat: full | dots | none).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.function_table import DEFAULT_TABLE
from repro.kernels import ops as kops
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.layers import MeshInfo, ParamSpec, _maybe
from repro.models.mlp import mlp, mlp_param_specs

Array = jax.Array


# ---------------------------------------------------------------------------
# Param specs.
# ---------------------------------------------------------------------------

def _block_specs(cfg: ModelConfig, m: MeshInfo, *, kind: str) -> dict:
    """One decoder block. kind: dense | moe | cross."""
    specs = {
        "attn_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "mlp_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "attn": attn_lib.attn_param_specs(cfg, m),
    }
    if kind == "moe":
        specs["moe"] = moe_lib.moe_param_specs(cfg, m)
    else:
        specs["mlp"] = mlp_param_specs(cfg, m)
    if kind == "cross":
        # gated cross-attention (llama-3.2-vision style: tanh gates)
        specs["xattn"] = attn_lib.gqa_param_specs(cfg, m)
        specs["xattn_norm"] = ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones")
        specs["xattn_gate"] = ParamSpec((1,), jnp.float32, _maybe(m, None), "zeros")
        specs["xmlp_gate"] = ParamSpec((1,), jnp.float32, _maybe(m, None), "zeros")
    return specs


def _layer_plan(cfg: ModelConfig) -> list[tuple[str, int]]:
    """[(scan_group_kind, count)]: how layers stack into scans."""
    if cfg.family == "vlm" and cfg.cross_attn_every:
        n_groups = cfg.num_layers // cfg.cross_attn_every
        return [("vlm_group", n_groups)]
    if cfg.num_experts:
        plan = []
        if cfg.first_dense_layers:
            plan.append(("dense", cfg.first_dense_layers))
        plan.append(("moe", cfg.num_layers - cfg.first_dense_layers))
        return plan
    return [("dense", cfg.num_layers)]


def param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    fsdp = tuple(m.fsdp) or None
    specs: dict[str, Any] = {
        "embed": ParamSpec((L.padded_vocab(cfg.vocab_size), cfg.d_model),
                           cfg.dtype, _maybe(m, "model", fsdp), "embed"),
        "final_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "blocks": {},
    }
    for kind, count in _layer_plan(cfg):
        if kind == "vlm_group":
            n_self = cfg.cross_attn_every - 1
            group = {
                "self": L.stack_specs(_block_specs(cfg, m, kind="dense"), n_self),
                "cross": _block_specs(cfg, m, kind="cross"),
            }
            specs["blocks"][kind] = L.stack_specs(group, count)
        else:
            specs["blocks"][kind] = L.stack_specs(
                _block_specs(cfg, m, kind=kind), count
            )
    return specs


def init(key: Array, cfg: ModelConfig, m: MeshInfo = L.HOST) -> dict:
    return L.materialize(key, param_specs(cfg, m))


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def _decoder_block(p, cfg, x, positions, *, kind, table, minfo, mesh,
                   cache=None, cache_pos=None, memory=None,
                   block_tables=None):
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)       # flexible
    a, new_cache = attn_lib.attention(
        p["attn"], cfg, h, positions, cache=cache, cache_pos=cache_pos,
        block_tables=block_tables,
    )
    x = x + a
    if kind == "cross" and memory is not None:
        h = L.rms_norm(x, p["xattn_norm"], cfg.norm_eps)
        xa, _ = attn_lib.gqa_attention(
            p["xattn"], cfg, h, positions, causal=False, memory=memory,
        )
        x = x + jnp.tanh(p["xattn_gate"]).astype(x.dtype) * xa
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)        # flexible
    if kind == "moe":
        y = moe_lib.moe(p["moe"], cfg, h, table=table, minfo=minfo, mesh=mesh)
    else:
        y = mlp(p["mlp"], cfg, h, table=table)
    if kind == "cross" and memory is not None:
        y = jnp.tanh(p["xmlp_gate"]).astype(x.dtype) * y
    return x + y, new_cache


def _boundary(x, cfg: ModelConfig):
    """Layer-boundary activation sharding (the scan carry = what remat
    saves for bwd). Levers (see EXPERIMENTS.md §Perf):
      * seq_shard_acts: shard the sequence dim over "model" — saved
        checkpoints shrink TP-fold (Megatron-SP at boundaries);
      * tp_activations: weight-stationary TP — shard d_model over the
        fsdp axes so weight matmuls contract locally (activation psums
        replace per-microbatch FSDP weight all-gathers)."""
    from repro.parallel.hints import constrain

    if cfg.tp_activations and cfg.seq_shard_acts:
        return constrain(x, (None, "model", "fsdp"))
    if cfg.tp_activations:
        return constrain(x, (None, None, "fsdp"))
    if cfg.seq_shard_acts:
        return constrain(x, ("batch", "model", None))
    return x


def _unboundary(x, cfg: ModelConfig):
    """Restore batch-sharded layout before the unembed projection."""
    from repro.parallel.hints import constrain

    if cfg.tp_activations or cfg.seq_shard_acts:
        return constrain(x, ("batch", None, None))
    return x


def _run_stack(params, cfg, x, positions, *, table, minfo, mesh,
               caches=None, cache_pos=None, memory=None, block_tables=None):
    """Run every scan group in the layer plan. caches mirrors blocks.

    ``layer_base`` tracks the global layer index across scan groups so an
    unrolled stack (``cfg.scan_layers=False``) can announce each layer to
    ``kernels.ops.layer_scope`` — that is how a layer-indexed
    ``ExecutionPlan`` reaches a different kernel variant per layer. A
    scanned stack traces its body once and necessarily runs the plan
    default for every layer; the same holds for vlm groups, which ALWAYS
    scan (the vlm branch below never unrolls and never enters
    ``layer_scope``, so a layer-indexed plan resolves to its default
    there — ``launch.serve.Server`` only unrolls heterogeneous plans for
    the dense/moe families that reach the unrolled branch).
    """
    new_caches: dict[str, Any] = {}
    x = _boundary(x, cfg)
    layer_base = 0
    for kind, count in _layer_plan(cfg):
        p_stack = params["blocks"][kind]
        c_stack = caches.get(kind) if caches else None

        if kind == "vlm_group":
            def cross_body(x, p_cross, c_cross):
                return _decoder_block(
                    p_cross, cfg, x, positions, kind="cross", table=table,
                    minfo=minfo, mesh=mesh, memory=memory,
                    cache=c_cross, cache_pos=cache_pos,
                    block_tables=block_tables,
                )

            def group_body(x, xs):
                p_g, c_g = xs

                def self_body(x, xs_inner):
                    p_l, c_l = xs_inner
                    y, nc = _decoder_block(
                        p_l, cfg, x, positions, kind="dense", table=table,
                        minfo=minfo, mesh=mesh, cache=c_l, cache_pos=cache_pos,
                        block_tables=block_tables,
                    )
                    return y, nc

                c_self = c_g["self"] if c_g else None
                x, nc_self = jax.lax.scan(
                    _remat(self_body, cfg), x, (p_g["self"], c_self),
                )
                y, nc_cross = _remat(cross_body, cfg)(
                    x, p_g["cross"], c_g["cross"] if c_g else None,
                )
                return y, {"self": nc_self, "cross": nc_cross}

            if cfg.cache_in_carry and c_stack is not None:
                # carry the full (G, ...) cache tree; update group g's
                # slice in place (same aliasing win as the dense branch).
                def group_carry_body(carry, p_g):
                    x, cache_full, g = carry
                    c_g = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, g, 0, keepdims=False), cache_full,
                    )
                    y, nc_g = group_body_inner(x, p_g, c_g)
                    cache_full = jax.tree.map(
                        lambda a, u: jax.lax.dynamic_update_index_in_dim(
                            a, u.astype(a.dtype), g, 0),
                        cache_full, nc_g,
                    )
                    return (y, cache_full, g + 1), None

                def group_body_inner(x, p_g, c_g):
                    def self_body(x, xs_inner):
                        p_l, c_l = xs_inner
                        y, nc = _decoder_block(
                            p_l, cfg, x, positions, kind="dense", table=table,
                            minfo=minfo, mesh=mesh, cache=c_l,
                            cache_pos=cache_pos, block_tables=block_tables,
                        )
                        return _boundary(y, cfg), nc

                    x, nc_self = jax.lax.scan(
                        _remat(self_body, cfg), x, (p_g["self"], c_g["self"]),
                    )
                    y, nc_cross = _remat(cross_body, cfg)(
                        x, p_g["cross"], c_g["cross"],
                    )
                    return _boundary(y, cfg), {"self": nc_self,
                                               "cross": nc_cross}

                (x, nc, _), _ = jax.lax.scan(
                    group_carry_body, (x, c_stack, jnp.int32(0)), p_stack,
                )
            else:
                x, nc = jax.lax.scan(
                    group_body, x,
                    (p_stack, c_stack) if c_stack is not None else (p_stack, None),
                )
            new_caches[kind] = nc
        else:
            def body(x, xs, kind=kind):
                p_l, c_l = xs
                y, nc = _decoder_block(
                    p_l, cfg, x, positions, kind=kind, table=table,
                    minfo=minfo, mesh=mesh, cache=c_l, cache_pos=cache_pos,
                    block_tables=block_tables,
                )
                return _boundary(y, cfg), nc

            if cfg.scan_layers and cfg.cache_in_carry and c_stack is not None:
                # cache in the CARRY, updated in place per layer: XLA can
                # alias the (donated) cache buffer through the loop instead
                # of restacking ys (which doubles peak memory on decode —
                # EXPERIMENTS.md §Perf, deepseek-7b decode_32k iteration).
                def carry_body(carry, p_l, kind=kind):
                    x, cache_full, idx = carry
                    c_l = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, idx, 0, keepdims=False), cache_full,
                    )
                    y, nc = _decoder_block(
                        p_l, cfg, x, positions, kind=kind, table=table,
                        minfo=minfo, mesh=mesh, cache=c_l,
                        cache_pos=cache_pos, block_tables=block_tables,
                    )
                    cache_full = jax.tree.map(
                        lambda a, u: jax.lax.dynamic_update_index_in_dim(
                            a, u.astype(a.dtype), idx, 0),
                        cache_full, nc,
                    )
                    return (_boundary(y, cfg), cache_full, idx + 1), None

                (x, nc, _), _ = jax.lax.scan(
                    lambda c, p: _remat(carry_body, cfg)(c, p),
                    (x, c_stack, jnp.int32(0)), p_stack,
                )
            elif cfg.scan_layers:
                x, nc = jax.lax.scan(
                    _remat(body, cfg), x,
                    (p_stack, c_stack) if c_stack is not None else (p_stack, None),
                )
            else:
                ncs = []
                for i in range(count):
                    p_l = jax.tree.map(lambda a: a[i], p_stack)
                    c_l = jax.tree.map(lambda a: a[i], c_stack) if c_stack else None
                    with kops.layer_scope(layer_base + i):
                        x, nc_i = body(x, (p_l, c_l))
                    ncs.append(nc_i)
                nc = (
                    jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
                    if ncs and ncs[0] is not None else None
                )
            new_caches[kind] = nc
        layer_base += count * (
            cfg.cross_attn_every if kind == "vlm_group" else 1
        )
    return x, new_caches


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
            minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    """Training forward: batch {"tokens": (B,S) [, "image_embeds"]}."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    memory = batch.get("image_embeds")
    x, _ = _run_stack(params, cfg, x, positions, table=table, minfo=minfo,
                      mesh=mesh, memory=memory)
    x = _unboundary(x, cfg)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"])


def loss(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
         minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    logits = forward(params, cfg, batch, table=table, minfo=minfo, mesh=mesh)
    return L.softmax_cross_entropy(
        logits[:, :-1, :].reshape(-1, logits.shape[-1]),
        batch["labels"][:, 1:].reshape(-1),
        vocab=cfg.vocab_size,
    )


def cache_specs(cfg: ModelConfig, m: MeshInfo, batch: int, max_len: int) -> dict:
    out: dict[str, Any] = {}
    for kind, count in _layer_plan(cfg):
        if kind == "vlm_group":
            n_self = cfg.cross_attn_every - 1
            out[kind] = {
                "self": attn_lib.kv_cache_specs(
                    cfg, m, batch, max_len, count * n_self
                ),
                "cross": attn_lib.kv_cache_specs(cfg, m, batch, max_len, count),
            }
            # reshape leading (G*n,...) -> (G, n, ...) for the nested scan
            out[kind]["self"] = jax.tree.map(
                lambda sp: ParamSpec((count, n_self, *sp.shape[1:]), sp.dtype,
                                     _maybe(m, None, *sp.pspec), sp.init),
                out[kind]["self"], is_leaf=L.is_spec,
            )
            out[kind]["cross"] = jax.tree.map(
                lambda sp: ParamSpec((count, *sp.shape[1:]), sp.dtype,
                                     sp.pspec, sp.init),
                out[kind]["cross"], is_leaf=L.is_spec,
            )
        else:
            out[kind] = attn_lib.kv_cache_specs(cfg, m, batch, max_len, count)
    return out


def init_cache(cfg: ModelConfig, m: MeshInfo, batch: int, max_len: int) -> dict:
    return L.materialize(jax.random.PRNGKey(0), cache_specs(cfg, m, batch, max_len))


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict, *,
            table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST, mesh=None,
            cache_pos=None, block_tables=None, all_logits: bool = False):
    """Write the prompt's KV. ``cache_pos`` (default 0) is the position
    of the chunk's first token — chunked prefill runs this repeatedly
    with advancing offsets (scalar, or per-row ``(B,)`` for staged rows
    at unaligned frontiers); RoPE, the causal mask, and the KV writes
    all key off it. ``block_tables`` (B, nb) routes the writes through
    the paged KV pool instead of a dense slab. ``all_logits`` returns
    logits at EVERY chunk position (B, S, V) instead of the last only —
    the speculative-decode verifier needs the target's prediction after
    each drafted token; default off keeps the (B, 1, V) shape and
    skips the S-wide unembed for every existing caller."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    if cache_pos is None:
        cache_pos = jnp.int32(0)
    if attn_lib.rowwise_pos(cache_pos):
        positions = cache_pos[:, None] + jnp.arange(s)[None, :]
    else:
        positions = jnp.broadcast_to(cache_pos + jnp.arange(s)[None, :],
                                     (b, s))
    x, new_cache = _run_stack(
        params, cfg, x, positions, table=table, minfo=minfo, mesh=mesh,
        caches=cache, cache_pos=cache_pos, block_tables=block_tables,
        memory=batch.get("image_embeds"),
    )
    x = _unboundary(x, cfg)
    if not all_logits:
        x = x[:, -1:, :]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"]), new_cache


def decode_step(params, cfg: ModelConfig, tokens: Array, cache: dict,
                pos: Array, *, table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST,
                mesh=None, memory: Array | None = None, block_tables=None):
    """One token: tokens (B, 1), pos int32 — scalar (whole batch at one
    length) or per-row ``(B,)`` (batched slots at unaligned positions:
    RoPE, causal masks, and KV writes all key off each row's own
    position — see ``attention.rowwise_pos``). With ``block_tables``
    (B, nb) the cache is the paged KV pool and reads/writes go through
    each row's table (``attention`` gathers the dense view; the
    contiguous slab fast path is untouched when tables are absent)."""
    b = tokens.shape[0]
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    if attn_lib.rowwise_pos(pos):
        positions = pos[:, None]
    else:
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
    x, new_cache = _run_stack(
        params, cfg, x, positions, table=table, minfo=minfo, mesh=mesh,
        caches=cache, cache_pos=pos, memory=memory,
        block_tables=block_tables,
    )
    x = _unboundary(x, cfg)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"]), new_cache
