"""Mamba2 (SSD) blocks — the zamba2 backbone.

Sidebar decomposition: the chunked SSD algorithm is built from *static*
tensor contractions (the intra-chunk (CBᵀ⊙L)X matmuls and the inter-chunk
state einsums — all MXU work), while the *flexible* ops are exactly the
fast-evolving nonlinearities: softplus(dt), exp decays, SiLU gates, and
the gated RMSNorm. These come from the function table.

Chunked SSD recurrence (chunk length Q, per head, state N, head dim P):

  a_t = exp(dt_t · A)            L_t = Σ_{s≤t} log a_s   (cumsum in chunk)
  h_t = a_t h_{t-1} + dt_t B_t ⊗ x_t          y_t = C_t · h_t + D x_t

  intra:  y⁺_t = Σ_{s≤t} (C_t·B_s) e^{L_t-L_s} dt_s x_s
  inter:  y°_t = e^{L_t} (C_t · h_chunk_start)
  state:  h' = e^{L_Q} h + Σ_s e^{L_Q-L_s} dt_s B_s ⊗ x_s

The chunk loop is a ``lax.scan`` (carries the (B,H,N,P) state), so HLO
size is depth-independent and decode is the single-step special case.

Sharding: heads (and d_inner) are TP-sharded over "model"; B/C (shared
across heads, ngroups=1) are replicated; out_proj contracts the sharded
d_inner (psum by XLA).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import MeshInfo, ParamSpec, _maybe, linear, rms_norm

Array = jax.Array

CONV_K = 4  # causal depthwise conv kernel width


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    """(d_inner, n_heads, head_dim)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    return d_inner, d_inner // cfg.ssm_head_dim, cfg.ssm_head_dim


def mamba2_param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    d, n = cfg.d_model, cfg.ssm_state
    d_in, h, p = ssm_dims(cfg)
    dt = cfg.dtype
    fsdp = tuple(m.fsdp) or None
    tp = "model"
    return {
        "in_x": ParamSpec((d, d_in), dt, _maybe(m, fsdp, tp)),
        "in_z": ParamSpec((d, d_in), dt, _maybe(m, fsdp, tp)),
        "in_B": ParamSpec((d, n), dt, _maybe(m, fsdp, None)),
        "in_C": ParamSpec((d, n), dt, _maybe(m, fsdp, None)),
        "in_dt": ParamSpec((d, h), dt, _maybe(m, fsdp, tp)),
        "conv_x": ParamSpec((CONV_K, d_in), dt, _maybe(m, None, tp)),
        "conv_B": ParamSpec((CONV_K, n), dt, P_none()),
        "conv_C": ParamSpec((CONV_K, n), dt, P_none()),
        "a_log": ParamSpec((h,), jnp.float32, _maybe(m, tp), "ones"),
        "d_skip": ParamSpec((h,), jnp.float32, _maybe(m, tp), "ones"),
        "dt_bias": ParamSpec((h,), jnp.float32, _maybe(m, tp), "zeros"),
        "norm": ParamSpec((d_in,), dt, _maybe(m, tp), "ones"),
        "out": ParamSpec((d_in, d), dt, _maybe(m, tp, fsdp)),
    }


def P_none():
    from jax.sharding import PartitionSpec
    return PartitionSpec(None, None)


def ssm_state_specs(cfg: ModelConfig, m: MeshInfo, batch: int,
                    num_layers: int) -> dict:
    """Decode-state specs (stacked over layers)."""
    d_in, h, p = ssm_dims(cfg)
    n = cfg.ssm_state
    batch_ax = tuple(m.fsdp) or None
    return {
        "h": ParamSpec((num_layers, batch, h, n, p), jnp.float32,
                       _maybe(m, None, batch_ax, "model", None, None), "zeros"),
        "conv": ParamSpec((num_layers, batch, CONV_K - 1, d_in + 2 * n),
                          cfg.dtype,
                          _maybe(m, None, batch_ax, None, None), "zeros"),
    }


def _causal_conv(x: Array, w: Array, state: Array | None = None):
    """Depthwise causal conv, kernel CONV_K. x (B,T,C), w (K,C).

    Returns (y, new_state) where new_state is the trailing K-1 inputs.
    """
    b, t, c = x.shape
    if state is None:
        pad = jnp.zeros((b, CONV_K - 1, c), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, T+K-1, C)
    y = sum(
        xp[:, i : i + t, :] * w[i][None, None, :] for i in range(CONV_K)
    )
    new_state = xp[:, t:, :] if t >= CONV_K - 1 else xp[:, -(CONV_K - 1):, :]
    return y.astype(x.dtype), new_state


def mamba2_chunked(x: Array, dt: Array, a: Array, bmat: Array, cmat: Array,
                   d_skip: Array, h0: Array, chunk: int):
    """Chunked SSD scan.

    x (B,T,H,P) fp32, dt (B,T,H) fp32 (post-softplus), a (H,) negative,
    bmat/cmat (B,T,N) fp32, d_skip (H,), h0 (B,H,N,P) fp32.
    Returns y (B,T,H,P) fp32, h_final.
    """
    b, t, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, t)
    while t % q:
        q //= 2
    nc = t // q

    xc = x.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    bc = bmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)

    causal = jnp.tril(jnp.ones((q, q), jnp.float32))

    def body(hstate, args):
        xq, dtq, bq, cq = args                 # (B,Q,H,P),(B,Q,H),(B,Q,N)x2
        l = dtq * a[None, None, :]             # (B,Q,H) log-decay, <= 0
        lc = jnp.cumsum(l, axis=1)             # (B,Q,H)
        # inter-chunk: y° = e^{L_t} C_t · h_start
        y_inter = jnp.einsum("bqn,bhnp->bqhp", cq, hstate) * \
            jnp.exp(lc)[..., None]
        # intra-chunk: M ⊙ decay, then @ (dt x)
        m = jnp.einsum("bqn,bsn->bqs", cq, bq)            # (B,Q,S)
        decay = jnp.exp(
            jnp.clip(lc[:, :, None, :] - lc[:, None, :, :], -60.0, 0.0)
        )                                                  # (B,Q,S,H)
        w = m[..., None] * decay * dtq[:, None, :, :] * causal[None, :, :, None]
        y_intra = jnp.einsum("bqsh,bshp->bqhp", w, xq)
        # state: h' = e^{L_Q} h + Σ e^{L_Q - L_s} dt_s B_s ⊗ x_s
        decay_state = jnp.exp(jnp.clip(lc[:, -1:, :] - lc, -60.0, 0.0)) * dtq
        h_inc = jnp.einsum("bsh,bsn,bshp->bhnp", decay_state, bq, xq)
        h_new = jnp.exp(lc[:, -1])[..., None, None] * hstate + h_inc
        y = y_intra + y_inter + xq * d_skip[None, None, :, None]
        return h_new, y

    h_final, yc = jax.lax.scan(body, h0, (xc, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    return y, h_final


def mamba2_step(x: Array, dt: Array, a: Array, bvec: Array, cvec: Array,
                d_skip: Array, h: Array):
    """Single decode step. x (B,H,P), dt (B,H), b/c (B,N), h (B,H,N,P)."""
    decay = jnp.exp(dt * a[None, :])                       # (B,H)
    h_new = decay[..., None, None] * h + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bvec, x
    )
    y = jnp.einsum("bn,bhnp->bhp", cvec, h_new) + x * d_skip[None, :, None]
    return y, h_new


def mamba2_block(
    params: dict,
    cfg: ModelConfig,
    xin: Array,                   # (B, S, D)
    *,
    table,
    state: dict | None = None,    # {"h": (B,H,N,P), "conv": (B,K-1,C)}
) -> tuple[Array, dict | None]:
    b, s, d = xin.shape
    d_in, h, p = ssm_dims(cfg)
    n = cfg.ssm_state
    silu = table.lookup("silu")
    softplus = table.lookup("softplus")      # flexible: dt nonlinearity

    z = linear(xin, params["in_z"])                          # (B,S,d_in)
    xproj = linear(xin, params["in_x"])
    bproj = linear(xin, params["in_B"])
    cproj = linear(xin, params["in_C"])
    dt_raw = linear(xin, params["in_dt"])                    # (B,S,H)

    xbc = jnp.concatenate([xproj, bproj, cproj], axis=-1)
    conv_w = jnp.concatenate(
        [params["conv_x"], params["conv_B"], params["conv_C"]], axis=-1
    )
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, conv_w, conv_state)
    xbc = silu(xbc)                                          # flexible
    xs = xbc[..., :d_in].astype(jnp.float32).reshape(b, s, h, p)
    bmat = xbc[..., d_in : d_in + n].astype(jnp.float32)
    cmat = xbc[..., d_in + n :].astype(jnp.float32)

    dt = softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    ).astype(jnp.float32)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))        # (H,) negative

    if state is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)
        y, h_new = mamba2_chunked(
            xs, dt, a, bmat, cmat, params["d_skip"].astype(jnp.float32),
            h0, cfg.ssm_chunk,
        )
    elif s == 1:
        y, h_new = mamba2_step(
            xs[:, 0], dt[:, 0], a, bmat[:, 0], cmat[:, 0],
            params["d_skip"].astype(jnp.float32), state["h"],
        )
        y = y[:, None]
    else:  # prefill with state
        y, h_new = mamba2_chunked(
            xs, dt, a, bmat, cmat, params["d_skip"].astype(jnp.float32),
            state["h"], cfg.ssm_chunk,
        )

    y = y.reshape(b, s, d_in).astype(cfg.dtype)
    y = rms_norm(y * silu(z), params["norm"], cfg.norm_eps)  # flexible gate
    out = linear(y, params["out"])
    new_state = None
    if state is not None:
        new_state = {"h": h_new, "conv": new_conv.astype(state["conv"].dtype)}
    return out, new_state
