"""Mixture-of-Experts with expert parallelism (deepseek-v3, llama4-scout).

Routing is token-choice top-k with per-expert capacity (gather-based):

  * the router (softmax + top-k) is a *flexible* op — it lives in router
    space exactly like an activation lives in MLP space, and it is the
    fastest-changing part of MoE designs (aux-loss-free biasing, sigmoid
    routers, ...). The expert MLPs are *static* primitives.
  * EP: experts are sharded over the "model" axis and FSDP'd over
    ("pod","data"); tokens are sharded over ("pod","data") and replicated
    over "model". Inside a ``shard_map`` island each model-rank:
      1. all-gathers its experts' weights over the FSDP axes (ZeRO-3),
      2. scores all local tokens for its E_local experts,
      3. picks top-C tokens per expert (capacity drop, by router weight),
      4. gathers/computes/scatter-adds,
    and the partial outputs are psum'd over "model". No all-to-all — at
    these expert counts the replicated-token EP pattern keeps the only
    cross-chip traffic at psum(B·S·D), which the roofline tracks.
  * shared experts (deepseek-v3) run dense, TP-sharded over "model".

The HOST (single-device) path runs the same algorithm without collectives.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.models.layers import MeshInfo, ParamSpec, _maybe
from repro.parallel import tp

Array = jax.Array


def moe_param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    d, f, e, dt = cfg.d_model, cfg.moe_d_ff, cfg.num_experts, cfg.dtype
    fsdp = tuple(m.fsdp) or None
    specs = {
        "router": ParamSpec((d, e), dt, _maybe(m, fsdp, None)),
        # experts: E over "model" (EP), D over FSDP (ZeRO-3)
        "w_gate": ParamSpec((e, d, f), dt, _maybe(m, "model", fsdp, None)),
        "w_up": ParamSpec((e, d, f), dt, _maybe(m, "model", fsdp, None)),
        "w_down": ParamSpec((e, f, d), dt, _maybe(m, "model", None, fsdp)),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        specs["shared"] = {
            "w_gate": ParamSpec((d, fs), dt, _maybe(m, fsdp, "model")),
            "w_up": ParamSpec((d, fs), dt, _maybe(m, fsdp, "model")),
            "w_down": ParamSpec((fs, d), dt, _maybe(m, "model", fsdp)),
        }
    return specs


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.num_experts
                  * cfg.capacity_factor)
    return min(tokens, max(8, (c + 7) // 8 * 8))


def _expert_mlp(x: Array, wg: Array, wu: Array, wd: Array, act) -> Array:
    """(C, D) tokens through one expert; static primitives + flexible act."""
    g = act(jnp.dot(x, wg, preferred_element_type=jnp.float32))
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    return jnp.dot((g * u).astype(x.dtype), wd,
                   preferred_element_type=jnp.float32).astype(x.dtype)


def _route(x: Array, router_w: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """Flexible op: router softmax + top-k. x (T, D) -> weights/ids (T, k)."""
    logits = jnp.dot(x, router_w, preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights.astype(jnp.float32), ids


def _local_expert_pass(x: Array, weights: Array, ids: Array,
                       w_gate: Array, w_up: Array, w_down: Array,
                       e_offset: Array | int, cfg: ModelConfig, act) -> Array:
    """Run E_local experts over T local tokens. Returns (T, D) partial sum."""
    t = x.shape[0]
    e_local = w_gate.shape[0]
    cap = _capacity(t, cfg)

    def one_expert(j, wg, wu, wd):
        gid = e_offset + j
        score = jnp.sum(jnp.where(ids == gid, weights, 0.0), axis=-1)  # (T,)
        top_w, top_idx = jax.lax.top_k(score, cap)                     # capacity
        xe = jnp.take(x, top_idx, axis=0)                              # (C, D)
        ye = _expert_mlp(xe, wg, wu, wd, act)
        ye = ye * top_w[:, None].astype(ye.dtype)
        return jnp.zeros((t, x.shape[1]), ye.dtype).at[top_idx].add(ye)

    parts = jax.vmap(one_expert, in_axes=(0, 0, 0, 0))(
        jnp.arange(e_local), w_gate, w_up, w_down
    )
    return jnp.sum(parts, axis=0)


def moe(
    params: dict,
    cfg: ModelConfig,
    x: Array,                     # (B, S, D)
    *,
    table,
    minfo: MeshInfo,
    mesh: Mesh | None = None,
) -> Array:
    act = table.lookup(cfg.activation)
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)

    use_shard_map = (
        mesh is not None
        and "model" in minfo.axis_names
        and cfg.moe_dispatch == "shard_map"
    )
    if tp.active() is not None:
        # Already inside a manual shard_map (TP serving): the router is
        # replicated so routing decisions are GLOBAL expert ids, while
        # the expert stack is sharded over the model axis — run the
        # local experts at this shard's id offset and leave y a PARTIAL
        # sum. The shared-expert slice below adds its own partial and
        # ONE merged psum at the end reassembles the layer output.
        weights, ids = _route(x2, params["router"], cfg)
        e_local = params["w_gate"].shape[0]
        y = _local_expert_pass(
            x2, weights, ids, params["w_gate"], params["w_up"],
            params["w_down"], tp.shard_offset(e_local), cfg, act,
        )
    elif not use_shard_map:
        weights, ids = _route(x2, params["router"], cfg)
        y = _local_expert_pass(
            x2, weights, ids, params["w_gate"], params["w_up"],
            params["w_down"], 0, cfg, act,
        )
    else:
        fsdp = tuple(minfo.fsdp)
        tok_spec = _maybe(minfo, fsdp or None, None)       # (T, D)
        ew_spec = _maybe(minfo, "model", fsdp or None, None)
        ed_spec = _maybe(minfo, "model", None, fsdp or None)
        r_spec = _maybe(minfo, fsdp or None, None)

        def shard_fn(x_l, wr_l, wg_l, wu_l, wd_l):
            # ZeRO-3 gather of this rank's expert weights over FSDP axes.
            if fsdp:
                wr_l = jax.lax.all_gather(wr_l, fsdp, axis=0, tiled=True)
                wg_l = jax.lax.all_gather(wg_l, fsdp, axis=1, tiled=True)
                wu_l = jax.lax.all_gather(wu_l, fsdp, axis=1, tiled=True)
                wd_l = jax.lax.all_gather(wd_l, fsdp, axis=2, tiled=True)
            weights, ids = _route(x_l, wr_l, cfg)
            e_local = wg_l.shape[0]
            e_offset = jax.lax.axis_index("model") * e_local
            y_l = _local_expert_pass(
                x_l, weights, ids, wg_l, wu_l, wd_l, e_offset, cfg, act,
            )
            return jax.lax.psum(y_l, "model")

        from repro.parallel.compat import shard_map

        y = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(tok_spec, r_spec, ew_spec, ew_spec, ed_spec),
            out_specs=tok_spec,
            check_vma=False,
        )(x2, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])

    if cfg.num_shared_experts:
        sh = params["shared"]
        g = act(jnp.dot(x2, sh["w_gate"], preferred_element_type=jnp.float32))
        u = jnp.dot(x2, sh["w_up"], preferred_element_type=jnp.float32)
        y = y + jnp.dot((g * u).astype(x2.dtype), sh["w_down"],
                        preferred_element_type=jnp.float32).astype(y.dtype)

    return tp.psum_partial(y).reshape(b, s, d).astype(x.dtype)
