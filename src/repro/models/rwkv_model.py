"""RWKV6 full model: embeddings + scanned [time-mix, channel-mix] layers."""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.core.function_table import DEFAULT_TABLE
from repro.models import layers as L
from repro.models import rwkv as rwkv_lib
from repro.models.layers import MeshInfo, ParamSpec, _maybe

Array = jax.Array


def param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    fsdp = tuple(m.fsdp) or None
    block = dict(rwkv_lib.rwkv_param_specs(cfg, m))
    block["tm_norm"] = ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones")
    block["cm_norm"] = ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones")
    return {
        "embed": ParamSpec((L.padded_vocab(cfg.vocab_size), cfg.d_model),
                           cfg.dtype, _maybe(m, "model", fsdp), "embed"),
        "final_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "blocks": L.stack_specs(block, cfg.num_layers),
    }


def init(key, cfg: ModelConfig, m: MeshInfo = L.HOST) -> dict:
    return L.materialize(key, param_specs(cfg, m))


def cache_specs(cfg: ModelConfig, m: MeshInfo, batch: int, max_len: int) -> dict:
    return rwkv_lib.rwkv_state_specs(cfg, m, batch, cfg.num_layers)


def init_cache(cfg, m, batch, max_len):
    return L.materialize(jax.random.PRNGKey(0), cache_specs(cfg, m, batch, max_len))


def _remat(fn, cfg):
    return fn if cfg.remat == "none" else jax.checkpoint(fn)


def _run(params, cfg: ModelConfig, x, *, table, state=None):
    def body(x, xs):
        p_l, s_l = xs
        h = L.rms_norm(x, p_l["tm_norm"], cfg.norm_eps)
        y, ns = rwkv_lib.rwkv_block(p_l, cfg, h, table=table, state=s_l)
        x = x + y
        h = L.rms_norm(x, p_l["cm_norm"], cfg.norm_eps)
        y, ns2 = rwkv_lib.rwkv_channel_mix(p_l, cfg, h, table=table, state=ns)
        return x + y, ns2

    x, new_state = jax.lax.scan(_remat(body, cfg), x, (params["blocks"], state))
    return x, new_state


def forward(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
            minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    x = L.embed_lookup(params["embed"], batch["tokens"],
                       sharded="model" in minfo.axis_names)
    x, _ = _run(params, cfg, x, table=table)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"])


def loss(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
         minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    logits = forward(params, cfg, batch, table=table, minfo=minfo, mesh=mesh)
    return L.softmax_cross_entropy(
        logits[:, :-1, :].reshape(-1, logits.shape[-1]),
        batch["labels"][:, 1:].reshape(-1),
        vocab=cfg.vocab_size,
    )


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict, *,
            table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST, mesh=None):
    x = L.embed_lookup(params["embed"], batch["tokens"],
                       sharded="model" in minfo.axis_names)
    x, new_state = _run(params, cfg, x, table=table, state=cache)
    x = L.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"]), new_state


def decode_step(params, cfg: ModelConfig, tokens: Array, cache: dict,
                pos: Array, *, table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST,
                mesh=None, memory=None):
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    x, new_state = _run(params, cfg, x, table=table, state=cache)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"]), new_state
