"""MLP blocks — the paper's canonical static→flexible→static pattern.

``gated`` (SwiGLU-family): y = (f(x@Wg) ⊙ (x@Wu)) @ Wd
``plain`` (nemotron squared-relu, rwkv channel-mix): y = f(x@W1) @ W2

The activation is a function-table key — swapping it (the paper's "new
activation function" scenario) touches no model or kernel code. In SIDEBAR
mode with ``cfg.use_pallas`` the plain MLP runs through the fused
``kernels.sidebar_mlp`` (VMEM-resident intermediate).
"""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.kernels import ops as kops
from repro.models.layers import MeshInfo, ParamSpec, _maybe, linear

Array = jax.Array


def mlp_param_specs(cfg: ModelConfig, m: MeshInfo, d_ff: int | None = None) -> dict:
    d, f, dt = cfg.d_model, d_ff or cfg.d_ff, cfg.dtype
    fsdp = tuple(m.fsdp) or None
    specs = {
        "w_up": ParamSpec((d, f), dt, _maybe(m, fsdp, "model")),
        "w_down": ParamSpec((f, d), dt, _maybe(m, "model", fsdp)),
    }
    if cfg.gated_mlp:
        specs["w_gate"] = ParamSpec((d, f), dt, _maybe(m, fsdp, "model"))
    return specs


def mlp(
    params: dict,
    cfg: ModelConfig,
    x: Array,
    *,
    table: FunctionTable = DEFAULT_TABLE,
    activation: str | None = None,
) -> Array:
    """x (..., D) -> (..., D)."""
    act_name = activation or cfg.activation
    act = table.lookup(act_name)
    if cfg.gated_mlp:
        if cfg.use_pallas and x.ndim == 2 and x.shape[0] % 8 == 0:
            return kops.sidebar_gated_mlp(
                x, params["w_gate"], params["w_up"], params["w_down"],
                act_name, table=table,
                interpret=jax.default_backend() != "tpu",
            )
        g = act(linear(x, params["w_gate"]))          # flexible (VPU)
        u = linear(x, params["w_up"])                 # static  (MXU)
        return linear((g * u).astype(x.dtype), params["w_down"])
    if cfg.use_pallas and x.ndim == 2 and x.shape[0] % 8 == 0:
        return kops.sidebar_mlp(
            x, params["w_up"], params["w_down"], act_name, table=table,
            interpret=jax.default_backend() != "tpu",
        )
    h = act(linear(x, params["w_up"]))
    return linear(h.astype(x.dtype), params["w_down"])
