"""MLP blocks — the paper's canonical static→flexible→static pattern.

``gated`` (SwiGLU-family): y = (f(x@Wg) ⊙ (x@Wu)) @ Wd
``plain`` (nemotron squared-relu, rwkv channel-mix): y = f(x@W1) @ W2

The activation is a function-table key — swapping it (the paper's "new
activation function" scenario) touches no model or kernel code. In SIDEBAR
mode with ``cfg.use_pallas`` the plain MLP runs through the fused
``kernels.sidebar_mlp`` (VMEM-resident intermediate).
"""

from __future__ import annotations

import math

import jax

from repro.configs.base import ModelConfig
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.kernels import ops as kops
from repro.models.layers import MeshInfo, ParamSpec, _maybe, linear
from repro.parallel import tp

Array = jax.Array


def mlp_param_specs(cfg: ModelConfig, m: MeshInfo, d_ff: int | None = None) -> dict:
    d, f, dt = cfg.d_model, d_ff or cfg.d_ff, cfg.dtype
    fsdp = tuple(m.fsdp) or None
    specs = {
        "w_up": ParamSpec((d, f), dt, _maybe(m, fsdp, "model")),
        "w_down": ParamSpec((f, d), dt, _maybe(m, "model", fsdp)),
    }
    if cfg.gated_mlp:
        specs["w_gate"] = ParamSpec((d, f), dt, _maybe(m, fsdp, "model"))
    return specs


def mlp(
    params: dict,
    cfg: ModelConfig,
    x: Array,
    *,
    table: FunctionTable = DEFAULT_TABLE,
    activation: str | None = None,
) -> Array:
    """x (..., D) -> (..., D).

    The sidebar kernels take 2-D operands; higher-rank activations (the
    serving path is (B, S, D)) flatten their leading dims into the row
    axis — rows are independent for every op here, so the fused kernels
    serve decode/prefill shapes too (PR 3: before this, serving never
    reached the kernels and per-layer plans had nothing to dispatch to).

    Under ambient TP w_up/w_gate are column-parallel and w_down is
    row-parallel, so every exit below returns a PARTIAL down-projection
    over the local d_ff shard — psum'd on the model axis (identity
    outside TP). The sidebar kernels run per-shard unmodified: they only
    ever see the local (d, f_local)/(f_local, d) weight slices.
    """
    act_name = activation or cfg.activation
    act = table.lookup(act_name)
    d = x.shape[-1]
    rows = math.prod(x.shape[:-1]) if x.ndim > 1 else 0
    kernel_ok = cfg.use_pallas and x.ndim >= 2 and rows % 8 == 0
    if cfg.gated_mlp:
        if kernel_ok:
            y = kops.sidebar_gated_mlp(
                x.reshape(rows, d), params["w_gate"], params["w_up"],
                params["w_down"], act_name, table=table,
                interpret=jax.default_backend() != "tpu",
            )
            return tp.psum_partial(y.reshape(x.shape))
        g = act(linear(x, params["w_gate"]))          # flexible (VPU)
        u = linear(x, params["w_up"])                 # static  (MXU)
        return tp.psum_partial(
            linear((g * u).astype(x.dtype), params["w_down"]))
    if kernel_ok:
        y = kops.sidebar_mlp(
            x.reshape(rows, d), params["w_up"], params["w_down"], act_name,
            table=table, interpret=jax.default_backend() != "tpu",
        )
        return tp.psum_partial(y.reshape(x.shape))
    h = act(linear(x, params["w_up"]))
    return tp.psum_partial(linear(h.astype(x.dtype), params["w_down"]))
