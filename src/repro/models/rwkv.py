"""RWKV6 ("Finch") — attention-free, data-dependent decay.

Sidebar decomposition: the r/k/v/g projections, the lora mixers, and the
chunked WKV contractions are *static* primitives; the fast-evolving parts
are all *flexible* function-table ops — the double-exponential decay
``exp_decay`` (w = e^{-e^{x}} — a function that did not exist when RWKV4
hardware would have been taped out: the paper's obsolescence scenario,
realized), SiLU/sigmoid gates, and the squared-ReLU channel-mix.

Chunked WKV (chunk Q, per head, key dim K, value dim V):

  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
  o_t = r_t (diag(u) k_tᵀ v_t + S_{t-1})

  With L_t = Σ_{s≤t} log w_s (cumsum per channel, ≤ 0):
    intra (s<t):  A_ts = Σ_d r_td k_sd e^{L_{t-1,d} - L_{s,d}}
    diag:         A_tt = Σ_d r_td k_td u_d
    inter:        o°_t = (r_t ⊙ e^{L_{t-1}}) · S
    state:        S' = diag(e^{L_Q}) S + Σ_s (k_s ⊙ e^{L_Q-L_s})ᵀ v_s

  The pairwise decay e^{L_{t-1}-L_s} is computed explicitly per chunk
  (never factored into overflowing e^{±L} halves) — stable for any decay.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import MeshInfo, ParamSpec, _maybe, linear, rms_norm

Array = jax.Array

LORA_MIX = 32
LORA_DECAY = 64
CHUNK = 64
MIX_COMPONENTS = 5  # r, k, v, w, g


def rwkv_dims(cfg: ModelConfig) -> tuple[int, int]:
    return cfg.d_model // cfg.rwkv_head_dim, cfg.rwkv_head_dim


def rwkv_param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    h, k = rwkv_dims(cfg)
    fsdp = tuple(m.fsdp) or None
    tp = "model"
    return {
        # time-mix (token-shift ddlerp)
        "mix_base": ParamSpec((MIX_COMPONENTS, d), dt, P(None, None), "zeros"),
        "mix_w1": ParamSpec((d, MIX_COMPONENTS * LORA_MIX), dt, _maybe(m, fsdp, None)),
        "mix_w2": ParamSpec((MIX_COMPONENTS, LORA_MIX, d), dt, P(None, None, None)),
        # data-dependent decay lora
        "w0": ParamSpec((d,), jnp.float32, P(None), "zeros"),
        "w_lora1": ParamSpec((d, LORA_DECAY), dt, _maybe(m, fsdp, None)),
        "w_lora2": ParamSpec((LORA_DECAY, d), dt, P(None, None)),
        # projections
        "wr": ParamSpec((d, d), dt, _maybe(m, fsdp, tp)),
        "wk": ParamSpec((d, d), dt, _maybe(m, fsdp, tp)),
        "wv": ParamSpec((d, d), dt, _maybe(m, fsdp, tp)),
        "wg": ParamSpec((d, d), dt, _maybe(m, fsdp, tp)),
        "u": ParamSpec((d,), jnp.float32, _maybe(m, tp), "zeros"),
        "ln_x": ParamSpec((d,), dt, _maybe(m, tp), "ones"),
        "wo": ParamSpec((d, d), dt, _maybe(m, tp, fsdp)),
        # channel-mix
        "cm_mix_k": ParamSpec((d,), dt, P(None), "zeros"),
        "cm_mix_r": ParamSpec((d,), dt, P(None), "zeros"),
        "cm_key": ParamSpec((d, f), dt, _maybe(m, fsdp, tp)),
        "cm_value": ParamSpec((f, d), dt, _maybe(m, tp, fsdp)),
        "cm_recept": ParamSpec((d, d), dt, _maybe(m, fsdp, tp)),
    }


def rwkv_state_specs(cfg: ModelConfig, m: MeshInfo, batch: int,
                     num_layers: int) -> dict:
    h, k = rwkv_dims(cfg)
    batch_ax = tuple(m.fsdp) or None
    return {
        "wkv": ParamSpec((num_layers, batch, h, k, k), jnp.float32,
                         _maybe(m, None, batch_ax, "model", None, None), "zeros"),
        "shift_tm": ParamSpec((num_layers, batch, cfg.d_model), cfg.dtype,
                              _maybe(m, None, batch_ax, None), "zeros"),
        "shift_cm": ParamSpec((num_layers, batch, cfg.d_model), cfg.dtype,
                              _maybe(m, None, batch_ax, None), "zeros"),
    }


def _token_shift(x: Array, prev: Array | None) -> Array:
    """shift(x)[t] = x[t-1]; position 0 gets `prev` (decode state) or 0."""
    b, t, d = x.shape
    first = jnp.zeros((b, 1, d), x.dtype) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1, :]], axis=1)


def wkv_chunked(r: Array, k: Array, v: Array, logw: Array, u: Array,
                s0: Array, chunk: int = CHUNK):
    """r/k/v (B,T,H,K) fp32, logw (B,T,H,K) (<=0), u (H,K), s0 (B,H,K,K).

    Returns o (B,T,H,K), s_final. State layout: S[h, d_k, d_v].
    """
    b, t, h, kk = r.shape
    q = min(chunk, t)
    while t % q:
        q //= 2
    nc = t // q

    def resh(x):
        return x.reshape(b, nc, q, h, kk).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(logw)
    strict = jnp.tril(jnp.ones((q, q), jnp.float32), k=-1)

    def body(s, args):
        rq, kq, vq, lw = args                   # (B,Q,H,K)
        lc = jnp.cumsum(lw, axis=1)             # (B,Q,H,K) cumulative log w
        lc_prev = lc - lw                       # L_{t-1}
        # intra: A_ts = Σ_d r_td k_sd e^{Lprev_t - L_s}  (s < t)
        pair = jnp.exp(
            jnp.clip(lc_prev[:, :, None] - lc[:, None, :, :], -60.0, 0.0)
        )                                       # (B,Q,S,H,K)
        a = jnp.einsum("bqhk,bshk,bqshk->bqsh", rq, kq, pair)
        a = a * strict[None, :, :, None]
        a_diag = jnp.einsum("bqhk,bqhk,hk->bqh", rq, kq, u)
        o = jnp.einsum("bqsh,bshk->bqhk", a, vq)
        o += a_diag[..., None] * vq
        # inter: o° = (r ⊙ e^{Lprev}) · S
        o += jnp.einsum("bqhk,bhkv->bqhv", rq * jnp.exp(lc_prev), s)
        # state update
        kdec = kq * jnp.exp(jnp.clip(lc[:, -1:] - lc, -60.0, 0.0))
        s_new = jnp.exp(lc[:, -1])[..., None] * s + jnp.einsum(
            "bshk,bshv->bhkv", kdec, vq
        )
        return s_new, o

    s_final, oc = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(b, t, h, kk)
    return o, s_final


def wkv_step(r: Array, k: Array, v: Array, w: Array, u: Array, s: Array):
    """Single decode step; r/k/v/w (B,H,K), s (B,H,K,K)."""
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    o = jnp.einsum("bhk,bhkv->bhv", r, s + u[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    return o, s_new


def rwkv_block(
    params: dict,
    cfg: ModelConfig,
    xin: Array,                    # (B, S, D) — post-norm input (time-mix half)
    *,
    table,
    state: dict | None = None,
) -> tuple[Array, dict | None]:
    """Time-mix (WKV) half. Returns (out, new_state-without-channel-mix)."""
    b, s, d = xin.shape
    h, kk = rwkv_dims(cfg)
    silu = table.lookup("silu")
    sigmoid = table.lookup("sigmoid")
    exp_decay = table.lookup("exp_decay")     # flexible: e^{-e^{x}}

    prev = state["shift_tm"] if state is not None else None
    xx = _token_shift(xin, prev)
    delta = xx - xin
    # ddlerp: 5 data-dependent mixes from one lora
    mix_l = jnp.tanh(linear(xin, params["mix_w1"]))          # (B,S,5*32)
    mix_l = mix_l.reshape(b, s, MIX_COMPONENTS, LORA_MIX)
    mix_dyn = jnp.einsum("bscl,cld->bscd", mix_l.astype(jnp.float32),
                         params["mix_w2"].astype(jnp.float32))
    mix = params["mix_base"].astype(jnp.float32)[None, None] + mix_dyn
    xmix = xin[:, :, None, :].astype(jnp.float32) + \
        delta[:, :, None, :].astype(jnp.float32) * mix       # (B,S,5,D)
    x_r, x_k, x_v, x_w, x_g = [
        xmix[:, :, i, :].astype(cfg.dtype) for i in range(MIX_COMPONENTS)
    ]

    r = linear(x_r, params["wr"]).astype(jnp.float32).reshape(b, s, h, kk)
    k = linear(x_k, params["wk"]).astype(jnp.float32).reshape(b, s, h, kk)
    v = linear(x_v, params["wv"]).astype(jnp.float32).reshape(b, s, h, kk)
    g = silu(linear(x_g, params["wg"]))

    ww = params["w0"][None, None, :] + jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(linear(x_w, params["w_lora1"])).astype(jnp.float32),
        params["w_lora2"].astype(jnp.float32),
    )
    w = exp_decay(ww)                                        # (B,S,D) in (0,1)
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
    logw = logw.reshape(b, s, h, kk)
    u = params["u"].astype(jnp.float32).reshape(h, kk)

    if state is None:
        s0 = jnp.zeros((b, h, kk, kk), jnp.float32)
        o, s_new = wkv_chunked(r, k, v, logw, u, s0)
    elif s == 1:
        o, s_new = wkv_step(
            r[:, 0], k[:, 0], v[:, 0],
            jnp.exp(logw[:, 0]), u, state["wkv"],
        )
        o = o[:, None]
    else:
        o, s_new = wkv_chunked(r, k, v, logw, u, state["wkv"])

    o = o.reshape(b, s, d).astype(cfg.dtype)
    o = rms_norm(o, params["ln_x"], cfg.norm_eps) * g.astype(cfg.dtype)
    out = linear(o, params["wo"])

    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["wkv"] = s_new
        new_state["shift_tm"] = xin[:, -1, :]
    return out, new_state


def rwkv_channel_mix(
    params: dict,
    cfg: ModelConfig,
    xin: Array,
    *,
    table,
    state: dict | None = None,
) -> tuple[Array, dict | None]:
    """Channel-mix half: squared-relu MLP with sigmoid receptance gate."""
    sq_relu = table.lookup("squared_relu")    # flexible
    sigmoid = table.lookup("sigmoid")

    prev = state["shift_cm"] if state is not None else None
    xx = _token_shift(xin, prev)
    delta = xx - xin
    x_k = xin + delta * params["cm_mix_k"].astype(xin.dtype)[None, None]
    x_r = xin + delta * params["cm_mix_r"].astype(xin.dtype)[None, None]

    kk = sq_relu(linear(x_k, params["cm_key"]))
    vv = linear(kk.astype(xin.dtype), params["cm_value"])
    rr = sigmoid(linear(x_r, params["cm_recept"]))
    out = (rr * vv).astype(xin.dtype)

    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["shift_cm"] = xin[:, -1, :]
    return out, new_state
