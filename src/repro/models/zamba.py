"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block.

Layer plan for num_layers=81, attn_every=6:
  13 groups of [6 mamba2 layers, then the shared attn+MLP block] + 3 tail
  mamba2 layers. The shared block's weights are reused at every
  invocation (zamba2's parameter-sharing trick) but each invocation keeps
  its OWN KV cache (13 cache slots).

long_500k runs here: the 81 mamba states are O(1) in sequence length and
only the 13 shared-attn invocations keep (sharded) 500k KV caches —
the hybrid's selling point, and why this arch keeps the long cell while
pure-attention archs skip it.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.function_table import DEFAULT_TABLE
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import ssm as ssm_lib
from repro.models.layers import MeshInfo, ParamSpec, _maybe
from repro.models.mlp import mlp, mlp_param_specs

Array = jax.Array


def _plan(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, n_tail)."""
    n_groups = cfg.num_layers // cfg.attn_every
    return n_groups, cfg.num_layers - n_groups * cfg.attn_every


def param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    fsdp = tuple(m.fsdp) or None
    n_groups, n_tail = _plan(cfg)
    mamba = ssm_lib.mamba2_param_specs(cfg, m)
    shared = {
        "attn_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "mlp_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "attn": attn_lib.gqa_param_specs(cfg, m),
        "mlp": mlp_param_specs(cfg, m),
    }
    specs = {
        "embed": ParamSpec((L.padded_vocab(cfg.vocab_size), cfg.d_model),
                           cfg.dtype, _maybe(m, "model", fsdp), "embed"),
        "final_norm": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones"),
        "mamba_norm": L.stack_specs(
            {"w": ParamSpec((cfg.d_model,), cfg.dtype, _maybe(m, None), "ones")},
            cfg.num_layers,
        ),
        "groups": L.stack_specs(L.stack_specs(mamba, cfg.attn_every), n_groups),
        "shared": shared,  # ONE copy — reused by all 13 invocations
    }
    if n_tail:
        specs["tail"] = L.stack_specs(mamba, n_tail)
    return specs


def init(key, cfg: ModelConfig, m: MeshInfo = L.HOST) -> dict:
    return L.materialize(key, param_specs(cfg, m))


def state_specs(cfg: ModelConfig, m: MeshInfo, batch: int, max_len: int) -> dict:
    n_groups, n_tail = _plan(cfg)
    ssm = ssm_lib.ssm_state_specs(cfg, m, batch, cfg.num_layers)
    return {
        "ssm": ssm,  # leading dim = num_layers (group-major then tail)
        "kv": attn_lib.kv_cache_specs(cfg, m, batch, max_len, n_groups),
    }


def cache_specs(cfg, m, batch, max_len):
    return state_specs(cfg, m, batch, max_len)


def init_cache(cfg, m, batch, max_len):
    return L.materialize(jax.random.PRNGKey(0), state_specs(cfg, m, batch, max_len))


def _remat(fn, cfg):
    return fn if cfg.remat == "none" else jax.checkpoint(fn)


def _shared_block(params, cfg, x, positions, *, table, cache=None,
                  cache_pos=None):
    p = params["shared"]
    h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
    a, nc = attn_lib.gqa_attention(p["attn"], cfg, h, positions,
                                   cache=cache, cache_pos=cache_pos)
    x = x + a
    h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + mlp(p["mlp"], cfg, h, table=table), nc


def _run(params, cfg: ModelConfig, x, positions, *, table,
         state=None, cache_pos=None):
    n_groups, n_tail = _plan(cfg)
    per = cfg.attn_every
    norms = params["mamba_norm"]["w"]          # (num_layers, D)

    def mamba_body(x, xs, base_idx=None):
        p_l, norm_w, s_l = xs
        h = L.rms_norm(x, norm_w, cfg.norm_eps)
        y, ns = ssm_lib.mamba2_block(p_l, cfg, h, table=table, state=s_l)
        return x + y, ns

    # group-major state slicing: ssm states [g*per:(g+1)*per], kv slot g
    def group_body(x, xs):
        p_g, norm_g, s_g, kv_g = xs

        x, ns = jax.lax.scan(_remat(mamba_body, cfg), x, (p_g, norm_g, s_g))
        x, nkv = _remat(
            lambda x, kv: _shared_block(params, cfg, x, positions, table=table,
                                        cache=kv, cache_pos=cache_pos),
            cfg,
        )(x, kv_g)
        return x, (ns, nkv)

    if state is not None:
        ssm_states = state["ssm"]
        group_ssm = jax.tree.map(
            lambda a: a[: n_groups * per].reshape(n_groups, per, *a.shape[1:]),
            ssm_states,
        )
        tail_ssm = jax.tree.map(lambda a: a[n_groups * per:], ssm_states)
        kv = state["kv"]
    else:
        group_ssm = tail_ssm = kv = None

    group_norms = norms[: n_groups * per].reshape(n_groups, per, -1)
    x, (new_group_ssm, new_kv) = jax.lax.scan(
        group_body, x, (params["groups"], group_norms, group_ssm, kv),
    )

    new_state = None
    if n_tail:
        tail_norms = norms[n_groups * per:]
        x, new_tail_ssm = jax.lax.scan(
            _remat(mamba_body, cfg), x,
            (params["tail"], {"w": tail_norms}["w"], tail_ssm),
        )
    if state is not None:
        flat_group = jax.tree.map(
            lambda a: a.reshape(n_groups * per, *a.shape[2:]), new_group_ssm
        )
        if n_tail:
            new_ssm = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                flat_group, new_tail_ssm,
            )
        else:
            new_ssm = flat_group
        new_state = {"ssm": new_ssm, "kv": new_kv}
    return x, new_state


def forward(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
            minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, _ = _run(params, cfg, x, positions, table=table)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"])


def loss(params, cfg: ModelConfig, batch: dict, *, table=DEFAULT_TABLE,
         minfo: MeshInfo = L.HOST, mesh=None) -> Array:
    logits = forward(params, cfg, batch, table=table, minfo=minfo, mesh=mesh)
    return L.softmax_cross_entropy(
        logits[:, :-1, :].reshape(-1, logits.shape[-1]),
        batch["labels"][:, 1:].reshape(-1),
        vocab=cfg.vocab_size,
    )


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict, *,
            table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST, mesh=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, new_state = _run(params, cfg, x, positions, table=table,
                        state=cache, cache_pos=jnp.int32(0))
    x = L.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"]), new_state


def decode_step(params, cfg: ModelConfig, tokens: Array, cache: dict,
                pos: Array, *, table=DEFAULT_TABLE, minfo: MeshInfo = L.HOST,
                mesh=None, memory=None):
    b = tokens.shape[0]
    x = L.embed_lookup(params["embed"], tokens,
                       sharded="model" in minfo.axis_names)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    x, new_state = _run(params, cfg, x, positions, table=table,
                        state=cache, cache_pos=pos)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(x, params["embed"]), new_state
