"""Model zoo: all assigned architecture families + the paper's LeNet."""
