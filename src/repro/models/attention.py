"""Attention blocks: GQA (+qk-norm, +cross-attn) and MLA (DeepSeek-V3).

Sidebar decomposition: the QKV/output projections and the two attention
einsums are *static* primitives (MXU); softmax and qk-RMSNorm are
*flexible* functions (VPU). In SIDEBAR mode the fused path is
``kernels/flash_attention.py`` (logits + softmax stats in VMEM scratch);
the XLA path below uses a chunked-scan formulation so long-sequence
prefill never materializes the full S×T logits (sub-quadratic memory).

KV caches:
  * GQA: (B, Hkv, T, Dh) per layer; optional int8 quantization with
    per-(token, head) scales (production decode memory trick).
  * MLA: compressed — (B, T, kv_lora_rank) latent + (B, T, rope_dim)
    shared rope key. Decode uses the absorbed-matmul formulation
    (q projected into latent space; no per-head K/V expansion).
"""

from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.parallel import tp
from repro.parallel.hints import constrain
from repro.models.layers import (
    MeshInfo,
    ParamSpec,
    _maybe,
    apply_rope,
    linear,
    rms_norm,
)

Array = jax.Array

CHUNK_Q = int(os.environ.get("REPRO_ATTN_CHUNK_Q", "1024"))  # q-block size (chunked XLA attention)


# ---------------------------------------------------------------------------
# Param specs.
# ---------------------------------------------------------------------------

def gqa_param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.dtype
    fsdp = tuple(m.fsdp) or None
    specs = {
        "wq": ParamSpec((d, h * dh), dt, _maybe(m, fsdp, "model")),
        "wk": ParamSpec((d, hkv * dh), dt, _maybe(m, fsdp, "model")),
        "wv": ParamSpec((d, hkv * dh), dt, _maybe(m, fsdp, "model")),
        "wo": ParamSpec((h * dh, d), dt, _maybe(m, "model", fsdp)),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((dh,), dt, P(None), "ones")
        specs["k_norm"] = ParamSpec((dh,), dt, P(None), "ones")
    return specs


def mla_param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vdh = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    dt = cfg.dtype
    fsdp = tuple(m.fsdp) or None
    return {
        "w_dq": ParamSpec((d, qr), dt, _maybe(m, fsdp, None)),
        "q_norm": ParamSpec((qr,), dt, P(None), "ones"),
        "w_uq": ParamSpec((qr, h * (nope + rope)), dt, _maybe(m, fsdp, "model")),
        "w_dkv": ParamSpec((d, kvr), dt, _maybe(m, fsdp, None)),
        "kv_norm": ParamSpec((kvr,), dt, P(None), "ones"),
        "w_kr": ParamSpec((d, rope), dt, _maybe(m, fsdp, None)),
        "w_uk": ParamSpec((kvr, h * nope), dt, _maybe(m, fsdp, "model")),
        "w_uv": ParamSpec((kvr, h * vdh), dt, _maybe(m, fsdp, "model")),
        "wo": ParamSpec((h * vdh, d), dt, _maybe(m, "model", fsdp)),
    }


def attn_param_specs(cfg: ModelConfig, m: MeshInfo) -> dict:
    return mla_param_specs(cfg, m) if cfg.use_mla else gqa_param_specs(cfg, m)


# ---------------------------------------------------------------------------
# KV cache (GQA).
# ---------------------------------------------------------------------------

def kv_cache_specs(cfg: ModelConfig, m: MeshInfo, batch: int, max_len: int,
                   num_layers: int) -> dict:
    """Stacked-over-layers cache specs (leading L dim, scan xs layout)."""
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    batch_ax = tuple(m.fsdp) or None
    if cfg.use_mla:
        return {
            "c_kv": ParamSpec((num_layers, batch, max_len, cfg.kv_lora_rank),
                              cfg.dtype, _maybe(m, None, batch_ax, None, None), "zeros"),
            "k_rope": ParamSpec((num_layers, batch, max_len, cfg.rope_head_dim),
                                cfg.dtype, _maybe(m, None, batch_ax, None, None), "zeros"),
        }
    kv_dt = cfg.kv_cache_dtype
    # GQA often has fewer kv heads than the TP degree (e.g. kv=8, TP=16).
    # Shard heads over "model" when divisible; else shard head_dim (the
    # QKV weights stay TP-sharded on the fused hkv*dh dim either way, and
    # XLA reconciles the two layouts with a local reshard).
    tp = m.size("model")
    if tp > 1 and hkv % tp != 0 and dh % tp == 0:
        head_ax, dh_ax = None, "model"
    else:
        head_ax, dh_ax = "model", None
    specs = {
        "k": ParamSpec((num_layers, batch, hkv, max_len, dh), kv_dt,
                       _maybe(m, None, batch_ax, head_ax, None, dh_ax), "zeros"),
        "v": ParamSpec((num_layers, batch, hkv, max_len, dh), kv_dt,
                       _maybe(m, None, batch_ax, head_ax, None, dh_ax), "zeros"),
    }
    if kv_dt == jnp.int8:
        specs["k_scale"] = ParamSpec((num_layers, batch, hkv, max_len), jnp.float32,
                                     _maybe(m, None, batch_ax, head_ax, None), "zeros")
        specs["v_scale"] = ParamSpec((num_layers, batch, hkv, max_len), jnp.float32,
                                     _maybe(m, None, batch_ax, head_ax, None), "zeros")
    return specs


def rowwise_pos(pos) -> bool:
    """True when ``cache_pos`` is a per-row ``(B,)`` vector — batched
    decode of slots sitting at unaligned positions (the continuous-
    batching scheduler's segment decode). Scalar positions keep the
    dense ``dynamic_update_slice`` fast path."""
    return pos is not None and getattr(pos, "ndim", 0) == 1


# ---------------------------------------------------------------------------
# Paged KV (block tables): gather/scatter between the pooled cache and
# the dense layout the attention math runs on.
# ---------------------------------------------------------------------------

def _paged_write_index(block_tables: Array, cache_pos, s: int, bs: int,
                       num_blocks: int):
    """Physical (block, offset) for each written token position.

    ``block_tables`` (B, nb) maps logical block j of each row onto a
    pooled block id. Positions are ``cache_pos`` (scalar or per-row
    ``(B,)``) plus the within-call token index. Returns ``(pb, off)``
    with shape ``(B,)`` for single-token decode and ``(B, s)`` for a
    prefill chunk — advanced-index scatters either way, so pooled
    writes cost one scatter exactly like the slot scheduler's rowwise
    path. A position past the table (a padded staging chunk running
    past it, or an idle row parked at ``max_len - 1`` under a sliced
    table) gets the out-of-range sentinel ``num_blocks`` — callers
    scatter with ``mode="drop"`` so the write vanishes instead of
    silently clamping onto the row's LAST real block (which corrupts a
    possibly prefix-shared neighbour when the table is fully
    allocated). The scheduler additionally span-checks real rows
    host-side before dispatch (``kvpool.PagedKVManager.check_span``).
    """
    b = block_tables.shape[0]
    nb = block_tables.shape[1]
    pos = jnp.asarray(cache_pos, jnp.int32)
    if s == 1:
        p = jnp.broadcast_to(pos, (b,)) if pos.ndim == 0 else pos  # (B,)
    else:
        start = pos[:, None] if pos.ndim == 1 else pos
        p = jnp.broadcast_to(start + jnp.arange(s, dtype=jnp.int32),
                             (b, s))
    blk = p // bs
    pb = jnp.take_along_axis(
        block_tables, jnp.minimum(blk, nb - 1).reshape(b, -1),
        axis=1).reshape(p.shape)
    # out-of-table positions -> one past the pool: a dead row that
    # mode="drop" scatters discard entirely
    pb = jnp.where(blk < nb, pb, num_blocks)
    return pb, p % bs


# Table gathers declare mode="promise_in_bounds" instead of jnp.take's
# default OOB *clipping*, which would silently read block 0 for any
# stale/corrupt table entry. The promise is real: tables are built from
# allocator-owned block ids padded with SCRATCH_BLOCK, and the scheduler
# re-validates host-side before every dispatch (kvpool.validate_tables).


def _paged_gather_kv(leaf: Array, block_tables: Array) -> Array:
    """(P, Hkv, bs, Dh) pooled KV -> (B, Hkv, nb*bs, Dh) dense view."""
    g = leaf.at[block_tables].get(
        mode="promise_in_bounds")        # (B, nb, Hkv, bs, Dh)
    g = jnp.moveaxis(g, 1, 2)                     # (B, Hkv, nb, bs, Dh)
    b, h = g.shape[0], g.shape[1]
    return g.reshape(b, h, -1, leaf.shape[-1])


def _paged_gather_scale(leaf: Array, block_tables: Array) -> Array:
    """(P, Hkv, bs) pooled scales -> (B, Hkv, nb*bs)."""
    g = leaf.at[block_tables].get(
        mode="promise_in_bounds")        # (B, nb, Hkv, bs)
    g = jnp.moveaxis(g, 1, 2)                     # (B, Hkv, nb, bs)
    return g.reshape(g.shape[0], g.shape[1], -1)


def _paged_gather_lat(leaf: Array, block_tables: Array) -> Array:
    """(P, bs, r) pooled MLA latent/rope -> (B, nb*bs, r)."""
    g = leaf.at[block_tables].get(
        mode="promise_in_bounds")        # (B, nb, bs, r)
    return g.reshape(g.shape[0], -1, leaf.shape[-1])


def _quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) int8 quantization: x (B, Hkv, S, Dh)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)  # (B,Hkv,S)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# Core attention math (XLA path): chunked over q blocks.
# ---------------------------------------------------------------------------

def _attend(q: Array, k: Array, v: Array, *, causal: bool, cfg: ModelConfig,
            offset: int | Array | None = None) -> Array:
    """q (B,H,S,Dh), k/v (B,Hkv,T,Dh). Chooses pallas / chunked / direct.

    ``offset`` is the global position of query row 0 (kpos <= qpos+offset
    is visible). Default (None) = queries at the sequence end (t - s).
    """
    b, h, s, dh = q.shape
    t = k.shape[2]
    if offset is None:
        offset = t - s
    static_end = isinstance(offset, int) and offset == t - s
    if cfg.use_pallas and s % 128 == 0 and t % 128 == 0 and static_end:
        return kops.flash_attention(q, k, v, causal=causal,
                                    interpret=jax.default_backend() != "tpu")
    group = h // k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    if s <= CHUNK_Q or s % CHUNK_Q:
        return _attend_direct(q, k, v, group, scale, causal, offset)
    return _attend_chunked(q, k, v, group, scale, causal, offset)


def _attend_direct(q, k, v, group, scale, causal, offset):
    return _attend_direct_offset(q, k, v, group, scale, causal, offset)


UNROLL_CHUNKS = int(os.environ.get("REPRO_ATTN_UNROLL", "64"))  # unroll threshold (causal skipping)


def _attend_chunked(q, k, v, group, scale, causal, offset):
    """Chunked over q: peak logits memory O(chunk x T), not O(S x T).

    When the chunk count is moderate the loop is UNROLLED with static
    k/v prefixes per chunk (chunk i attends k[: offset+(i+1)*CHUNK]) —
    the causal block-skipping that a scan cannot express (saves ~2x
    flops at s == t). Falls back to a scan for very long sequences.
    """
    b, h, s, dh = q.shape
    n_chunks = s // CHUNK_Q
    static_off = isinstance(offset, int)

    if causal and static_off and n_chunks <= UNROLL_CHUNKS:
        outs = []
        for i in range(n_chunks):
            qi = q[:, :, i * CHUNK_Q : (i + 1) * CHUNK_Q, :]
            qi = constrain(qi, ("batch", "model", None, None))
            end = offset + (i + 1) * CHUNK_Q
            ki, vi = k[:, :, :end, :], v[:, :, :end, :]
            outs.append(
                _attend_direct_offset(qi, ki, vi, group, scale, True,
                                      offset + i * CHUNK_Q)
            )
        return jnp.concatenate(outs, axis=2)

    qc = q.reshape(b, h, n_chunks, CHUNK_Q, dh).transpose(2, 0, 1, 3, 4)
    qc = constrain(qc, (None, "batch", "model", None, None))

    def body(carry, args):
        qi, idx = args
        qi = constrain(qi, ("batch", "model", None, None))
        out = _attend_direct_offset(qi, k, v, group, scale, causal,
                                    offset + idx * CHUNK_Q)
        return carry, out

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)))
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, v.shape[-1])


def _attend_direct_offset(q, k, v, group, scale, causal, offset):
    b, h, s, dh = q.shape
    t = k.shape[2]
    qg = q.reshape(b, k.shape[1], group, s, dh)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        if rowwise_pos(offset):
            # per-row query offsets (batched slots at unaligned
            # positions): (B, s, t) mask broadcast over (kv-head, group)
            qpos = jnp.arange(s)[None, :] + offset[:, None]           # (B, s)
            mask = jnp.arange(t)[None, None, :] <= qpos[:, :, None]   # (B, s, t)
            logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        else:
            qpos = jnp.arange(s)[:, None] + offset
            kpos = jnp.arange(t)[None, :]
            logits = jnp.where(kpos <= qpos, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, s, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block.
# ---------------------------------------------------------------------------

def gqa_attention(
    params: dict,
    cfg: ModelConfig,
    x: Array,                      # (B, S, D)
    positions: Array,              # (B, S)
    *,
    causal: bool = True,
    cache: dict | None = None,     # per-layer slice (no leading L dim)
    cache_pos: Array | None = None,  # scalar write offset (decode/prefill)
    memory: Array | None = None,   # cross-attention memory (B, T, D)
    block_tables: Array | None = None,  # (B, nb) paged-KV mapping
) -> tuple[Array, dict | None]:
    b, s, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = linear(x, params["wq"]).reshape(b, s, h, dh)
    kv_src = memory if memory is not None else x
    k = linear(kv_src, params["wk"]).reshape(b, kv_src.shape[1], hkv, dh)
    v = linear(kv_src, params["wv"]).reshape(b, kv_src.shape[1], hkv, dh)

    if cfg.qk_norm:  # flexible op: qk-RMSNorm (qwen3)
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if memory is None:  # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        if cache is None:
            kpos = positions
        elif rowwise_pos(cache_pos):
            kpos = cache_pos[:, None] + jnp.arange(kv_src.shape[1])[None, :]
        else:
            kpos = cache_pos + jnp.arange(kv_src.shape[1])[None, :]
        k = apply_rope(k, kpos, cfg.rope_theta)

    q = constrain(q.transpose(0, 2, 1, 3), ("batch", "model", None, None))
    k = constrain(k.transpose(0, 2, 1, 3), ("batch", "model", None, None))
    v = constrain(v.transpose(0, 2, 1, 3), ("batch", "model", None, None))

    new_cache = None
    if cache is not None:
        int8 = cfg.kv_cache_dtype == jnp.int8
        if int8:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
        else:
            kq, vq = k.astype(cfg.kv_cache_dtype), v.astype(cfg.kv_cache_dtype)
        new_cache = dict(cache)
        if block_tables is not None:
            # paged KV: the cache leaves are the pooled (P, Hkv, bs, Dh)
            # physical blocks; each written position scatters to its
            # row's table entry (blocks shared across rows by the prefix
            # cache are never in any row's write range — the scheduler's
            # copy-on-write guarantee).
            bs_blk = cache["k"].shape[2]
            pb, po = _paged_write_index(block_tables, cache_pos, s, bs_blk,
                                        cache["k"].shape[0])
            if s == 1:
                kv_vals = (kq[:, :, 0, :], vq[:, :, 0, :])
            else:
                kv_vals = (kq.transpose(0, 2, 1, 3), vq.transpose(0, 2, 1, 3))
            # mode="drop": out-of-table positions carry the OOB sentinel
            # block id and must vanish, never clamp onto a real block
            new_cache["k"] = cache["k"].at[pb, :, po, :].set(
                kv_vals[0], mode="drop")
            new_cache["v"] = cache["v"].at[pb, :, po, :].set(
                kv_vals[1], mode="drop")
            if int8:
                s_vals = ((ks[:, :, 0], vs[:, :, 0]) if s == 1
                          else (ks.transpose(0, 2, 1), vs.transpose(0, 2, 1)))
                new_cache["k_scale"] = (
                    cache["k_scale"].at[pb, :, po].set(
                        s_vals[0], mode="drop"))
                new_cache["v_scale"] = (
                    cache["v_scale"].at[pb, :, po].set(
                        s_vals[1], mode="drop"))
        elif rowwise_pos(cache_pos):
            # per-row scatter: slot row i writes its own position — ONE
            # batched program over unaligned slots instead of num_slots
            # vmapped batch-1 programs (the scheduler's segment decode).
            bidx = jnp.arange(b)
            if s == 1:
                new_cache["k"] = cache["k"].at[bidx, :, cache_pos, :].set(kq[:, :, 0, :])
                new_cache["v"] = cache["v"].at[bidx, :, cache_pos, :].set(vq[:, :, 0, :])
                if int8:
                    new_cache["k_scale"] = (
                        cache["k_scale"].at[bidx, :, cache_pos].set(ks[:, :, 0])
                    )
                    new_cache["v_scale"] = (
                        cache["v_scale"].at[bidx, :, cache_pos].set(vs[:, :, 0])
                    )
            else:
                # rowwise multi-token chunk on a dense slab (the draft
                # model's ingest program): each row writes s positions
                # from its own start; positions past the slab (padded
                # short rows) carry OOB indices and must vanish, not
                # clamp onto the slab's last column.
                ppos = cache_pos[:, None] + jnp.arange(s)[None, :]
                new_cache["k"] = cache["k"].at[bidx[:, None], :, ppos, :].set(
                    kq.transpose(0, 2, 1, 3), mode="drop")
                new_cache["v"] = cache["v"].at[bidx[:, None], :, ppos, :].set(
                    vq.transpose(0, 2, 1, 3), mode="drop")
                if int8:
                    new_cache["k_scale"] = (
                        cache["k_scale"].at[bidx[:, None], :, ppos].set(
                            ks.transpose(0, 2, 1), mode="drop"))
                    new_cache["v_scale"] = (
                        cache["v_scale"].at[bidx[:, None], :, ppos].set(
                            vs.transpose(0, 2, 1), mode="drop"))
        else:
            start = (0, 0, cache_pos, 0)
            new_cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, start)
            new_cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, start)
            if int8:
                new_cache["k_scale"] = jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, 0, cache_pos))
                new_cache["v_scale"] = jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, 0, cache_pos))
        if block_tables is not None and s == 1:
            # paged decode attention IN PLACE on the pool: the op walks
            # the block table directly (Pallas table-indexed DMA on
            # TPU / under interpret; a per-layer table gather feeding
            # the identical dense math in the jnp reference) — no
            # pool-wide slab view anywhere on the decode hot path.
            pos = jnp.asarray(cache_pos, jnp.int32)
            lengths = jnp.broadcast_to(pos, (b,)).astype(jnp.int32) + 1
            ctx = kops.paged_attention_gqa(
                q[:, :, 0, :], new_cache["k"], new_cache["v"],
                block_tables, lengths, scale=1.0 / math.sqrt(dh),
                k_scale=new_cache["k_scale"] if int8 else None,
                v_scale=new_cache["v_scale"] if int8 else None,
                compute_dtype=cfg.dtype,
                interpret=cfg.use_pallas
                and jax.default_backend() != "tpu",
            )
            out = ctx.reshape(b, 1, h * dh)
            return tp.psum_partial(linear(out, params["wo"])), new_cache
        if block_tables is not None:
            # prefill chunks (s > 1): dense (B, Hkv, nb*bs, Dh) view
            # gathered through the block table; junk in padded/unwritten
            # blocks sits behind the causal mask (exactly like a slab
            # cache's stale tail), so the attend below is bit-identical
            # to the slab path.
            kr = _paged_gather_kv(new_cache["k"], block_tables)
            vr = _paged_gather_kv(new_cache["v"], block_tables)
            if int8:
                k = _dequantize_kv(
                    kr, _paged_gather_scale(new_cache["k_scale"],
                                            block_tables), cfg.dtype)
                v = _dequantize_kv(
                    vr, _paged_gather_scale(new_cache["v_scale"],
                                            block_tables), cfg.dtype)
            else:
                k, v = kr.astype(cfg.dtype), vr.astype(cfg.dtype)
        elif int8:
            k = _dequantize_kv(new_cache["k"], new_cache["k_scale"], cfg.dtype)
            v = _dequantize_kv(new_cache["v"], new_cache["v_scale"], cfg.dtype)
        else:
            k = new_cache["k"].astype(cfg.dtype)
            v = new_cache["v"].astype(cfg.dtype)

    offset = cache_pos if cache is not None else None
    out = _attend(q, k, v, causal=causal and memory is None, cfg=cfg,
                  offset=offset)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return tp.psum_partial(linear(out, params["wo"])), new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V3).
# ---------------------------------------------------------------------------

def mla_attention(
    params: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    *,
    cache: dict | None = None,
    cache_pos: Array | None = None,
    block_tables: Array | None = None,
) -> tuple[Array, dict | None]:
    b, s, d = x.shape
    h = cfg.num_heads
    nope, rope, vdh = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim

    # --- queries: low-rank down + norm (flexible) + up.
    c_q = linear(x, params["w_dq"])
    c_q = rms_norm(c_q, params["q_norm"], cfg.norm_eps)
    q = linear(c_q, params["w_uq"]).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # --- compressed kv latent + shared rope key.
    c_kv = linear(x, params["w_dkv"])                     # (B,S,kvr)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = linear(x, params["w_kr"])                    # (B,S,rope)
    if cache is None:
        kpos = positions
    elif rowwise_pos(cache_pos):
        kpos = cache_pos[:, None] + jnp.arange(s)[None, :]
    else:
        kpos = cache_pos + jnp.arange(s)[None, :]
    k_rope = apply_rope(k_rope, kpos, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        if block_tables is not None:
            # paged MLA: latent + rope leaves are (P, bs, r) pooled
            # blocks; the (block, offset) advanced-index scatter and the
            # table gather mirror the GQA path exactly.
            bs_blk = cache["c_kv"].shape[1]
            pb, po = _paged_write_index(block_tables, cache_pos, s, bs_blk,
                                        cache["c_kv"].shape[0])
            ckv_w = c_kv[:, 0, :] if s == 1 else c_kv
            kr_w = k_rope[:, 0, :] if s == 1 else k_rope
            new_cache["c_kv"] = cache["c_kv"].at[pb, po, :].set(
                ckv_w.astype(cache["c_kv"].dtype), mode="drop")
            new_cache["k_rope"] = cache["k_rope"].at[pb, po, :].set(
                kr_w.astype(cache["k_rope"].dtype), mode="drop")
            if s != 1:
                # prefill chunks attend through the dense gathered view;
                # single-token decode goes in place on the pool via
                # kops.paged_attention_mla in the absorbed branch below
                c_kv_full = _paged_gather_lat(
                    new_cache["c_kv"], block_tables).astype(cfg.dtype)
                k_rope_full = _paged_gather_lat(
                    new_cache["k_rope"], block_tables).astype(cfg.dtype)
        elif rowwise_pos(cache_pos):
            # per-row scatter (see gqa_attention): batched decode of
            # slots at unaligned positions; s > 1 is the rowwise chunk
            # write (draft-model ingest), OOB padded positions dropped.
            bidx = jnp.arange(b)
            if s == 1:
                new_cache["c_kv"] = cache["c_kv"].at[bidx, cache_pos, :].set(
                    c_kv[:, 0, :].astype(cache["c_kv"].dtype))
                new_cache["k_rope"] = cache["k_rope"].at[bidx, cache_pos, :].set(
                    k_rope[:, 0, :].astype(cache["k_rope"].dtype))
            else:
                ppos = cache_pos[:, None] + jnp.arange(s)[None, :]
                new_cache["c_kv"] = cache["c_kv"].at[bidx[:, None], ppos, :].set(
                    c_kv.astype(cache["c_kv"].dtype), mode="drop")
                new_cache["k_rope"] = cache["k_rope"].at[bidx[:, None], ppos, :].set(
                    k_rope.astype(cache["k_rope"].dtype), mode="drop")
        else:
            new_cache["c_kv"] = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_pos, 0))
            new_cache["k_rope"] = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_pos, 0))
        if block_tables is None:
            c_kv_full = new_cache["c_kv"].astype(cfg.dtype)
            k_rope_full = new_cache["k_rope"].astype(cfg.dtype)
    else:
        c_kv_full, k_rope_full = c_kv, k_rope

    scale = 1.0 / math.sqrt(nope + rope)

    if cache is not None and s == 1:
        # ---- absorbed decode: project q into latent space; never expand K/V.
        w_uk = params["w_uk"].reshape(cfg.kv_lora_rank, h, nope)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))        # (B,1,H,kvr)
        if block_tables is not None:
            # paged absorbed decode IN PLACE on the compressed pool —
            # same contract as the GQA route: Pallas table-indexed DMA
            # on TPU/interpret, per-layer table gather in the reference.
            pos = jnp.asarray(cache_pos, jnp.int32)
            lengths = jnp.broadcast_to(pos, (b,)).astype(jnp.int32) + 1
            ctx_lat = kops.paged_attention_mla(
                q_lat[:, 0], q_rope[:, 0], new_cache["c_kv"],
                new_cache["k_rope"], block_tables, lengths, scale=scale,
                compute_dtype=cfg.dtype,
                interpret=cfg.use_pallas
                and jax.default_backend() != "tpu",
            )[:, None]                                       # (B,1,H,kvr)
        else:
            t = c_kv_full.shape[1]
            logits = (
                jnp.einsum("bshr,btr->bhst", q_lat,
                           c_kv_full.astype(jnp.float32))
                + jnp.einsum("bshn,btn->bhst", q_rope.astype(jnp.float32),
                             k_rope_full.astype(jnp.float32))
            ) * scale
            end = cache_pos + s - 1            # scalar, or (B,) per-row
            if rowwise_pos(cache_pos):
                end = end[:, None, None, None]
            mask = jnp.arange(t)[None, None, None, :] <= end
            logits = jnp.where(mask, logits, -1e30)
            p = jax.nn.softmax(logits, axis=-1)              # flexible op
            ctx_lat = jnp.einsum("bhst,btr->bshr", p,
                                 c_kv_full.astype(jnp.float32))
        w_uv = params["w_uv"].reshape(cfg.kv_lora_rank, h, vdh)
        out = jnp.einsum("bshr,rhv->bshv", ctx_lat, w_uv.astype(jnp.float32))
        out = out.reshape(b, s, h * vdh).astype(cfg.dtype)
        return tp.psum_partial(linear(out, params["wo"])), new_cache

    t = c_kv_full.shape[1]

    # ---- train/prefill: expand per-head keys/values (naive MLA).
    k_nope = linear(c_kv_full, params["w_uk"]).reshape(b, t, h, nope)
    vv = linear(c_kv_full, params["w_uv"]).reshape(b, t, h, vdh)
    k_rope_b = jnp.broadcast_to(k_rope_full[:, :, None, :], (b, t, h, rope))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1).transpose(0, 2, 1, 3)
    vv = vv.transpose(0, 2, 1, 3)
    offset = cache_pos if cache is not None else t - s
    # MLA head dims are non-uniform; always the XLA path.
    out = _attend_chunked(q_full, k_full, vv, 1, scale, True, offset) \
        if s > CHUNK_Q and s % CHUNK_Q == 0 else \
        _attend_direct(q_full, k_full, vv, 1, scale, True, offset)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * vdh)
    return tp.psum_partial(linear(out, params["wo"])), new_cache


def attention(params, cfg, x, positions, **kw):
    if cfg.use_mla:
        kw.pop("memory", None)
        kw.pop("causal", None)
        return mla_attention(params, cfg, x, positions, **kw)
    return gqa_attention(params, cfg, x, positions, **kw)
