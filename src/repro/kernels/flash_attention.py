"""Blocked (flash) attention kernel with online softmax.

Attention is the other matmul→flexible→matmul chain in every assigned
transformer: logits (static, MXU) → softmax (flexible, VPU) → PV (static,
MXU). Unfused, the logits round-trip HBM at O(S·T) bytes — the exact
flexible-DMA failure mode of the paper, at quadratic scale. This kernel is
the SIDEBAR treatment of attention: the logits tile and the softmax
running statistics live in VMEM scratch; the softmax (the flexible step)
is computed tile-wise on the VPU between the two MXU contractions, and
only the final O(S·D) output reaches HBM.

Tiling (BlockSpec):

  q reshaped (B·Hq, S, D), k/v reshaped (B·Hkv, T, D); GQA is handled by
  the k/v index_map (head h reads kv head h // group) — no kv duplication.

  grid = (B·Hq, S/bq, T/bk), kv minor (sequential online-softmax axis).
  q   : (1, bq, D) at (h, i, 0)
  k,v : (1, bk, D) at (h // group, j, 0)
  out : (1, bq, D) at (h, i, 0)
  scratch: m (bq, 1) fp32, l (bq, 1) fp32, acc (bq, D) fp32   [the sidebar]

Causal blocks strictly above the diagonal are skipped (``pl.when`` guards
the whole body), giving the ~2x causal flop saving.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, offset: int, block_q: int,
            block_k: int, n_k_blocks: int, out_dtype):
    i = pl.program_id(1)
    j = pl.program_id(2)

    # last kv block this q block attends to (causal skipping)
    if causal:
        last_q = i * block_q + block_q - 1 + offset
        j_last = jnp.minimum(n_k_blocks - 1, last_q // block_k)
        should_run = j * block_k <= last_q
    else:
        j_last = n_k_blocks - 1
        should_run = True

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(should_run)
    def _body():
        q = q_ref[0]                      # (bq, D)
        k = k_ref[0]                      # (bk, D)
        # static primitive #1 (MXU): logits tile into VMEM
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                          # (bq, bk)

        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos + offset, s, NEG_INF)

        # flexible step (VPU): online softmax on the sidebar-resident tile
        m_prev = m_ref[...]               # (bq, 1)
        m_curr = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_curr)
        p = jnp.exp(s - m_curr)           # (bq, bk)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_curr

        # static primitive #2 (MXU): weighted value accumulation
        pv = jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                     preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == j_last)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)   # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / l).astype(out_dtype)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    """softmax(q k^T * scale) v, fused; q (B,Hq,S,D), k/v (B,Hkv,T,D)."""
    b, hq, s_len, d = q.shape
    _, hkv, t_len, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA needs Hq % Hkv == 0, got {hq} % {hkv}")
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    block_q = min(block_q, s_len)
    block_k = min(block_k, t_len)
    if s_len % block_q or t_len % block_k:
        raise ValueError(f"S={s_len}%{block_q} or T={t_len}%{block_k} != 0")
    offset = t_len - s_len  # decode/cache: queries sit at the sequence end
    if causal and offset < 0:
        raise ValueError("causal attention needs T >= S")

    qr = q.reshape(b * hq, s_len, d)
    kr = k.reshape(b * hkv, t_len, d)
    vr = v.reshape(b * hkv, t_len, d)
    n_k_blocks = t_len // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, offset=offset,
        block_q=block_q, block_k=block_k, n_k_blocks=n_k_blocks,
        out_dtype=q.dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, s_len // block_q, n_k_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s_len, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s_len, d)
