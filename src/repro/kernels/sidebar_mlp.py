"""Fused Sidebar MLP kernel: y = f(x @ W1) @ W2, one ``pallas_call``.

This is the TPU realization of the paper's SIDEBAR design for its hot
pattern (static matmul → flexible activation → static matmul):

  * The two matmuls are the *static* primitives — they run on the MXU.
  * The intermediate ``h = x @ W1`` tile lives in a **VMEM scratch buffer —
    the Sidebar**. It is never materialized to HBM, exists outside the
    program's array namespace (``scratch_shapes``), and holds intermediate
    data only — exactly the paper's scratchpad semantics.
  * The activation is the *flexible* function: it is looked up in the
    ``FunctionTable`` at trace time and applied to the sidebar tile on the
    VPU. Registering a new activation re-specializes the kernel with **no
    kernel-source change** — the software analogue of the paper's
    driver-provided host function.

Tiling (BlockSpec):

  grid = (M/bm, F/bf), F minor (sequential accumulation axis).
  x   : (bm, D)   block at (i, 0)      — row panel, K resident
  w1  : (D, bf)   block at (0, j)      — column panel of W1
  w2  : (bf, D)   block at (j, 0)      — row panel of W2
  out : (bm, D)   block at (i, 0)      — revisited for every j (accumulate)
  sidebar : VMEM (bm, bf) fp32         — the scratchpad
  acc     : VMEM (bm, D)  fp32         — output accumulator

Per-step VMEM footprint (bm=128, bf=512, D=4096, bf16 in / fp32 scratch):
x 1.0 MiB + w1 4.0 MiB + w2 4.0 MiB + out 1.0 MiB + sidebar 0.25 MiB +
acc 2.0 MiB ≈ 12.3 MiB — comfortably inside a 16 MiB/core VMEM budget
(``choose_tiles`` picks bm/bf to respect it for other D).

The contraction dimension D stays resident (no k-blocking): for the
assigned architectures D = d_model ≤ 16 384, and the dominant tile is the
W panels; ``choose_tiles`` shrinks bf accordingly.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants
from repro.core.function_table import DEFAULT_TABLE, FunctionTable

Array = jax.Array

LANE = 128  # TPU lane width; last dims should be multiples of this
SUBLANE = 8


def choose_tiles(m: int, d: int, f: int, itemsize: int = 2,
                 vmem_budget: int = constants.VMEM_BYTES_PER_CHIP // 8,
                 sidebar_copies: int = 1) -> tuple[int, int]:
    """Pick (bm, bf) so the per-step working set fits the VMEM budget.

    working_set(bm, bf) = bm*d*itemsize   (x tile)
                        + d*bf*itemsize   (w1 panel)
                        + bf*d*itemsize   (w2 panel)
                        + bm*d*itemsize   (out tile)
                        + 4*bm*bf*copies  (sidebar; 2 copies when ping-pong)
                        + 4*bm*d          (accumulator, fp32)
    """
    for bm in (256, 128, 64, 32, 16, 8):
        if bm > m or m % bm:
            continue
        for bf in (1024, 512, 256, 128):
            if bf > f or f % bf:
                continue
            ws = (
                bm * d * itemsize
                + 2 * d * bf * itemsize
                + bm * d * itemsize
                + 4 * bm * bf * sidebar_copies
                + 4 * bm * d
            )
            if ws <= vmem_budget:
                return bm, bf
    return SUBLANE, LANE


def _kernel(x_ref, w1_ref, w2_ref, o_ref, sidebar_ref, acc_ref, *,
            activation: Callable, n_f_blocks: int, out_dtype):
    """One (i, j) grid step: sidebar tile j of row panel i."""
    j = pl.program_id(1)

    # --- static primitive #1 (MXU): partial intermediate into the sidebar.
    h = jnp.dot(x_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
    sidebar_ref[...] = h

    # --- flexible function (VPU) on the sidebar-resident intermediate.
    #     `activation` was fetched from the FunctionTable at trace time:
    #     this is the host-function invocation of paper §3.3, with the
    #     handshake realized by program order inside the fused kernel.
    act = activation(sidebar_ref[...])

    # --- static primitive #2 (MXU): consume the sidebar, accumulate y.
    part = jnp.dot(
        act.astype(w2_ref.dtype), w2_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(j > 0)
    def _accum():
        acc_ref[...] += part

    @pl.when(j == n_f_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _pipelined_kernel(x_ref, w1_ref, w2_ref, o_ref, sidebar_ref, acc_ref, *,
                      activation: Callable, n_f_blocks: int, depth: int,
                      out_dtype):
    """One (i, j) step of the T-deep ring schedule, j in [0, n_f + T - 2].

    The sidebar is a ring ``(T, bm, bf)``; the producer of step j and the
    consumer of step j touch *different* slots (the consumer lags T-1
    steps), so there is no data dependence between them and the MXU
    matmul of the produce stage can overlap the VPU activation + MXU
    accumulate of the consume stage — the VMEM realization of the
    engine's per-region ownership trade. At T=2 (lag 1):

        j:       0          1              2         ...   n_f
        produce  h0 -> s0   h1 -> s1       h2 -> s0
        consume             f(s0) @ w2_0   f(s1) @ w2_1    f(.) @ w2_last

    The grid runs T-1 steps past the last f-block (the pipeline drain).
    """
    j = pl.program_id(1)
    lag = depth - 1

    @pl.when(j < n_f_blocks)
    def _produce():
        # static primitive #1 (MXU): fill this step's slot of the ring
        h = jnp.dot(
            x_ref[...], w1_ref[...], preferred_element_type=jnp.float32
        )
        sidebar_ref[j % depth] = h

    @pl.when(j >= lag)
    def _consume():
        # flexible function (VPU) + static primitive #2 (MXU) on the slot
        # filled T-1 steps ago — the oldest in-flight slot of the ring
        act = activation(sidebar_ref[(j - lag) % depth])
        part = jnp.dot(
            act.astype(w2_ref.dtype), w2_ref[...],
            preferred_element_type=jnp.float32,
        )

        @pl.when(j == lag)
        def _init():
            acc_ref[...] = part

        @pl.when(j > lag)
        def _accum():
            acc_ref[...] += part

    @pl.when(j == n_f_blocks + lag - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def sidebar_mlp(
    x: Array,
    w1: Array,
    w2: Array,
    activation: str | Callable = "relu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    block_m: int | None = None,
    block_f: int | None = None,
    interpret: bool = False,
) -> Array:
    """Fused f(x @ w1) @ w2 with the intermediate resident in VMEM.

    Args:
      x: (M, D) activations.
      w1: (D, F) up-projection.  w2: (F, D2) down-projection.
      activation: function-table key (or raw callable) — the flexible op.
      block_m/block_f: tile overrides; default via ``choose_tiles``.
      interpret: run the kernel body in Python on CPU (validation mode).
    """
    m, d = x.shape
    d1, f = w1.shape
    f2, d2 = w2.shape
    if d != d1 or f != f2:
        raise ValueError(f"shape mismatch: x{x.shape} w1{w1.shape} w2{w2.shape}")
    fn = table.lookup(activation) if isinstance(activation, str) else activation

    bm, bf = choose_tiles(m, d, f, x.dtype.itemsize)
    bm = block_m or bm
    bf = block_f or bf
    if m % bm or f % bf:
        raise ValueError(f"M={m} % bm={bm} or F={f} % bf={bf} != 0")
    n_f_blocks = f // bf

    grid = (m // bm, n_f_blocks)
    kernel = functools.partial(
        _kernel, activation=fn, n_f_blocks=n_f_blocks, out_dtype=x.dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d2), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bf), jnp.float32),   # the Sidebar
            pltpu.VMEM((bm, d2), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(x, w1, w2)


def sidebar_mlp_pipelined(
    x: Array,
    w1: Array,
    w2: Array,
    activation: str | Callable = "relu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    block_m: int | None = None,
    block_f: int | None = None,
    depth: int = 2,
    interpret: bool = False,
) -> Array:
    """Ring-buffered f(x @ w1) @ w2: the sidebar is a ``depth``-deep VMEM
    ring and the f-axis grid is software-pipelined ``depth - 1`` steps
    deep, so the producer matmul of block j and the activation+consumer
    matmul of block j-(depth-1) are independent within every grid step
    (the kernel analogue of ExecutionMode.SIDEBAR_PIPELINED at ring depth
    T). ``depth=2`` is the PR-1 ping-pong pair; ``depth=1`` degenerates
    to the serial schedule. Numerically identical to ``sidebar_mlp`` at
    every depth.
    """
    m, d = x.shape
    d1, f = w1.shape
    f2, d2 = w2.shape
    if d != d1 or f != f2:
        raise ValueError(f"shape mismatch: x{x.shape} w1{w1.shape} w2{w2.shape}")
    if depth < 1:
        raise ValueError(f"ring depth must be >= 1, got {depth}")
    fn = table.lookup(activation) if isinstance(activation, str) else activation

    bm, bf = choose_tiles(m, d, f, x.dtype.itemsize, sidebar_copies=depth)
    bm = block_m or bm
    bf = block_f or bf
    if m % bm or f % bf:
        raise ValueError(f"M={m} % bm={bm} or F={f} % bf={bf} != 0")
    n_f_blocks = f // bf
    lag = depth - 1

    # depth-1 drain steps past the last f-block; weight index maps clamp
    # so the warm-up/drain steps re-read a valid (ignored) panel
    grid = (m // bm, n_f_blocks + lag)
    last = n_f_blocks - 1
    kernel = functools.partial(
        _pipelined_kernel, activation=fn, n_f_blocks=n_f_blocks,
        depth=depth, out_dtype=x.dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, jnp.minimum(j, last))),
            pl.BlockSpec(
                (bf, d2),
                lambda i, j: (jnp.clip(j - lag, 0, last), 0),
            ),
        ],
        out_specs=pl.BlockSpec((bm, d2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d2), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((depth, bm, bf), jnp.float32),  # the Sidebar ring
            pltpu.VMEM((bm, d2), jnp.float32),         # output accumulator
        ],
        interpret=interpret,
    )(x, w1, w2)
