"""Pallas TPU kernels for the Sidebar hot paths.

  sidebar_mlp     — fused f(x@W1)@W2; the intermediate lives in a VMEM
                    scratch ("the Sidebar"), the activation comes from the
                    host FunctionTable.
  sidebar_matmul  — tiled matmul + pluggable flexible epilogue.
  activations     — standalone host activation (the FLEXIBLE_DMA step).
  flash_attention — blocked attention; logits+softmax stats in VMEM.

``ops`` holds the jitted wrappers (kernel on TPU / interpret, oracle
fallback elsewhere); ``ref`` holds the pure-jnp oracles.
"""

from repro.kernels import ops, ref
from repro.kernels.activations import activation
from repro.kernels.flash_attention import flash_attention
from repro.kernels.sidebar_gated_mlp import sidebar_gated_mlp
from repro.kernels.sidebar_matmul import sidebar_matmul
from repro.kernels.sidebar_mlp import sidebar_mlp

__all__ = [
    "ops",
    "ref",
    "activation",
    "flash_attention",
    "sidebar_gated_mlp",
    "sidebar_matmul",
    "sidebar_mlp",
]
