"""Standalone activation kernel — the FLEXIBLE_DMA 'host step'.

In the flexible-DMA baseline the activation is its own dispatch: it reads
the full intermediate from HBM, applies the function-table entry on the
VPU, and writes the result back to HBM. This kernel IS that round-trip —
its existence (a separate ``pallas_call`` whose operand/result cross HBM)
is what the SIDEBAR design eliminates by fusing the same function into the
producer kernel's epilogue.

Tiling: 2-D row/col tiles; rowwise functions (softmax, rmsnorm) keep the
last dim resident.
"""

from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import constants
from repro.core.function_table import DEFAULT_TABLE, FunctionTable

Array = jax.Array


def _kernel(x_ref, o_ref, *, fn: Callable, out_dtype):
    o_ref[...] = fn(x_ref[...].astype(jnp.float32)).astype(out_dtype)


def plan_tiles(
    m: int,
    n: int,
    *,
    rowwise: bool = False,
    block_m: int | None = None,
    block_n: int | None = None,
) -> tuple[int, int]:
    """Resolve the (block_m, block_n) tiling for an (m, n) activation.

    Raises ``ValueError`` when no legal tiling exists (explicit blocks
    that don't divide, or a rowwise function whose resident row exceeds
    the VMEM budget). This is the single source of truth shared by the
    kernel and by ``ops.host_activation``'s eligibility precheck.
    """
    if block_m is None:
        block_m = min(m, 256)
        while m % block_m:
            block_m //= 2
        block_m = max(block_m, 1)
    if rowwise:
        block_n = n  # rowwise flexible ops need the full row resident
    if block_n is None:
        block_n = min(n, 2048)
        while n % block_n:
            block_n //= 2
        block_n = max(block_n, 1)
    if m % block_m or n % block_n:
        raise ValueError(f"tiles must divide: {m}%{block_m}, {n}%{block_n}")
    # VMEM sanity: in + out tiles in fp32
    if 8 * block_m * block_n > constants.VMEM_BYTES_PER_CHIP // 4:
        raise ValueError("activation tile exceeds VMEM budget")
    return block_m, block_n


def tileable(
    shape: tuple[int, ...],
    activation: str | Callable = "relu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
) -> bool:
    """Would ``activation(x)`` of this shape have a legal kernel tiling?

    Mirrors the lead-dims-flattened 2-D view the kernel entry point uses,
    so callers can precheck instead of catching the kernel's ValueError.
    """
    if not shape:
        return False
    entry = table[activation] if isinstance(activation, str) else None
    rowwise = entry.rowwise if entry is not None else False
    if len(shape) == 1:
        m, n = 1, shape[0]
    else:
        m, n = math.prod(shape[:-1]), shape[-1]
    try:
        plan_tiles(m, n, rowwise=rowwise,
                   block_m=1 if len(shape) == 1 else None)
    except ValueError:
        return False
    return True


def activation(
    x: Array,
    activation: str | Callable = "relu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> Array:
    """y = f(x) as its own kernel launch (HBM -> VPU -> HBM)."""
    if x.ndim == 1:
        x2 = x.reshape(1, -1)
        return activation_2d(
            x2, activation, table=table, block_m=1,
            block_n=block_n, interpret=interpret
        ).reshape(x.shape)
    if x.ndim == 2:
        return activation_2d(
            x, activation, table=table, block_m=block_m,
            block_n=block_n, interpret=interpret
        )
    lead = 1
    for s in x.shape[:-1]:
        lead *= s
    y = activation_2d(
        x.reshape(lead, x.shape[-1]), activation, table=table,
        block_m=block_m, block_n=block_n, interpret=interpret
    )
    return y.reshape(x.shape)


def activation_2d(
    x: Array,
    activation: str | Callable = "relu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = False,
) -> Array:
    m, n = x.shape
    entry = table[activation] if isinstance(activation, str) else None
    fn = entry.fn if entry is not None else activation
    rowwise = entry.rowwise if entry is not None else False

    block_m, block_n = plan_tiles(m, n, rowwise=rowwise,
                                  block_m=block_m, block_n=block_n)

    kernel = functools.partial(_kernel, fn=fn, out_dtype=x.dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x)
