"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each oracle computes in fp32 regardless of input dtype and casts back, so
kernels (which accumulate in fp32 VMEM scratch) are compared like-for-like.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.function_table import DEFAULT_TABLE, FunctionTable

Array = jax.Array


def _resolve(activation: str | Callable, table: FunctionTable) -> Callable:
    if callable(activation):
        return activation
    return table.lookup(activation)


def sidebar_mlp_ref(
    x: Array,
    w1: Array,
    w2: Array,
    activation: str | Callable = "relu",
    table: FunctionTable = DEFAULT_TABLE,
) -> Array:
    """y = f(x @ w1) @ w2 with fp32 intermediate (the paper's hot pattern)."""
    fn = _resolve(activation, table)
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    h = fn(h)
    y = jnp.dot(h.astype(w2.dtype), w2, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def sidebar_gated_mlp_ref(
    x: Array,
    w_gate: Array,
    w_up: Array,
    w_down: Array,
    activation: str | Callable = "silu",
    table: FunctionTable = DEFAULT_TABLE,
) -> Array:
    """y = (f(x@Wg) * (x@Wu)) @ Wd with fp32 intermediates."""
    fn = _resolve(activation, table)
    g = fn(jnp.dot(x, w_gate, preferred_element_type=jnp.float32))
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    y = jnp.dot((g * u).astype(w_down.dtype), w_down,
                preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def sidebar_matmul_ref(
    a: Array,
    b: Array,
    activation: str | Callable = "identity",
    table: FunctionTable = DEFAULT_TABLE,
) -> Array:
    """c = f(a @ b): one static primitive with a function-table epilogue."""
    fn = _resolve(activation, table)
    c = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return fn(c).astype(a.dtype)


def activation_ref(
    x: Array,
    activation: str | Callable = "relu",
    table: FunctionTable = DEFAULT_TABLE,
) -> Array:
    """Standalone host activation (the FLEXIBLE_DMA 'host step')."""
    fn = _resolve(activation, table)
    return fn(x.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> Array:
    """Reference attention: softmax(q k^T * scale [+mask]) v, fp32 math.

    Shapes: q (B, Hq, S, D), k/v (B, Hkv, T, D) with Hq % Hkv == 0 (GQA).
    """
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / (d**0.5)
    logits = jnp.einsum(
        "bhsd,bhtd->bhst", q, k, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
