"""Tiled matmul with a pluggable function-table epilogue.

The *single static primitive* of the Sidebar design: ``c = f(a @ b)`` where
``f`` is a flexible function fetched from the host function table. Used as
the building block for layers whose flexible op follows one matmul (e.g.
router logits → softmax/top-k, qk products, conv-as-matmul in LeNet).

Tiling (BlockSpec):

  grid = (M/bm, N/bn, K/bk), K minor (sequential accumulation axis).
  a   : (bm, bk) at (i, k)
  b   : (bk, bn) at (k, j)
  out : (bm, bn) at (i, j)   — revisited across k
  acc : VMEM (bm, bn) fp32   — the sidebar tile; epilogue applied at k==last

The epilogue runs on the VPU against the VMEM-resident accumulator; the
raw (pre-activation) intermediate never reaches HBM.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants
from repro.core.function_table import DEFAULT_TABLE, FunctionTable

Array = jax.Array


def choose_tiles(m: int, k: int, n: int, itemsize: int = 2,
                 vmem_budget: int = constants.VMEM_BYTES_PER_CHIP // 8) -> tuple[int, int, int]:
    for bm in (256, 128, 64, 32, 16, 8):
        if bm > m or m % bm:
            continue
        for bn in (512, 256, 128):
            if bn > n or n % bn:
                continue
            for bk in (2048, 1024, 512, 256, 128):
                if bk > k or k % bk:
                    continue
                ws = itemsize * (bm * bk + bk * bn + bm * bn) + 4 * bm * bn
                if ws <= vmem_budget:
                    return bm, bn, bk
    return 8, 128, 128


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, epilogue: Callable,
            n_k_blocks: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k_blocks - 1)
    def _epilogue():
        # flexible function on the VMEM-resident tile (host step).
        o_ref[...] = epilogue(acc_ref[...]).astype(out_dtype)


def sidebar_matmul(
    a: Array,
    b: Array,
    activation: str | Callable = "identity",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> Array:
    """c = f(a @ b) with f from the function table, one pallas_call."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: a{a.shape} b{b.shape}")
    fn = table.lookup(activation) if isinstance(activation, str) else activation

    bm, bn, bk = choose_tiles(m, k, n, a.dtype.itemsize)
    bm, bn, bk = block_m or bm, block_n or bn, block_k or bk
    if m % bm or n % bn or k % bk:
        raise ValueError(f"tiles must divide: M{m}%{bm} N{n}%{bn} K{k}%{bk}")
    n_k_blocks = k // bk

    kernel = functools.partial(
        _kernel, epilogue=fn, n_k_blocks=n_k_blocks, out_dtype=a.dtype
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k_blocks),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
