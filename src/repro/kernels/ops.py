"""Jitted public entry points for the Pallas kernels.

Each op:
  * validates/normalizes shapes and dtypes,
  * dispatches to the Pallas kernel when shapes are TPU-tileable and the
    backend supports it, otherwise to the jnp oracle (bit-for-bit the same
    math) — so models can call these unconditionally,
  * is jit-friendly (static flags only via closure/partial).

``interpret`` is threaded through for CPU validation of the kernel bodies.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Callable

import jax

from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    LayerPlan,
    coerce_layer_plan,
)
from repro.kernels import activations as _activations
from repro.kernels import ref
from repro.kernels.activations import activation as _activation_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.paged_attention import (
    paged_gqa_kernel as _paged_gqa_kernel,
    paged_gqa_reference as _paged_gqa_ref,
    paged_mla_kernel as _paged_mla_kernel,
    paged_mla_reference as _paged_mla_ref,
)
from repro.kernels.sidebar_gated_mlp import sidebar_gated_mlp as _gated_kernel
from repro.kernels.sidebar_matmul import sidebar_matmul as _matmul_kernel
from repro.kernels.sidebar_mlp import sidebar_mlp as _mlp_kernel
from repro.kernels.sidebar_mlp import (
    sidebar_mlp_pipelined as _mlp_kernel_pipelined,
)

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _tileable(n: int, t: int = 128) -> bool:
    return n % t == 0


# -- execution-plan selection (wired from launch.serve.Server) -------------
# Models call the sidebar ops unconditionally; which kernel variant backs
# them (serial VMEM scratch vs T-deep ring pipelined, and how deep) is a
# deployment choice, so it is carried here as thread-local ambient state
# rather than threaded through every model signature. The ambient value
# may be a single ``LayerPlan`` (uniform) or a whole ``ExecutionPlan``:
# models announce which layer index they are tracing via ``layer_scope``
# and ``current_plan()`` resolves ``plan.for_layer(index)`` — so the
# planner's per-layer mode/depth choices reach each layer's kernel trace.

_PLAN_STATE = threading.local()

_DEFAULT_PLAN = LayerPlan(ExecutionMode.SIDEBAR, depth=1)


def current_layer() -> str | int | None:
    """The layer key the model is tracing right now (None outside any)."""
    return getattr(_PLAN_STATE, "layer", None)


@contextlib.contextmanager
def layer_scope(key: str | int | None):
    """Announce the layer being traced so a layer-indexed ``ExecutionPlan``
    resolves per-layer kernel variants. Models wrap each (unrolled) layer
    trace in this; a scanned stack traces once under the plan default."""
    prev = current_layer()
    _PLAN_STATE.layer = key
    try:
        yield
    finally:
        _PLAN_STATE.layer = prev


def current_full_plan() -> LayerPlan | ExecutionPlan:
    """The raw ambient plan (an ``ExecutionPlan`` stays layer-indexed)."""
    return getattr(_PLAN_STATE, "plan", _DEFAULT_PLAN)


def current_plan() -> LayerPlan:
    """The ``LayerPlan`` in effect for the layer currently being traced."""
    plan = current_full_plan()
    if isinstance(plan, ExecutionPlan):
        return plan.for_layer(current_layer())
    return plan


def current_execution_mode() -> ExecutionMode:
    return current_plan().mode


def set_plan(
    plan: LayerPlan | ExecutionPlan | ExecutionMode | str,
    depth: int | None = None,
) -> LayerPlan | ExecutionPlan:
    """Set the ambient sidebar kernel plan; returns the previous one.

    An ``ExecutionPlan`` is kept whole (layer-indexed resolution via
    ``layer_scope``); other spellings normalize to a ``LayerPlan``.
    """
    prev = current_full_plan()
    if isinstance(plan, ExecutionPlan):
        _PLAN_STATE.plan = plan
    else:
        _PLAN_STATE.plan = coerce_layer_plan(plan, depth)
    return prev


def set_execution_mode(
    mode: ExecutionMode | str, depth: int | None = None
) -> ExecutionMode:
    """Set the ambient sidebar kernel variant; returns the previous one."""
    prev = set_plan(mode, depth)
    if isinstance(prev, ExecutionPlan):
        return prev.default.mode
    return prev.mode


@contextlib.contextmanager
def execution_plan(
    plan: LayerPlan | ExecutionPlan | ExecutionMode | str,
    depth: int | None = None,
):
    prev = set_plan(plan, depth)
    try:
        yield
    finally:
        set_plan(prev)


@contextlib.contextmanager
def execution_mode(mode: ExecutionMode | str, depth: int | None = None):
    with execution_plan(mode, depth):
        yield


# -- dispatch recording (test/diagnostic probe) -----------------------------
# ``record_dispatches`` captures which kernel variant each sidebar op
# actually resolved at trace time — the observable for "the planner's
# per-layer choice reached the kernels" (a plan-state probe, cheaper and
# sharper than diffing HLO).


@dataclasses.dataclass(frozen=True)
class PlanDispatch:
    """One sidebar-op trace-time dispatch decision."""

    op: str                       # "sidebar_mlp" | "sidebar_gated_mlp" | ...
    layer: str | int | None       # ambient layer_scope key at trace time
    mode: ExecutionMode           # resolved plan mode
    depth: int                    # resolved ring depth
    variant: str                  # "pipelined" | "serial" | "dma" | "ref"
    used_kernel: bool             # the variant's primary kernel path was
    # taken: the fused Pallas kernel for serial/pipelined, the producer
    # matmul kernel for "dma" (its standalone host_activation gates its
    # own tiling independently and is not reflected here)


@contextlib.contextmanager
def record_dispatches(into: list):
    """Append a ``PlanDispatch`` per sidebar-op trace into ``into``."""
    prev = getattr(_PLAN_STATE, "recorder", None)
    _PLAN_STATE.recorder = into
    try:
        yield into
    finally:
        _PLAN_STATE.recorder = prev


def _record(op: str, mode: ExecutionMode, depth: int, variant: str,
            used_kernel: bool) -> None:
    rec = getattr(_PLAN_STATE, "recorder", None)
    if rec is not None:
        rec.append(PlanDispatch(op, current_layer(), mode, depth, variant,
                                used_kernel))


def record_dispatch(op: str, variant: str, used_kernel: bool = False) -> None:
    """Public trace-time dispatch record for non-sidebar hot-path ops
    (e.g. ``kvpool.gather_blocks`` — the observable that lets tests
    assert the paged-kernel segment issues ZERO pool-wide copies)."""
    plan = current_plan()
    _record(op, plan.mode, plan.depth, variant, used_kernel)


def sidebar_mlp(
    x: Array,
    w1: Array,
    w2: Array,
    activation: str | Callable = "relu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    use_kernel: bool | None = None,
    interpret: bool = False,
    pipelined: bool | None = None,
    depth: int | None = None,
) -> Array:
    """y = f(x @ w1) @ w2 — fused sidebar kernel when eligible.

    ``pipelined`` selects the T-deep ring variant and ``depth`` its ring
    depth; when None they follow the ambient ``execution_plan`` resolved
    for the layer currently being traced (``layer_scope``):
    SIDEBAR_PIPELINED => pipelined at the plan's depth, FLEXIBLE_DMA =>
    the unfused three-dispatch path (producer matmul, standalone host
    activation with the intermediate crossing HBM, consumer matmul),
    SIDEBAR / MONOLITHIC => the serial fused kernel. All variants are
    numerically equivalent.
    """
    m, d = x.shape
    _, f = w1.shape
    eligible = _tileable(m, 8) and _tileable(f) and _tileable(d)
    use = (
        use_kernel
        if use_kernel is not None
        else (eligible and (_on_tpu() or interpret))
    )
    plan = current_plan()
    dma = (
        plan.mode is ExecutionMode.FLEXIBLE_DMA
        and pipelined is None
        and use_kernel is None
    )
    if pipelined is None:
        pipelined = plan.mode is ExecutionMode.SIDEBAR_PIPELINED
    if depth is None:
        if plan.mode is ExecutionMode.SIDEBAR_PIPELINED:
            depth = plan.depth  # the planner's scored choice, verbatim
        else:
            depth = 2 if pipelined else 1  # explicit opt-in: classic ring
    if dma:
        _record("sidebar_mlp", plan.mode, 1, "dma", use)
        h = sidebar_matmul(x, w1, "identity", table=table,
                           use_kernel=use_kernel, interpret=interpret)
        h = host_activation(h.astype(x.dtype), activation, table=table,
                            use_kernel=use_kernel, interpret=interpret)
        return sidebar_matmul(h.astype(x.dtype), w2, "identity", table=table,
                              use_kernel=use_kernel, interpret=interpret)
    if use:
        if pipelined:
            _record("sidebar_mlp", plan.mode, depth, "pipelined", True)
            return _mlp_kernel_pipelined(
                x, w1, w2, activation, table=table, depth=depth,
                interpret=interpret,
            )
        _record("sidebar_mlp", plan.mode, depth, "serial", True)
        return _mlp_kernel(x, w1, w2, activation, table=table,
                           interpret=interpret)
    _record("sidebar_mlp", plan.mode, depth, "ref", False)
    return ref.sidebar_mlp_ref(x, w1, w2, activation, table)


def sidebar_gated_mlp(
    x: Array,
    w_gate: Array,
    w_up: Array,
    w_down: Array,
    activation: str | Callable = "silu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    use_kernel: bool | None = None,
    interpret: bool = False,
) -> Array:
    """y = (f(x@Wg) * (x@Wu)) @ Wd — fused gated sidebar kernel."""
    m, d = x.shape
    _, f = w_gate.shape
    eligible = _tileable(m, 8) and _tileable(f) and _tileable(d)
    use = (
        use_kernel
        if use_kernel is not None
        else (eligible and (_on_tpu() or interpret))
    )
    plan = current_plan()
    if use:
        _record("sidebar_gated_mlp", plan.mode, 1, "serial", True)
        return _gated_kernel(x, w_gate, w_up, w_down, activation,
                             table=table, interpret=interpret)
    _record("sidebar_gated_mlp", plan.mode, 1, "ref", False)
    return ref.sidebar_gated_mlp_ref(x, w_gate, w_up, w_down, activation, table)


def sidebar_matmul(
    a: Array,
    b: Array,
    activation: str | Callable = "identity",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    use_kernel: bool | None = None,
    interpret: bool = False,
) -> Array:
    m, k = a.shape
    _, n = b.shape
    eligible = _tileable(m, 8) and _tileable(n) and _tileable(k)
    use = (
        use_kernel
        if use_kernel is not None
        else (eligible and (_on_tpu() or interpret))
    )
    if use:
        return _matmul_kernel(a, b, activation, table=table, interpret=interpret)
    return ref.sidebar_matmul_ref(a, b, activation, table)


def host_activation(
    x: Array,
    activation: str | Callable = "relu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    use_kernel: bool | None = None,
    interpret: bool = False,
) -> Array:
    """The FLEXIBLE_DMA standalone host step (own launch, HBM round-trip).

    Eligibility is prechecked (``activations.tileable`` — the same block
    planning the kernel itself runs) like every other op here, instead of
    catching the kernel's shape ValueError: control flow stays exception-
    free and an explicit ``use_kernel=True`` on an untileable shape fails
    loudly instead of silently routing to the oracle.
    """
    eligible = x.ndim >= 1 and _activations.tileable(
        x.shape, activation, table=table
    )
    use = (
        use_kernel
        if use_kernel is not None
        else (eligible and (_on_tpu() or interpret))
    )
    if use:
        return _activation_kernel(x, activation, table=table,
                                  interpret=interpret)
    return ref.activation_ref(x, activation, table)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    use_kernel: bool | None = None,
    interpret: bool = False,
    block_q: int = 128,
    block_k: int = 128,
) -> Array:
    b, hq, s, d = q.shape
    t = k.shape[2]
    eligible = (
        _tileable(min(s, block_q), 8)
        and s % min(block_q, s) == 0
        and t % min(block_k, t) == 0
    )
    use = (
        use_kernel
        if use_kernel is not None
        else (eligible and (_on_tpu() or interpret))
    )
    if use:
        return _flash_kernel(
            q, k, v, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)


def paged_attention_gqa(
    q: Array,                     # (B, H, Dh) — single decode token/row
    k_pool: Array,                # (P, Hkv, bs, Dh) pooled blocks
    v_pool: Array,
    block_tables: Array,          # (B, nb) int32, host-validated in-bounds
    lengths: Array,               # (B,) int32 — row attends kpos < length
    *,
    scale: float,
    k_scale: Array | None = None,  # (P, Hkv, bs) fp32 int8-KV scales
    v_scale: Array | None = None,
    compute_dtype=None,
    use_kernel: bool | None = None,
    interpret: bool = False,
) -> Array:
    """Paged GQA decode attention, in place on the block pool.

    Dispatch mirrors the sidebar MLP ops: the Pallas kernel (table rows
    in SMEM, per-block DMA, online softmax) when eligible on TPU or
    under ``interpret``; otherwise the jnp reference — the slab path's
    dense math fed by a per-layer table gather, bit-identical to it. A
    layer planned ``FLEXIBLE_DMA`` also takes the gather route (the
    dense-view round-trip IS that mode's memory discipline), recorded as
    variant ``"dma"`` so per-layer plan choices stay observable.

    Under tensor-parallel serving this runs INSIDE the shard_map body,
    so ``h``/``hkv`` are per-shard locals (``H/tp``, ``Hkv/tp``) and the
    pool leaves are the shard's own head slice. Eligibility is decided
    on those locals — and since ``make_tp_spec`` only admits degrees
    dividing both head counts, the group size ``h // hkv`` (and hence
    kernel eligibility) is invariant across TP degrees: a config that
    takes the kernel solo takes it on every shard, with no collectives
    inside the kernel.
    """
    _, h, dh = q.shape
    _, hkv, bs, _ = k_pool.shape
    eligible = h % hkv == 0 and dh % 8 == 0 and bs % 4 == 0
    plan = current_plan()
    dma = plan.mode is ExecutionMode.FLEXIBLE_DMA and use_kernel is None
    use = (
        use_kernel
        if use_kernel is not None
        else (eligible and not dma and (_on_tpu() or interpret))
    )
    if use:
        _record("paged_attention", plan.mode, plan.depth, "paged", True)
        return _paged_gqa_kernel(
            q, k_pool, v_pool, block_tables, lengths, scale=scale,
            k_scale=k_scale, v_scale=v_scale, interpret=interpret,
        )
    _record("paged_attention", plan.mode, plan.depth,
            "dma" if dma else "ref", False)
    return _paged_gqa_ref(
        q, k_pool, v_pool, block_tables, lengths, scale=scale,
        k_scale=k_scale, v_scale=v_scale, compute_dtype=compute_dtype,
    )


def paged_attention_mla(
    q_lat: Array,                 # (B, H, kvr) fp32 — q @ absorbed w_uk
    q_rope: Array,                # (B, H, rope)
    ckv_pool: Array,              # (P, bs, kvr) pooled latent blocks
    krope_pool: Array,            # (P, bs, rope)
    block_tables: Array,
    lengths: Array,
    *,
    scale: float,
    compute_dtype=None,
    use_kernel: bool | None = None,
    interpret: bool = False,
) -> Array:
    """Paged MLA absorbed decode; returns ctx_lat (B, H, kvr) fp32.

    Same dispatch contract as ``paged_attention_gqa``; the w_uk
    projection (before) and w_uv absorption (after) stay with the model.
    Under TP only ``H`` is sharded (the latent pool replicates — it is
    head-free), so eligibility, decided on ``kvr``/``rope``/``bs``
    alone, is TP-degree-invariant by construction.
    """
    _, _, kvr = q_lat.shape
    rope = q_rope.shape[-1]
    bs = ckv_pool.shape[1]
    eligible = kvr % 8 == 0 and rope % 4 == 0 and bs % 4 == 0
    plan = current_plan()
    dma = plan.mode is ExecutionMode.FLEXIBLE_DMA and use_kernel is None
    use = (
        use_kernel
        if use_kernel is not None
        else (eligible and not dma and (_on_tpu() or interpret))
    )
    if use:
        _record("paged_attention", plan.mode, plan.depth, "paged", True)
        return _paged_mla_kernel(
            q_lat, q_rope, ckv_pool, krope_pool, block_tables, lengths,
            scale=scale, interpret=interpret,
        )
    _record("paged_attention", plan.mode, plan.depth,
            "dma" if dma else "ref", False)
    return _paged_mla_ref(
        q_lat, q_rope, ckv_pool, krope_pool, block_tables, lengths,
        scale=scale, compute_dtype=compute_dtype,
    )
