"""Paged decode-attention kernels: the block table IS the DMA program.

The paged scheduler used to gather every request's KV blocks into a
dense slab per segment and scatter them back — a full-pool round-trip
per boundary, the exact DMA-dependent shape the paper shows losing to
in-place consumption. These kernels read the pool in place: the block
table and per-row lengths ride in SMEM (``PrefetchScalarGridSpec``), and
each grid step's ``index_map`` picks the *physical* block to DMA into
VMEM straight out of the table — so attention walks a request's logical
blocks wherever they physically live, with online-softmax accumulation
across the row's blocks (the flash_attention recurrence) in VMEM
scratch. No dense view is ever materialized.

Two families:

  * **GQA decode** (one query token per row): q (B, H, Dh) against
    pooled k/v (P, Hkv, bs, Dh), grid (B, Hkv, nb) with the block axis
    minor. int8-KV pools dequantize in-kernel from the pooled
    per-(position, head) scales — the dequantized block never touches
    HBM.
  * **MLA absorbed decode**: q already projected into latent space
    (q_lat (B, H, kvr) fp32 + q_rope (B, H, rope)) against the pooled
    compressed cache (c_kv (P, bs, kvr), k_rope (P, bs, rope)), grid
    (B, nb); returns the latent context ctx_lat (B, H, kvr) fp32 — the
    w_uk/w_uv absorption stays outside (cheap, per-head-free matmuls).

The jnp references mirror ``models.attention``'s dense math op-for-op
(same einsums, fp32 accumulation, ``-1e30`` mask, ``jax.nn.softmax``):
they gather a dense view per layer through the table — narrower than
the retired pool-wide slab round-trip, and bit-identical to it wherever
the mask looks, because masked logits at ``-1e30`` underflow to exactly
0.0 in fp32, leaving softmax denominators and PV sums unchanged by any
junk behind the mask. The Pallas kernels accumulate online instead, so
they match the references to fp32 tolerance, not bitwise.

Tables must be validated in-bounds host-side before dispatch (the
scheduler's ``kvpool.validate_tables``): the reference gathers declare
``mode="promise_in_bounds"`` and the kernel's table-indexed DMA has no
bounds check at all.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Table gathers (the references' view builders). ``promise_in_bounds``:
# the scheduler validates tables host-side before every dispatch.
# ---------------------------------------------------------------------------


def _gather_kv(leaf: Array, tables: Array) -> Array:
    """(P, Hkv, bs, Dh) pool -> (B, Hkv, nb*bs, Dh) dense view."""
    g = leaf.at[tables].get(mode="promise_in_bounds")
    g = jnp.moveaxis(g, 1, 2)                     # (B, Hkv, nb, bs, Dh)
    return g.reshape(g.shape[0], g.shape[1], -1, leaf.shape[-1])


def _gather_scale(leaf: Array, tables: Array) -> Array:
    """(P, Hkv, bs) pooled scales -> (B, Hkv, nb*bs)."""
    g = leaf.at[tables].get(mode="promise_in_bounds")
    g = jnp.moveaxis(g, 1, 2)                     # (B, Hkv, nb, bs)
    return g.reshape(g.shape[0], g.shape[1], -1)


def _gather_lat(leaf: Array, tables: Array) -> Array:
    """(P, bs, r) pooled MLA latent/rope -> (B, nb*bs, r)."""
    g = leaf.at[tables].get(mode="promise_in_bounds")
    return g.reshape(g.shape[0], -1, leaf.shape[-1])


# ---------------------------------------------------------------------------
# References: the dense slab math, gathered through the table.
# ---------------------------------------------------------------------------


def paged_gqa_reference(
    q: Array,                     # (B, H, Dh) — one decode token per row
    k_pool: Array,                # (P, Hkv, bs, Dh)
    v_pool: Array,
    tables: Array,                # (B, nb) int32 physical block ids
    lengths: Array,               # (B,) int32 — row attends kpos < length
    *,
    scale: float,
    k_scale: Array | None = None,  # (P, Hkv, bs) fp32 int8-KV scales
    v_scale: Array | None = None,
    compute_dtype=None,
) -> Array:
    """Exactly ``models.attention._attend_direct_offset`` at s=1, fed by
    the table gather — the slab path's math, op for op."""
    b, h, dh = q.shape
    hkv = k_pool.shape[1]
    group = h // hkv
    dt = compute_dtype if compute_dtype is not None else q.dtype
    k = _gather_kv(k_pool, tables)
    v = _gather_kv(v_pool, tables)
    if k_scale is not None:
        k = (k.astype(jnp.float32)
             * _gather_scale(k_scale, tables)[..., None]).astype(dt)
        v = (v.astype(jnp.float32)
             * _gather_scale(v_scale, tables)[..., None]).astype(dt)
    else:
        k, v = k.astype(dt), v.astype(dt)
    t = k.shape[2]
    qg = q.reshape(b, hkv, group, 1, dh)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(t)[None, :] <= (lengths - 1)[:, None]       # (B, t)
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, dh).astype(q.dtype)


def paged_mla_reference(
    q_lat: Array,                 # (B, H, kvr) fp32 — q @ absorbed w_uk
    q_rope: Array,                # (B, H, rope)
    ckv_pool: Array,              # (P, bs, kvr)
    krope_pool: Array,            # (P, bs, rope)
    tables: Array,                # (B, nb)
    lengths: Array,               # (B,)
    *,
    scale: float,
    compute_dtype=None,
) -> Array:
    """The MLA absorbed-decode logits/softmax/context, table-gathered;
    returns ctx_lat (B, H, kvr) fp32 (w_uv absorption stays outside)."""
    dt = compute_dtype if compute_dtype is not None else q_rope.dtype
    ckv = _gather_lat(ckv_pool, tables).astype(dt)        # (B, T, kvr)
    krope = _gather_lat(krope_pool, tables).astype(dt)    # (B, T, rope)
    t = ckv.shape[1]
    ql = q_lat[:, None]                                   # (B, 1, H, kvr)
    qr = q_rope[:, None].astype(jnp.float32)
    logits = (
        jnp.einsum("bshr,btr->bhst", ql, ckv.astype(jnp.float32))
        + jnp.einsum("bshn,btn->bhst", qr, krope.astype(jnp.float32))
    ) * scale
    end = (lengths - 1)[:, None, None, None]
    mask = jnp.arange(t)[None, None, None, :] <= end
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", p, ckv.astype(jnp.float32))
    return ctx[:, 0]                                      # (B, H, kvr)


# ---------------------------------------------------------------------------
# GQA kernel: grid (B, Hkv, nb), block axis minor (online softmax).
# ---------------------------------------------------------------------------


def _gqa_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, *rest,
                scale: float, block_size: int, n_blocks: int,
                quantized: bool, out_dtype):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    # blocks at or past the row's frontier hold junk (or another row's
    # data): skip them entirely — the causal mask in block form
    @pl.when(j * block_size < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (group, Dh)
        k = k_ref[0, 0]                           # (bs, Dh) — this row's
        v = v_ref[0, 0]                           # table[b, j] pool block
        if quantized:
            k = k.astype(jnp.float32) * ks_ref[0, 0][:, None]
            v = v.astype(jnp.float32) * vs_ref[0, 0][:, None]
        # static primitive #1 (MXU): q·K^T on the in-place pool block
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                 # (group, bs)
        kpos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        # flexible step (VPU): online softmax across the row's blocks
        m_prev = m_ref[...]
        m_curr = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_curr)
        p = jnp.exp(s - m_curr)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        m_ref[...] = m_curr
        # static primitive #2 (MXU): weighted value accumulation
        pv = jnp.dot(p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(j == n_blocks - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(out_dtype)


def paged_gqa_kernel(
    q: Array,
    k_pool: Array,
    v_pool: Array,
    tables: Array,
    lengths: Array,
    *,
    scale: float,
    k_scale: Array | None = None,
    v_scale: Array | None = None,
    interpret: bool = False,
) -> Array:
    """Paged GQA decode: q (B, H, Dh) against the pool in place."""
    b, h, dh = q.shape
    _, hkv, bs, _ = k_pool.shape
    if h % hkv:
        raise ValueError(f"GQA needs H % Hkv == 0, got {h} % {hkv}")
    group = h // hkv
    nb = tables.shape[1]
    qg = q.reshape(b, hkv, group, dh)
    quantized = k_scale is not None

    def q_map(bb, hh, jj, t, ln):
        return (bb, hh, 0, 0)

    def kv_map(bb, hh, jj, t, ln):
        # THE paged idiom: the physical block to DMA comes out of the
        # prefetched table, per grid step — no dense gather anywhere
        return (t[bb, jj], hh, 0, 0)

    def scale_map(bb, hh, jj, t, ln):
        return (t[bb, jj], hh, 0)

    in_specs = [
        pl.BlockSpec((1, 1, group, dh), q_map),
        pl.BlockSpec((1, 1, bs, dh), kv_map),
        pl.BlockSpec((1, 1, bs, dh), kv_map),
    ]
    args = [qg, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, bs), scale_map)] * 2
        args += [k_scale, v_scale]

    kernel = functools.partial(
        _gqa_kernel, scale=scale, block_size=bs, n_blocks=nb,
        quantized=quantized, out_dtype=q.dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hkv, nb),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, group, dh), q_map),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, dh), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), *args)
    return out.reshape(b, h, dh)


# ---------------------------------------------------------------------------
# MLA kernel: grid (B, nb) over the compressed pooled cache.
# ---------------------------------------------------------------------------


def _mla_kernel(tables_ref, lengths_ref, ql_ref, qr_ref, ckv_ref, kr_ref,
                o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                block_size: int, n_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    @pl.when(j * block_size < length)
    def _body():
        ql = ql_ref[0]                            # (H, kvr) fp32
        qr = qr_ref[0].astype(jnp.float32)        # (H, rope)
        ckv = ckv_ref[0].astype(jnp.float32)      # (bs, kvr) — table[b, j]
        kr = kr_ref[0].astype(jnp.float32)        # (bs, rope)
        # absorbed logits: latent + shared-rope contractions on the block
        s = (
            jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
            + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        ) * scale                                 # (H, bs)
        kpos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_curr = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_curr)
        p = jnp.exp(s - m_curr)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        m_ref[...] = m_curr
        pv = jnp.dot(p, ckv, preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv  # (H, kvr)

    @pl.when(j == n_blocks - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = acc_ref[...] / l


def paged_mla_kernel(
    q_lat: Array,
    q_rope: Array,
    ckv_pool: Array,
    krope_pool: Array,
    tables: Array,
    lengths: Array,
    *,
    scale: float,
    interpret: bool = False,
) -> Array:
    """Paged MLA absorbed decode; returns ctx_lat (B, H, kvr) fp32."""
    b, h, kvr = q_lat.shape
    rope = q_rope.shape[-1]
    _, bs, _ = ckv_pool.shape
    nb = tables.shape[1]

    def q_map(bb, jj, t, ln):
        return (bb, 0, 0)

    def pool_map(bb, jj, t, ln):
        return (t[bb, jj], 0, 0)

    kernel = functools.partial(_mla_kernel, scale=scale, block_size=bs,
                               n_blocks=nb)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, nb),
            in_specs=[
                pl.BlockSpec((1, h, kvr), q_map),
                pl.BlockSpec((1, h, rope), q_map),
                pl.BlockSpec((1, bs, kvr), pool_map),
                pl.BlockSpec((1, bs, rope), pool_map),
            ],
            out_specs=pl.BlockSpec((1, h, kvr), q_map),
            scratch_shapes=[
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, 1), jnp.float32),
                pltpu.VMEM((h, kvr), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, kvr), jnp.float32),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q_lat.astype(jnp.float32), q_rope, ckv_pool, krope_pool)
