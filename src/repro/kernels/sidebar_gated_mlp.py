"""Fused gated (SwiGLU-family) Sidebar MLP: y = (f(x@Wg) ⊙ (x@Wu)) @ Wd.

The gated variant of ``sidebar_mlp`` — the hot pattern of 8 of the 10
assigned architectures (llama/deepseek/qwen/zamba/llama4/dsv3 experts).
TWO sidebar tiles live in VMEM scratch (gate and up paths); the flexible
function (from the host FunctionTable) and the elementwise gate product
run on the VPU between the MXU contractions; only y reaches HBM.

Tiling (BlockSpec):

  grid = (M/bm, F/bf), F minor (sequential accumulation axis).
  x       : (bm, D)  at (i, 0)
  wg, wu  : (D, bf)  at (0, j)
  wd      : (bf, D)  at (j, 0)
  out     : (bm, D)  at (i, 0)   — revisited across j (accumulate)
  scratch : sidebar_g (bm, bf) fp32, sidebar_u (bm, bf) fp32,
            acc (bm, D) fp32
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants
from repro.core.function_table import DEFAULT_TABLE, FunctionTable
from repro.kernels.sidebar_mlp import SUBLANE, LANE

Array = jax.Array


def choose_tiles(m: int, d: int, f: int, itemsize: int = 2,
                 vmem_budget: int = constants.VMEM_BYTES_PER_CHIP // 8) -> tuple[int, int]:
    for bm in (256, 128, 64, 32, 16, 8):
        if bm > m or m % bm:
            continue
        for bf in (1024, 512, 256, 128):
            if bf > f or f % bf:
                continue
            ws = (
                bm * d * itemsize          # x tile
                + 3 * d * bf * itemsize    # wg, wu panels + wd panel
                + bm * d * itemsize        # out tile
                + 8 * bm * bf              # two fp32 sidebars
                + 4 * bm * d               # accumulator
            )
            if ws <= vmem_budget:
                return bm, bf
    return SUBLANE, LANE


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, sb_g, sb_u, acc_ref, *,
            activation: Callable, n_f_blocks: int, out_dtype):
    j = pl.program_id(1)

    # static primitives #1/#2 (MXU): both halves into the sidebars
    sb_g[...] = jnp.dot(x_ref[...], wg_ref[...],
                        preferred_element_type=jnp.float32)
    sb_u[...] = jnp.dot(x_ref[...], wu_ref[...],
                        preferred_element_type=jnp.float32)

    # flexible function + gate product (VPU) on sidebar-resident tiles
    h = activation(sb_g[...]) * sb_u[...]

    # static primitive #3 (MXU): consume, accumulate
    part = jnp.dot(h.astype(wd_ref.dtype), wd_ref[...],
                   preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(j > 0)
    def _accum():
        acc_ref[...] += part

    @pl.when(j == n_f_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def sidebar_gated_mlp(
    x: Array,
    w_gate: Array,
    w_up: Array,
    w_down: Array,
    activation: str | Callable = "silu",
    *,
    table: FunctionTable = DEFAULT_TABLE,
    block_m: int | None = None,
    block_f: int | None = None,
    interpret: bool = False,
) -> Array:
    m, d = x.shape
    _, f = w_gate.shape
    if w_up.shape != (d, f) or w_down.shape[0] != f:
        raise ValueError(
            f"shape mismatch: x{x.shape} wg{w_gate.shape} wu{w_up.shape} "
            f"wd{w_down.shape}"
        )
    d2 = w_down.shape[1]
    fn = table.lookup(activation) if isinstance(activation, str) else activation

    bm, bf = choose_tiles(m, d, f, x.dtype.itemsize)
    bm, bf = block_m or bm, block_f or bf
    if m % bm or f % bf:
        raise ValueError(f"M={m}%{bm} or F={f}%{bf} != 0")
    n_f_blocks = f // bf

    kernel = functools.partial(
        _kernel, activation=fn, n_f_blocks=n_f_blocks, out_dtype=x.dtype
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n_f_blocks),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d2), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bf), jnp.float32),   # sidebar: gate path
            pltpu.VMEM((bm, bf), jnp.float32),   # sidebar: up path
            pltpu.VMEM((bm, d2), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
