"""Exact analytic FLOP model per (arch x shape) — the roofline cross-check.

``cost_analysis()`` on XLA counts each ``while`` (scan) body ONCE, not
x trip-count (verified in tests/test_roofline.py), so scanned models are
under-counted by the product of their scan trips. Two remedies, both
reported in §Roofline:

  * ``scan_correction(cfg, cell)`` — the known trip product of the
    layer/microbatch scans (applied to the measured HLO numbers),
  * ``analytic_fwd_flops`` / ``analytic_step_flops`` — exact per-arch
    math (attention quadratic terms incl. causal/2, MoE active experts,
    SSD/WKV chunk contractions, embeddings) used as the denominator
    cross-check and for MFU-style reporting.

Conventions: 1 MAC = 2 FLOPs. train = fwd + remat-recompute + bwd
(= 4x fwd under full remat, 3x without).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.layers import padded_vocab
from repro.models.ssm import ssm_dims


def _attn_flops(cfg: ModelConfig, tokens: int, kv_len: int,
                causal: bool = True) -> float:
    """Per-layer attention flops for `tokens` queries against kv_len."""
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * tokens * d * (h * dh + 2 * hkv * dh) + 2 * tokens * h * dh * d
    av = 2 * 2 * tokens * kv_len * h * dh
    if causal and tokens == kv_len:
        av *= 0.5
    return proj + av


def _mla_flops(cfg: ModelConfig, tokens: int, kv_len: int) -> float:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vdh = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    proj = 2 * tokens * (d * qr + qr * h * (nope + rope) + d * kvr + d * rope)
    expand = 2 * kv_len * kvr * h * (nope + vdh)
    av = 2 * 2 * tokens * kv_len * h * (nope + rope + vdh) / 2  # qk + pv avg
    causal = 0.5 if tokens == kv_len else 1.0
    out = 2 * tokens * h * vdh * d
    return proj + expand + av * 2 * causal + out


def _mlp_flops(cfg: ModelConfig, tokens: int, d_ff: int | None = None) -> float:
    f = d_ff or cfg.d_ff
    mats = 3 if cfg.gated_mlp else 2
    return 2 * tokens * cfg.d_model * f * mats


def _moe_flops(cfg: ModelConfig, tokens: int) -> float:
    router = 2 * tokens * cfg.d_model * cfg.num_experts
    routed = cfg.experts_per_token * 2 * tokens * cfg.d_model * cfg.moe_d_ff * 3
    shared = (2 * tokens * cfg.d_model * cfg.moe_d_ff * 3
              * cfg.num_shared_experts)
    return router + routed + shared


def _mamba2_flops(cfg: ModelConfig, tokens: int) -> float:
    d = cfg.d_model
    d_in, h, p = ssm_dims(cfg)
    n = cfg.ssm_state
    q = min(cfg.ssm_chunk, max(tokens, 1))
    proj = 2 * tokens * d * (2 * d_in + 2 * n + h) + 2 * tokens * d_in * d
    # SSD per chunk: CB^T (Q^2 N) + weighted X (Q^2 H P... as (Q,S,H)x(S,H,P))
    nc = max(tokens // q, 1)
    intra = nc * (2 * q * q * n + 2 * q * q * h * p)
    inter = nc * (2 * q * n * h * p * 2)
    return proj + intra + inter


def _rwkv_flops(cfg: ModelConfig, tokens: int) -> float:
    d, f = cfg.d_model, cfg.d_ff
    h = d // cfg.rwkv_head_dim
    k = cfg.rwkv_head_dim
    q = min(64, max(tokens, 1))
    nc = max(tokens // q, 1)
    proj = 2 * tokens * d * d * 4 + 2 * tokens * d * d  # r,k,v,g + out
    lora = 2 * tokens * d * (5 * 32 + 64) * 2
    wkv = nc * (3 * q * q * h * k + 2 * q * q * h * k + 4 * q * h * k * k)
    cmix = 2 * tokens * d * f * 2 + 2 * tokens * d * d
    return proj + lora + wkv + cmix


def analytic_fwd_flops(cfg: ModelConfig, tokens: int, kv_len: int | None = None,
                       batch: int = 1) -> float:
    """Exact forward flops for `tokens` total tokens (batch folded in),
    attending to kv_len (defaults to tokens/batch per sequence)."""
    t = tokens
    seq_kv = kv_len if kv_len is not None else t // max(batch, 1)
    total = 2.0 * t * cfg.d_model * padded_vocab(cfg.vocab_size)  # unembed
    if cfg.family == "ssm":
        total += cfg.num_layers * _rwkv_flops(cfg, t)
        return total
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.attn_every
        total += cfg.num_layers * _mamba2_flops(cfg, t)
        total += n_groups * (_attn_flops(cfg, t, seq_kv * 1) + _mlp_flops(cfg, t))
        return total
    if cfg.family == "audio":
        enc_t = batch * cfg.encoder_seq
        total += cfg.encoder_layers * (
            _attn_flops(cfg, enc_t, cfg.encoder_seq, causal=False)
            + _mlp_flops(cfg, enc_t)
        )
        total += cfg.num_layers * (
            _attn_flops(cfg, t, seq_kv)
            + _attn_flops(cfg, t, cfg.encoder_seq, causal=False)
            + _mlp_flops(cfg, t)
        )
        return total
    # dense / moe / vlm
    for i in range(cfg.num_layers):
        if cfg.use_mla:
            total += _mla_flops(cfg, t, seq_kv)
        else:
            total += _attn_flops(cfg, t, seq_kv)
        is_moe = cfg.num_experts and i >= cfg.first_dense_layers
        total += _moe_flops(cfg, t) if is_moe else _mlp_flops(cfg, t)
        if cfg.family == "vlm" and cfg.cross_attn_every and \
                (i + 1) % cfg.cross_attn_every == 0:
            total += _attn_flops(cfg, t, cfg.num_image_tokens, causal=False)
    return total


def analytic_step_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Exact flops of the lowered step for this cell."""
    b = cell.global_batch
    if cell.kind == "train":
        fwd = analytic_fwd_flops(cfg, b * cell.seq_len, batch=b)
        remat = 1.0 if cfg.remat != "none" else 0.0
        return fwd * (3.0 + remat)
    if cell.kind == "prefill":
        return analytic_fwd_flops(cfg, b * cell.seq_len, batch=b)
    # decode: one token per sequence against the full cache
    return analytic_fwd_flops(cfg, b, kv_len=cell.seq_len, batch=b)


# ---------------------------------------------------------------------------
# Scan trip-count corrections for the measured HLO numbers.
# ---------------------------------------------------------------------------

def layer_scan_correction(cfg: ModelConfig) -> float:
    """Layer-loop trips / measured-once bodies (leaf-body approximation)."""
    if cfg.family == "vlm":
        n_groups = cfg.num_layers // cfg.cross_attn_every
        # bodies measured: self + cross; trips: (per-1) self + 1 cross per group
        return (cfg.num_layers) / 2.0
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.attn_every
        bodies = 3.0 if cfg.num_layers % cfg.attn_every else 2.0
        return (cfg.num_layers + n_groups) / bodies
    if cfg.family == "audio":
        return (cfg.num_layers + cfg.encoder_layers) / 2.0
    if cfg.num_experts and cfg.first_dense_layers:
        return cfg.num_layers / 2.0
    return float(cfg.num_layers)


def scan_correction(cfg: ModelConfig, cell: ShapeCell,
                    n_micro: int = 1) -> float:
    """Multiplier for cost_analysis flops/bytes of the lowered step.

    Covers the layer scan and the microbatch-accumulation scan. KNOWN
    RESIDUAL UNDERCOUNT (documented in EXPERIMENTS.md): inner chunk scans
    (chunked attention at 32k prefill, SSD/WKV chunk loops) are still
    counted once — the analytic column is exact for those.
    """
    k = layer_scan_correction(cfg)
    if cell.kind == "train":
        k *= max(n_micro, 1)
    return k


# ---------------------------------------------------------------------------
# Analytic byte model (fused-TPU minimum traffic; the roofline denominator).
# ---------------------------------------------------------------------------

def _param_bytes(cfg: ModelConfig) -> float:
    from repro.launch.roofline import count_params
    from repro.models import layers as L
    from repro.models.registry import get_model

    total, _, routed = count_params(get_model(cfg).param_specs(cfg, L.HOST))
    itemsize = 2  # bf16 params
    if cfg.num_experts:
        active = total - routed * (1.0 - cfg.experts_per_token / cfg.num_experts)
        return total * itemsize, active * itemsize
    return total * itemsize, total * itemsize


def _cache_bytes(cfg: ModelConfig, batch: int, kv_len: int) -> float:
    """Persistent decode-state bytes touched per decode step."""
    kv_item = 1 if cfg.kv_cache_dtype.__name__ == "int8" else 2
    if cfg.use_mla:
        per_tok = cfg.kv_lora_rank + cfg.rope_head_dim
        return cfg.num_layers * batch * kv_len * per_tok * 2
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_head_dim
        return cfg.num_layers * batch * h * cfg.rwkv_head_dim**2 * 4
    if cfg.family == "hybrid":
        n_groups = cfg.num_layers // cfg.attn_every
        d_in = cfg.ssm_expand * cfg.d_model
        ssm = cfg.num_layers * batch * (d_in // cfg.ssm_head_dim) * \
            cfg.ssm_state * cfg.ssm_head_dim * 4
        kv = n_groups * batch * cfg.num_kv_heads * cfg.head_dim * kv_len * \
            2 * kv_item
        return ssm + kv
    layers = cfg.num_layers
    kv = layers * batch * cfg.num_kv_heads * cfg.head_dim * kv_len * 2 * kv_item
    if cfg.family == "audio":
        kv += cfg.num_layers * batch * cfg.encoder_seq * cfg.d_model * 2
    return kv


def analytic_step_bytes(cfg: ModelConfig, cell: ShapeCell,
                        n_micro: int = 1) -> float:
    """Fused-TPU minimum HBM bytes for the lowered step (global).

    train:   weights re-read per microbatch x (fwd + remat + bwd-wgrad)
             + optimizer state sweep (read m,v,p fp32-ish + writes)
             + boundary activations (saved layer inputs + grads, 2 passes)
    prefill: weights once + activations once + cache write
    decode:  active weights once + full cache read + cache write
    """
    p_bytes, p_active = _param_bytes(cfg)
    b, s = cell.global_batch, cell.seq_len
    act_item = 2
    if cell.kind == "train":
        tokens = b * s
        weights = 3.0 * n_micro * p_bytes            # fwd + remat + bwd
        opt = 14.0 * (p_bytes / 2)                    # p,g,m,v fp32-ish sweep
        acts = 4.0 * tokens * cfg.d_model * cfg.num_layers * act_item
        return weights + opt + acts
    if cell.kind == "prefill":
        tokens = b * s
        acts = 2.0 * tokens * cfg.d_model * cfg.num_layers * act_item
        return p_bytes + acts + _cache_bytes(cfg, b, s)
    # decode
    return p_active + _cache_bytes(cfg, b, s) + 2 * b * cfg.d_model * \
        cfg.num_layers * act_item
