import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Per cell this driver:
  1. builds the FULL config and the production mesh,
  2. assembles abstract params / optimizer state / caches
     (ShapeDtypeStruct trees — zero allocation),
  3. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...)
     .compile()`` for the cell's step function:
        train_4k     -> train_step (fwd+bwd+AdamW, grad-accum scan)
        prefill_32k  -> prefill_step (fwd + KV-cache write)
        decode_*     -> serve_step (one token against the cache)
  4. records memory_analysis / cost_analysis / collective schedule and the
     roofline terms into results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs as cfglib
from repro.configs.base import TrainConfig
from repro.launch import roofline as rl
from repro.launch.input_specs import batch_shardings, input_specs
from repro.launch.mesh import make_production_mesh, mesh_info, num_chips
from repro.launch.serve import make_serve_step
from repro.launch.train import make_train_step
from repro.models import layers as L
from repro.models.registry import get_model
from repro.optim.optimizer import abstract_state, state_shardings

from jax.sharding import NamedSharding, PartitionSpec as P


def _train_cfg_for(arch: str) -> TrainConfig:
    import jax.numpy as jnp

    # bf16 moments for the two largest configs (16 GB/chip budget)
    if arch in ("llama3-405b", "deepseek-v3-671b", "llama-3.2-vision-90b"):
        return TrainConfig(moment_dtype=jnp.bfloat16, microbatch_per_device=1)
    return TrainConfig(microbatch_per_device=1)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_overrides: dict | None = None,
               tcfg_overrides: dict | None = None):
    """Returns (lowered, compiled, context dict)."""
    import dataclasses

    cfg = cfglib.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cell = cfglib.get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    minfo = mesh_info(mesh)
    api = get_model(cfg)

    specs = api.param_specs(cfg, minfo)
    params_abs = L.abstract(specs)
    p_shard = L.shardings(mesh, specs)
    mflops = rl.model_flops(cfg, cell, specs)

    with mesh:
        if cell.kind == "train":
            tcfg = _train_cfg_for(arch)
            if tcfg_overrides:
                tcfg = dataclasses.replace(tcfg, **tcfg_overrides)
            step_fn, n_micro, use_ef = make_train_step(
                cfg, tcfg, api, minfo, mesh, cell
            )
            opt_abs = abstract_state(params_abs, tcfg)
            o_shard = state_shardings(p_shard, mesh)
            b_shard = batch_shardings(cfg, cell, mesh, minfo)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, None, b_shard),
                out_shardings=(p_shard, o_shard, None, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, None,
                                   input_specs(cfg, cell))
        elif cell.kind == "prefill":
            cache_specs = api.cache_specs(cfg, minfo, cell.global_batch,
                                          cell.seq_len)
            cache_abs = L.abstract(cache_specs)
            c_shard = L.shardings(mesh, cache_specs)
            b_shard = batch_shardings(cfg, cell, mesh, minfo)

            from repro.parallel.hints import sharding_hints

            def prefill_step(params, batch, cache):
                with sharding_hints(mesh, minfo):
                    return api.prefill(params, cfg, batch, cache,
                                       minfo=minfo, mesh=mesh)

            jitted = jax.jit(
                prefill_step,
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, input_specs(cfg, cell),
                                   cache_abs)
        else:  # decode
            cache_specs = api.cache_specs(cfg, minfo, cell.global_batch,
                                          cell.seq_len)
            cache_abs = L.abstract(cache_specs)
            c_shard = L.shardings(mesh, cache_specs)
            serve = make_serve_step(cfg, api, minfo, mesh)
            tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jax.numpy.int32)
            pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
            batch_axes = tuple(minfo.fsdp) or None
            tok_shard = NamedSharding(
                mesh, L.sanitize_pspec(mesh, P(batch_axes, None), tok.shape)
            )
            mem_abs = None
            mem_shard = None
            if cfg.family == "audio":
                mem_abs = jax.ShapeDtypeStruct(
                    (cell.global_batch, cfg.encoder_seq, cfg.d_model), cfg.dtype
                )
            if cfg.family == "vlm":
                mem_abs = jax.ShapeDtypeStruct(
                    (cell.global_batch, cfg.num_image_tokens, cfg.d_model),
                    cfg.dtype,
                )
            if mem_abs is not None:
                mem_shard = NamedSharding(
                    mesh,
                    L.sanitize_pspec(mesh, P(batch_axes, None, None),
                                     mem_abs.shape),
                )

            jitted = jax.jit(
                serve,
                in_shardings=(p_shard, tok_shard, c_shard, None, mem_shard),
                out_shardings=(tok_shard, c_shard),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, tok, cache_abs, pos, mem_abs)

        compiled = lowered.compile()

    ctx = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": num_chips(mesh),
        "kind": cell.kind,
        "model_flops": mflops,
    }
    return lowered, compiled, ctx


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             force: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    out_path = os.path.join(
        outdir, f"{arch}__{shape_name}__{mesh_name}.json"
    )
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    t0 = time.time()
    try:
        lowered, compiled, ctx = lower_cell(arch, shape_name, multi_pod)
        terms = rl.analyze(
            compiled, chips=ctx["chips"], model_flops=ctx["model_flops"]
        )
        from repro.launch.hlo_analysis import analyze_hlo

        la = analyze_hlo(compiled.as_text())
        mem = compiled.memory_analysis()
        record = {
            **ctx,
            "ok": True,
            "compile_s": round(time.time() - t0, 1),
            "memory_analysis": str(mem),
            "roofline": terms.to_json(),
            "loop_aware": {
                "dot_flops_per_dev": la.dot_flops,
                "coll_bytes_per_dev": la.coll_bytes,
                "coll_bytes_total_per_dev": la.coll_bytes_total,
                "loops": la.loops,
                "unknown_trip_loops": la.unknown_trip_loops,
            },
        }
        print(
            f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
            f"({record['compile_s']}s) bottleneck={terms.bottleneck} "
            f"t=(c {terms.t_compute:.2e}, m {terms.t_memory:.2e}, "
            f"x {terms.t_collective:.2e})s "
            f"temp/dev={terms.bytes_per_device['temp']/2**30:.2f}GiB",
            flush=True,
        )
    except Exception as e:  # a failing cell is a bug — record it loudly
        record = {
            **{"arch": arch, "shape": shape_name, "mesh": mesh_name},
            "ok": False,
            "compile_s": round(time.time() - t0, 1),
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e}",
              flush=True)
    os.makedirs(outdir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = cfglib.all_cells()
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = 0
    for arch, shape_name in cells:
        for multi in meshes:
            rec = run_cell(arch, shape_name, multi, args.out, args.force)
            n_ok += bool(rec.get("ok"))
            n_fail += not rec.get("ok")
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
