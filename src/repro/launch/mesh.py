"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single device and use
``host_mesh``/no mesh.

Axes:
  * single-pod:  (16, 16)    -> ("data", "model")    = 256 chips
  * multi-pod:   (2, 16, 16) -> ("pod", "data", "model") = 512 chips

"data" (and "pod") carry batch + FSDP parameter sharding; "model" is
tensor/expert parallel. Cross-pod traffic is only the FSDP gradient
reduce-scatter / param all-gather over ("pod","data") — DCN-friendly.
"""

from __future__ import annotations

from repro.models.layers import MeshInfo
from repro.parallel.compat import auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return auto_mesh(shape, axes)


def mesh_info(mesh) -> MeshInfo:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return MeshInfo.from_axes(tuple(mesh.axis_names), sizes)


def make_host_mesh():
    """Single-device mesh with the production axis names (all size 1) —
    lets the same sharded step functions run on one CPU for smoke tests."""
    return auto_mesh((1, 1), ("data", "model"))


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
