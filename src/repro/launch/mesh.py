"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single device and use
``host_mesh``/no mesh.

Axes:
  * single-pod:  (16, 16)    -> ("data", "model")    = 256 chips
  * multi-pod:   (2, 16, 16) -> ("pod", "data", "model") = 512 chips

"data" (and "pod") carry batch + FSDP parameter sharding; "model" is
tensor/expert parallel. Cross-pod traffic is only the FSDP gradient
reduce-scatter / param all-gather over ("pod","data") — DCN-friendly.

Every mesh in the system uses exactly these axis names — ``mesh_info``
asserts it, so a hand-rolled mesh with drifting names fails loudly at
construction instead of silently missing the "model" TP specs.
"""

from __future__ import annotations

from repro.models.layers import MeshInfo
from repro.parallel.compat import auto_mesh

# the one canonical axis-name vocabulary, by mesh rank
CANONICAL_AXES = {
    2: ("data", "model"),
    3: ("pod", "data", "model"),
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    return auto_mesh(shape, CANONICAL_AXES[len(shape)])


def mesh_info(mesh) -> MeshInfo:
    names = tuple(mesh.axis_names)
    expected = CANONICAL_AXES.get(len(names))
    if names != expected:
        raise ValueError(
            f"mesh axes {names} diverge from the canonical "
            f"{expected or 'serving axis sets ' + str(tuple(CANONICAL_AXES.values()))}"
            " — every sharded program in launch/ keys its specs off these"
            " names"
        )
    sizes = dict(zip(names, mesh.devices.shape))
    return MeshInfo.from_axes(names, sizes)


def make_host_mesh(*, multi_pod: bool = False):
    """Single-device mesh with the production axis names (all size 1) —
    lets the same sharded step functions run on one CPU for smoke tests.
    ``multi_pod`` mirrors ``make_production_mesh``'s 3-axis name set so
    both axis vocabularies smoke through the identical step programs."""
    shape = (1, 1, 1) if multi_pod else (1, 1)
    return auto_mesh(shape, CANONICAL_AXES[len(shape)])


def make_serving_mesh(shape: tuple[int, ...]):
    """A serving mesh of the given shape over the visible devices, with
    the canonical axis names for its rank — ``(1, 2)`` is data=1 x
    model=2 tensor parallel. Total size must match what the shape asks
    for (``auto_mesh`` validates against the real device count)."""
    if len(shape) not in CANONICAL_AXES:
        raise ValueError(f"serving mesh must be rank 2 or 3, got {shape}")
    return auto_mesh(tuple(shape), CANONICAL_AXES[len(shape)])


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
