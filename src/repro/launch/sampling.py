"""Sampled decoding: temperature / top-k / top-p with position-keyed PRNG.

The serving paths (``launch.serve.Server`` scan and loop decode, and the
continuous-batching scheduler's segment decode) all sample through ONE
rule so their token streams are interchangeable:

  * every request owns a **base key** — ``fold_in(PRNGKey(seed), row)``
    where ``row`` is the request's batch row (``Server.generate``) or 0
    (one scheduler request == batch row 0 of a solo generate);
  * the token written at sequence index ``p`` is sampled with
    ``fold_in(base_key, p)`` — the key depends only on (seed, position),
    never on batch composition, slot index, segment length, or decode
    style. Scan and loop decode are bit-identical by construction, and a
    scheduler restarted mid-stream (resubmit prompt + tokens-so-far with
    the same seed) continues the exact stream it would have produced.

Per-row sampling *parameters* are traced arrays, so one compiled segment
program serves any mix of greedy and sampled slots: a greedy row carries
``temperature == 0`` and takes the ``argmax`` branch of ``jnp.where`` —
bit-identical to the pure-greedy path on the same logits.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How to turn logits into a token.

    temperature 0 is exact greedy argmax (bit-identical to passing no
    sampling at all); ``top_k``/``top_p`` of ``None`` disable the
    respective truncation.
    """

    temperature: float = 1.0
    top_k: int | None = None
    top_p: float | None = None
    seed: int = 0

    def __post_init__(self) -> None:
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k is not None and self.top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {self.top_k}")
        if self.top_p is not None and not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


def request_key(seed: int, row: int = 0) -> Array:
    """The base key of one request: row r of a batched generate, or a
    scheduler request (always row 0)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), row)


def sample_state(sp: SamplingParams, batch: int) -> dict:
    """Traced per-row sampling state for a whole batch sharing ``sp``.

    Rows get independent streams (base key folded by row index), so two
    identical prompts in one batch do not sample identical continuations.
    The ``top_k``/``top_p`` entries are OMITTED when disabled — the
    pytree structure is what jit specializes on, so the temperature-only
    common case never traces the O(V log V) truncation sorts.
    """
    keys = jax.vmap(lambda r: request_key(sp.seed, r))(jnp.arange(batch))
    state = {
        "key": keys,
        "temperature": jnp.full((batch,), sp.temperature, jnp.float32),
    }
    if sp.top_k is not None:
        state["top_k"] = jnp.full((batch,), sp.top_k, jnp.int32)
    if sp.top_p is not None:
        state["top_p"] = jnp.full((batch,), sp.top_p, jnp.float32)
    return state


def merge_rows(rows: list[tuple[Array, SamplingParams | None]]) -> dict:
    """Per-row state from heterogeneous requests (the scheduler's slots).

    ``rows`` holds ``(base_key, params-or-None)`` per slot; greedy slots
    (``None``) become temperature-0 rows, which sample as exact argmax.
    ``top_k``/``top_p`` entries appear only when SOME row enables them
    (disabled rows carry the no-op values 0 / 1.0); an all-disabled
    batch omits them so the truncation sorts are never traced.
    """
    import numpy as np

    keys = np.stack([np.asarray(k) for k, _ in rows])
    temp = np.asarray(
        [0.0 if sp is None else sp.temperature for _, sp in rows], np.float32
    )
    state = {"key": jnp.asarray(keys), "temperature": jnp.asarray(temp)}
    if any(sp is not None and sp.top_k is not None for _, sp in rows):
        state["top_k"] = jnp.asarray(
            [(sp.top_k or 0) if sp else 0 for _, sp in rows], jnp.int32)
    if any(sp is not None and sp.top_p is not None for _, sp in rows):
        state["top_p"] = jnp.asarray(
            [1.0 if sp is None or sp.top_p is None else sp.top_p
             for _, sp in rows], jnp.float32)
    return state


def sample_token_block(logits: Array, state: dict | None, pos) -> Array:
    """Sample one token per (row, chunk offset): the verifier's rule.

    ``logits`` (B, S, V) come from a multi-token chunk whose FIRST input
    token sits at sequence index ``pos`` (scalar or per-row ``(B,)``);
    the token sampled from offset ``i`` will occupy index
    ``pos + 1 + i`` and is keyed by exactly that index — the same key
    single-token decode folds when it reaches the position. This is what
    makes speculative decoding's accepted prefixes bit-identical to the
    non-speculative stream for greedy AND sampled rows alike: the
    emitted token at any index is a pure function of (seed, index,
    logits), and the logits at an accepted index are the plain-decode
    logits by induction.
    """
    s = logits.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    cols = [sample_tokens(logits[:, i, :], state, pos + 1 + i)
            for i in range(s)]
    return jnp.stack(cols, axis=1)


def sample_tokens(logits: Array, state: dict | None, pos) -> Array:
    """Sample one token per row; ``pos`` keys each row's PRNG stream.

    logits (B, V) — already pad-masked; pos scalar or (B,) — the sequence
    index the sampled token will occupy (NOT the input token's position).
    Greedy rows (temperature 0) return the exact argmax of ``logits``.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if state is None:
        return greedy
    b, v = logits.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    keys = jax.vmap(jax.random.fold_in)(state["key"], pos)
    temp = state["temperature"]
    x = logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)[:, None]
    if "top_k" in state:
        # top-k: rank every logit within its row (stable argsort — ties
        # keep index order); a traced per-row k masks ranks >= k.
        # k == 0 disables that row.
        ranks = jnp.argsort(jnp.argsort(-x, axis=-1), axis=-1)
        k = jnp.where(state["top_k"] > 0, state["top_k"], v)
        x = jnp.where(ranks < k[:, None], x, -jnp.inf)
    if "top_p" in state:
        # top-p (nucleus) over the post-top-k distribution: keep the
        # smallest prefix of descending probs whose cumulative mass
        # reaches p — i.e. every token at least as probable as the one
        # that crosses p.
        probs = jax.nn.softmax(x, axis=-1)
        desc = jnp.sort(probs, axis=-1)[..., ::-1]
        cum = jnp.cumsum(desc, axis=-1)
        crossing = jnp.minimum(
            jnp.sum(cum < state["top_p"][:, None], axis=-1), v - 1)
        cutoff = jnp.take_along_axis(desc, crossing[:, None], axis=-1)
        x = jnp.where(probs >= cutoff, x, -jnp.inf)
    sampled = jax.vmap(jax.random.categorical)(keys, x).astype(jnp.int32)
    return jnp.where(temp > 0.0, sampled, greedy)
