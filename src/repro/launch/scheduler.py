"""Continuous batching: slot-based KV cache + segment-synchronous admission.

The PR-2 ``Server`` is a static-batch driver: every ``generate`` call
allocates a fresh KV cache, and a request that finishes early keeps its
batch row busy until the whole batch drains. This module adds the serving
discipline the ROADMAP's "heavy traffic" north star actually needs:

  * **Slot cache** — ONE persistent KV cache with ``num_slots`` batch
    rows, allocated once. Each request owns a slot for its lifetime; a
    freed slot is overwritten wholesale by the next admission (so no
    cross-request state leaks, for attention and recurrent caches alike).
    The batch axis of every cache leaf is *probed*, not assumed: specs
    for batch=2 vs batch=3 are diffed, which keeps the scheduler family-
    agnostic about cache layouts (GQA 5-D KV, MLA latent, int8 scales).
  * **Prompt bucketing** — admission prefills ``prompt[:-1]`` right-
    padded to the smallest bucket, then runs ONE single-token decode of
    the true last prompt token at its true position. The correction step
    overwrites the first pad's KV slot and returns the first generated
    token from the right logits row, so bucketing never changes tokens:
    pad KV beyond the true length is overwritten by later decode writes
    or masked by the causal ``kpos <= pos`` attention mask.
  * **Segment decode** — between admissions, ALL occupied slots advance
    ``segment`` tokens in one scan-compiled dispatch
    (``make_serve_step`` vmapped over slots with a *per-slot* position
    vector, wrapped in ``jax.lax.scan`` exactly like
    ``serve.make_decode_scan``). Requests finish mid-batch without
    stalling neighbours; their slots re-enter the free list at the next
    segment boundary.
  * **Executable cache** — every compiled program is keyed by
    ``(kind, shape-key, plan)``: repeat traffic (same bucket, same plan)
    never re-traces. ``stats["compiles"]`` / ``stats["hits"]`` make the
    no-retrace property testable.

Scope: families whose decode is batch-row independent and memory-free
(``dense`` — GQA and MLA — and ``moe``). Audio/VLM need per-request
encoder memory threaded through admission; that is an open item. MoE
caveat: pad tokens in a bucketed prefill compete for expert capacity, so
under a dropping ``capacity_factor`` a padded prefill can route real
tokens differently than an exact-length one — serve MoE with a no-drop
capacity factor (or exact-fit buckets) when bit-parity with solo decode
matters.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    LayerPlan,
    coerce_layer_plan,
)
from repro.kernels import ops as kops
from repro.launch.serve import (
    PER_LAYER_PLAN_FAMILIES,
    make_prefill_step,
    make_serve_step,
)
from repro.models import layers as L
from repro.models.registry import get_model

Array = jax.Array

# memory-free, batch-row-independent decode — currently the same set
# whose stacks realize per-layer plans, so the constant is shared
_SUPPORTED_FAMILIES = PER_LAYER_PLAN_FAMILIES

DEFAULT_BUCKETS = (16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    """One drained request: the prompt plus every generated token."""

    rid: int
    prompt: np.ndarray        # (S,) int32 — as submitted
    tokens: np.ndarray        # (generated,) int32
    prompt_len: int
    generated: int


@dataclasses.dataclass
class _Slot:
    rid: int | None = None
    pos: int = 0              # next KV write position (= current length)
    remaining: int = 0
    last_token: int = 0
    tokens: list[int] = dataclasses.field(default_factory=list)
    prompt: np.ndarray | None = None

    @property
    def free(self) -> bool:
        return self.rid is None


def probe_batch_axes(api, cfg: ModelConfig, minfo, max_len: int):
    """Which axis of each cache leaf is the batch (slot) axis?

    Diff the spec shapes for batch=2 vs batch=3 — the axis whose size
    changed is the batch axis. Works for every cache layout without
    hardcoding family knowledge.
    """
    s2 = api.cache_specs(cfg, minfo, 2, max_len)
    s3 = api.cache_specs(cfg, minfo, 3, max_len)

    def axis(a, b) -> int:
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(
            f"cache leaf {a.shape} has no batch axis; the slot scheduler "
            "cannot place requests into it"
        )

    return jax.tree.map(axis, s2, s3, is_leaf=L.is_spec)


class ContinuousBatchingServer:
    """Greedy-decoding server with slot-based continuous batching.

    >>> srv = ContinuousBatchingServer(cfg, params, num_slots=4)
    >>> srv.submit([1, 2, 3], max_new_tokens=16)
    >>> done = srv.run()          # drain pending + active
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 num_slots: int = 4, max_len: int = 256,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 segment: int = 8,
                 plan: LayerPlan | ExecutionPlan | ExecutionMode | str |
                 None = None) -> None:
        if cfg.family not in _SUPPORTED_FAMILIES:
            raise ValueError(
                f"continuous batching supports families {_SUPPORTED_FAMILIES}"
                f", got {cfg.family!r} (encoder-memory families need "
                "per-request memory plumbing — see module docstring)"
            )
        if plan is None:
            plan = ExecutionMode.SIDEBAR
        if isinstance(plan, ExecutionPlan):
            if not plan.is_uniform:
                cfg = dataclasses.replace(cfg, scan_layers=False)
            self._plan_key: Any = plan.cache_key()
        else:
            plan = coerce_layer_plan(plan)
            self._plan_key = plan
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.mesh = mesh
        self.minfo = (
            L.MeshInfo.from_axes(tuple(mesh.axis_names)) if mesh else L.HOST
        )
        self.api = get_model(cfg)
        self.num_slots = num_slots
        self.max_len = max_len
        # a bucket longer than the KV cache could never be prefilled into
        # it; submit() bounds every prompt to max_len, so exact-fit covers
        # whatever the dropped buckets would have
        self.buckets = tuple(sorted(b for b in buckets if b <= max_len))
        self.segment = segment
        self.axes = probe_batch_axes(self.api, cfg, self.minfo, max_len)
        # THE slot cache: allocated once, lives as long as the server.
        self.cache = self.api.init_cache(cfg, self.minfo, num_slots, max_len)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.pending: collections.deque = collections.deque()
        self.finished: list[FinishedRequest] = []
        self._next_rid = 0
        self._exec: dict[tuple, Callable] = {}
        self.stats = {"compiles": 0, "hits": 0, "admitted": 0,
                      "segments": 0, "decode_steps": 0, "wasted_steps": 0}

    # -- executable cache --------------------------------------------------
    def _compiled(self, key: tuple, builder: Callable[[], Callable]):
        """(kind, shape-key..., plan) -> compiled program. Repeat traffic
        hits the cache; a new bucket or plan is a recorded compile."""
        fn = self._exec.get(key)
        if fn is None:
            fn = self._exec[key] = builder()
            self.stats["compiles"] += 1
        else:
            self.stats["hits"] += 1
        return fn

    def executable_cache_keys(self) -> list[tuple]:
        return sorted(self._exec, key=repr)

    # -- submission --------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (prefill length); exact fit past the end."""
        for b in self.buckets:
            if b >= n:
                return b
        return n

    def submit(self, prompt, max_new_tokens: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new_tokens} exceeds "
                f"max_len {self.max_len}"
            )
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append((rid, prompt, max_new_tokens))
        return rid

    # -- admission ---------------------------------------------------------
    def _insert_fn(self):
        axes = self.axes

        def insert(full, one, slot):
            return jax.tree.map(
                lambda f, o, ax: jax.lax.dynamic_update_slice_in_dim(
                    f, o.astype(f.dtype), slot, axis=ax),
                full, one, axes,
            )

        return jax.jit(insert, donate_argnums=(0,))

    def _admit_one(self, slot_idx: int, rid: int, prompt: np.ndarray,
                   max_new: int) -> None:
        s_true = int(prompt.size)
        cache1 = self.api.init_cache(self.cfg, self.minfo, 1, self.max_len)
        if s_true > 1:
            bucket = self.bucket_for(s_true - 1)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, : s_true - 1] = prompt[:-1]
            prefill = self._compiled(
                ("prefill", bucket, self._plan_key),
                lambda: jax.jit(
                    make_prefill_step(self.cfg, self.api, self.minfo,
                                      self.mesh),
                    donate_argnums=(2,),
                ),
            )
            _, cache1 = prefill(self.params, {"tokens": jnp.asarray(padded)},
                                cache1)
        # correction step: the true last prompt token at its true position
        # overwrites the first pad's KV and yields the first new token
        # from the right logits row (bucket padding never changes tokens).
        decode = self._compiled(
            ("admit_decode", self._plan_key),
            lambda: jax.jit(
                make_serve_step(self.cfg, self.api, self.minfo, self.mesh),
                donate_argnums=(2,),
            ),
        )
        nxt, cache1 = decode(
            self.params, jnp.asarray([[prompt[-1]]], jnp.int32), cache1,
            jnp.int32(s_true - 1), None,
        )
        first = int(np.asarray(nxt)[0, 0])
        insert = self._compiled(("insert",), self._insert_fn)
        self.cache = insert(self.cache, cache1, jnp.int32(slot_idx))
        slot = self.slots[slot_idx]
        slot.rid = rid
        slot.pos = s_true
        slot.remaining = max_new - 1
        slot.last_token = first
        slot.tokens = [first]
        slot.prompt = prompt
        self.stats["admitted"] += 1
        if slot.remaining == 0:
            self._retire(slot_idx)

    def _retire(self, slot_idx: int) -> None:
        slot = self.slots[slot_idx]
        self.finished.append(FinishedRequest(
            rid=slot.rid, prompt=slot.prompt,
            tokens=np.asarray(slot.tokens, np.int32),
            prompt_len=int(slot.prompt.size), generated=len(slot.tokens),
        ))
        self.slots[slot_idx] = _Slot()

    def admit(self) -> int:
        """Fill free slots from the pending queue; returns #admitted."""
        n = 0
        with kops.execution_plan(self.plan):
            for i, slot in enumerate(self.slots):
                if not self.pending:
                    break
                if slot.free:
                    rid, prompt, max_new = self.pending.popleft()
                    self._admit_one(i, rid, prompt, max_new)
                    n += 1
        return n

    # -- segment decode ----------------------------------------------------
    def _segment_fn(self, num_steps: int) -> Callable:
        """All slots advance ``num_steps`` tokens in one compiled program:
        ``make_serve_step`` vmapped over the slot axis with per-slot
        positions, scanned over steps with the cache in the (donated)
        carry and the output buffer written via ``dynamic_update_slice``.
        """
        step = make_serve_step(self.cfg, self.api, self.minfo, self.mesh)
        axes = self.axes
        max_pos = self.max_len - 1

        def one(params, tok, cache, pos):
            # batch=1 view of one slot; finished slots idle at a clamped
            # position (their writes land on a dead row, see step()).
            return step(params, tok, cache, jnp.minimum(pos, max_pos), None)

        def vstep(params, toks_x, cache_x, pos):
            return jax.vmap(one, in_axes=(None, 0, axes, 0),
                            out_axes=(0, axes))(params, toks_x, cache_x, pos)

        def segment(params, toks, cache, pos):
            # toks (N, 1), pos (N,); cache = the full slot cache. Leaves
            # keep a singleton batch dim inside vmap so the model code
            # sees ordinary (1, ...) batches.
            cache_x = jax.tree.map(
                lambda a, ax: jnp.expand_dims(a, ax + 1), cache, axes)
            toks_x = toks[:, None, :]
            buf = jnp.zeros((toks.shape[0], num_steps), jnp.int32)

            def body(carry, i):
                toks_x, cache_x, buf = carry
                nxt, cache_x = vstep(params, toks_x, cache_x, pos + i)
                buf = jax.lax.dynamic_update_slice(buf, nxt[:, 0, :], (0, i))
                return (nxt, cache_x, buf), None

            (_, cache_x, buf), _ = jax.lax.scan(
                body, (toks_x, cache_x, buf),
                jnp.arange(num_steps, dtype=jnp.int32),
            )
            cache = jax.tree.map(
                lambda a, ax: jnp.squeeze(a, ax + 1), cache_x, axes)
            return buf, cache

        # params as an ARGUMENT (not a closure constant): the cached
        # executable never bakes weights into its jaxpr, and a params
        # swap on a live server takes effect on the next segment.
        return jax.jit(segment, donate_argnums=(2,))

    def step(self) -> list[FinishedRequest]:
        """Admit into free slots, then decode one segment on all active
        slots; returns requests that finished this step."""
        drained_before = len(self.finished)
        self.admit()
        active = [i for i, s in enumerate(self.slots)
                  if not s.free and s.remaining > 0]
        if active:
            toks = np.zeros((self.num_slots, 1), np.int32)
            pos = np.full((self.num_slots,), self.max_len - 1, np.int32)
            for i in active:
                toks[i, 0] = self.slots[i].last_token
                pos[i] = self.slots[i].pos
            seg = self._compiled(
                ("segment", self.num_slots, self.segment, self._plan_key),
                lambda: self._segment_fn(self.segment),
            )
            with kops.execution_plan(self.plan):
                buf, self.cache = seg(self.params, jnp.asarray(toks),
                                      self.cache, jnp.asarray(pos))
            buf = np.asarray(buf)
            self.stats["segments"] += 1
            self.stats["decode_steps"] += self.segment * len(active)
            for i in active:
                slot = self.slots[i]
                take = min(self.segment, slot.remaining)
                slot.tokens.extend(int(t) for t in buf[i, :take])
                slot.remaining -= take
                slot.pos += take
                slot.last_token = int(buf[i, take - 1])
                self.stats["wasted_steps"] += self.segment - take
                if slot.remaining == 0:
                    self._retire(i)
        return self.finished[drained_before:]

    def run(self) -> list[FinishedRequest]:
        """Drain every pending + active request; returns all finished
        requests (ordered by rid)."""
        while self.pending or any(not s.free for s in self.slots):
            self.step()
        out, self.finished = self.finished, []
        return sorted(out, key=lambda r: r.rid)
