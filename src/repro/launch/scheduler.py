"""Continuous batching: slot-based KV cache + segment-synchronous admission.

The PR-2 ``Server`` is a static-batch driver: every ``generate`` call
allocates a fresh KV cache, and a request that finishes early keeps its
batch row busy until the whole batch drains. This module adds the serving
discipline the ROADMAP's "heavy traffic" north star actually needs:

  * **Slot cache** — ONE persistent KV cache with ``num_slots`` batch
    rows, allocated once. Each request owns a slot for its lifetime; a
    freed slot is overwritten wholesale by the next admission (so no
    cross-request state leaks, for attention and recurrent caches alike).
    The batch axis of every cache leaf is *probed*, not assumed: specs
    for batch=2 vs batch=3 are diffed, which keeps the scheduler family-
    agnostic about cache layouts (GQA 5-D KV, MLA latent, int8 scales).
  * **Prompt bucketing + batched admission** — one admission round
    prefills EVERY co-admitted prompt's ``prompt[:-1]`` together, right-
    padded to the round's largest bucket, then runs ONE single-token
    decode of each true last prompt token at its true per-row position
    (the same rowwise-position machinery as segment decode), then
    scatters all rows into the slot cache in one insert. The correction
    step overwrites the first pad's KV slot and returns the first
    generated token from the right logits row, so bucketing never
    changes tokens: pad KV beyond the true length is overwritten by
    later decode writes or masked by the causal ``kpos <= pos``
    attention mask.
  * **Segment decode** — between admissions, ALL occupied slots advance
    ``segment`` tokens in ONE batched scan-compiled dispatch: the serve
    step runs over the whole slot cache with a per-row ``(B,)`` position
    vector threaded down to the attention math (RoPE, causal mask, and
    KV writes all key off each row's own position — see
    ``models.attention.rowwise_pos``). This keeps the matmuls dense over
    slots instead of vmapping into ``num_slots`` batch-1 programs with
    scatter KV writes (the "vmap tax" that made continuous batching lose
    to static batching at smoke scale). When every slot is occupied at
    the SAME position the scheduler dispatches the aligned fast path — a
    scalar-position program whose KV write is one dense
    ``dynamic_update_slice``, exactly like ``serve.make_decode_scan``.
    Requests finish mid-batch without stalling neighbours; their slots
    re-enter the free list at the next segment boundary.
  * **Sampling** — ``submit(..., sample=SamplingParams(...))`` gives a
    request temperature / top-k / top-p decoding. The request's PRNG
    stream is position-keyed (``launch.sampling``): its base key lives
    in the slot state and the token at sequence index p is keyed by
    (base key, p), so admission order, slot churn, segment length, and
    even a scheduler restart mid-stream (resubmit prompt + tokens-so-far
    with the same seed) never change the stream. Greedy and sampled
    requests share one batched segment program: greedy rows carry
    temperature 0, which is exact argmax.
  * **Executable cache** — every compiled program is keyed by
    ``(kind, shape-key, plan)``: repeat traffic (same bucket, same plan)
    never re-traces. ``stats["compiles"]`` / ``stats["hits"]`` make the
    no-retrace property testable.

Scope: families whose decode is batch-row independent and memory-free
(``dense`` — GQA and MLA — and ``moe``). Audio/VLM need per-request
encoder memory threaded through admission; that is an open item. MoE
caveat: pad tokens in a bucketed prefill compete for expert capacity, so
under a dropping ``capacity_factor`` a padded prefill can route real
tokens differently than an exact-length one — serve MoE with a no-drop
capacity factor (or exact-fit buckets) when bit-parity with solo decode
matters.

``PagedContinuousBatchingServer`` (below) swaps the slab cache for the
block-granular paged KV pool of ``launch.kvpool`` — prefix caching,
chunked prefill-ahead, and admission fused into the segment program —
with the same external contract and bit-identical tokens.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    LayerPlan,
    coerce_layer_plan,
)
from repro.core.sidebar import SidebarSpillRegion
from repro.ft.watchdog import SegmentWatchdog
from repro.kernels import ops as kops
from repro.launch import kvpool as kvp
from repro.launch import sampling
from repro.launch.faults import FaultInjector
from repro.launch.sampling import SamplingParams
from repro.launch.serve import (
    PER_LAYER_PLAN_FAMILIES,
    make_prefill_step,
    make_serve_step,
    make_tp_spec,
    make_verify_step,
)
from repro.launch.spec import SpecConfig, accepted_prefix, make_draft_program
from repro.models import layers as L
from repro.models.registry import get_model

Array = jax.Array

# memory-free, batch-row-independent decode — currently the same set
# whose stacks realize per-layer plans, so the constant is shared
_SUPPORTED_FAMILIES = PER_LAYER_PLAN_FAMILIES

DEFAULT_BUCKETS = (16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    """One drained request: the prompt plus every generated token."""

    rid: int
    prompt: np.ndarray        # (S,) int32 — as submitted
    tokens: np.ndarray        # (generated,) int32
    prompt_len: int
    generated: int
    ttft: float = float("nan")   # submit -> first-token dispatch (s)
    itl: float = float("nan")    # mean inter-token latency (s)


@dataclasses.dataclass(eq=False)
class _Request:
    """One submitted request while it waits (pending / staging / spilled).

    ``priority`` is the class (higher wins); ``ttft_target`` makes the
    EDF deadline (``submit_t + ttft_target``; no target = deadline inf,
    i.e. best-effort); ``itl_target`` is recorded for per-class stats.
    ``seq`` is the arrival index — the final tie-break, which makes
    every scheduling score a strict total order (no thrash: a victim is
    always *strictly* worse than the request it yields to)."""

    rid: int
    prompt: np.ndarray
    max_new: int
    sample: SamplingParams | None
    priority: int = 0
    ttft_target: float | None = None
    itl_target: float | None = None
    submit_t: float = 0.0
    seq: int = 0

    @property
    def deadline(self) -> float:
        return (math.inf if self.ttft_target is None
                else self.submit_t + self.ttft_target)


@dataclasses.dataclass
class _Slot:
    rid: int | None = None
    pos: int = 0              # next KV write position (= current length)
    remaining: int = 0
    generated: int = 0        # tokens produced so far (host-side count)
    # generated tokens as (device_array, row, take) chunk handles — the
    # async drain loop never syncs token VALUES; chunks materialize to
    # numpy only when a request is handed back (see _materialize)
    chunks: list[tuple] = dataclasses.field(default_factory=list)
    prompt: np.ndarray | None = None
    sample: SamplingParams | None = None
    # the request's PRNG base key ((2,) uint32): position-keyed at use,
    # so the stream survives slot churn and scheduler restarts
    key: np.ndarray | None = None
    req: _Request | None = None
    first_t: float | None = None   # first-token dispatch time (TTFT)

    @property
    def free(self) -> bool:
        return self.rid is None


# per-leaf batch-axis probing now lives with the paged pool (which also
# probes length axes); re-exported here for existing callers/tests
probe_batch_axes = kvp.probe_batch_axes


@dataclasses.dataclass
class SchedulerStats:
    """Typed scheduler counters (one object, attribute access; indexing
    kept as a compatibility shim for older call sites).

    Executable-cache counters (``compiles``/``hits``) are THE re-trace
    regression signal; ``wasted_steps`` counts free/dead slot rows the
    batched segment programs decode alongside active ones; the pool/
    prefix fields are live only on the paged scheduler, as are the
    robustness counters (``preemptions``/``restores``/``unstaged``/
    ``spilled_blocks``...) and the speculative-decoding group
    (``spec_steps``/``spec_drafted``/``spec_accepted``/
    ``spec_commit_copies``, with ``spec_acceptance_rate`` derived).
    Under speculation ``decode_steps`` still counts EMITTED tokens
    (1..k+1 per row per verify) and ``wasted_steps`` absorbs the
    rejected remainder, so throughput accounting stays comparable with
    plain decode. ``summary()`` renders the lot for smoke logs.
    """

    # executable cache
    compiles: int = 0
    hits: int = 0
    # admission / decode
    admitted: int = 0
    segments: int = 0
    decode_steps: int = 0
    wasted_steps: int = 0
    admit_deferrals: int = 0
    # paged pool (PagedContinuousBatchingServer only)
    stage_chunks: int = 0
    stage_stalls: int = 0
    cow_copies: int = 0
    evictions: int = 0
    prefix_block_lookups: int = 0
    prefix_block_hits: int = 0
    prefix_prompt_blocks: int = 0   # full prompt[:-1] blocks walked
    chunk_interior_hits: int = 0    # splices past the first miss
    pool_blocks: int = 0
    pool_in_use: int = 0
    pool_in_use_peak: int = 0
    # overload robustness (preemption / cancel / watchdog)
    preemptions: int = 0       # active slots spilled to the host region
    restores: int = 0          # spilled requests spliced back and resumed
    unstaged: int = 0          # staging entries reclaimed back to pending
    spilled_blocks: int = 0
    restored_blocks: int = 0
    cancelled: int = 0
    watchdog_events: int = 0   # segments past k * median segment wall
    # speculative decoding (PagedContinuousBatchingServer(spec=...) only)
    spec_steps: int = 0        # draft+verify scheduler iterations
    spec_drafted: int = 0      # draft tokens submitted to the verifier
    spec_accepted: int = 0     # of those, accepted (matched the target)
    spec_commit_copies: int = 0  # scratch->pool block copies (accepted KV)
    # retrieval stage (PagedContinuousBatchingServer(rag=...) only)
    retrievals: int = 0             # queries assembled by the pipeline
    retrieval_overlapped: int = 0   # of those, hidden behind a dispatch
    retrieval_chunk_blocks: int = 0  # retrieved-chunk blocks staged
    retrieval_chunk_hits: int = 0    # of those, spliced from the index
    # per-priority-class latency samples (seconds); dict fields merge by
    # concatenation in ``router.sum_stats``
    ttft_s: dict = dataclasses.field(default_factory=dict)
    itl_s: dict = dataclasses.field(default_factory=dict)

    def __getitem__(self, key: str) -> int:
        return getattr(self, key)

    def __setitem__(self, key: str, value: int) -> None:
        setattr(self, key, value)

    # -- per-class latency --------------------------------------------------
    def record_ttft(self, priority: int, seconds: float) -> None:
        self.ttft_s.setdefault(priority, []).append(float(seconds))

    def record_itl(self, priority: int, seconds: float) -> None:
        self.itl_s.setdefault(priority, []).append(float(seconds))

    @staticmethod
    def _tail(samples: dict, q: float, priority: int | None) -> float:
        xs = (samples.get(priority, []) if priority is not None
              else [x for v in samples.values() for x in v])
        return float(np.percentile(xs, q)) if xs else float("nan")

    def ttft_tail(self, q: float = 95.0,
                  priority: int | None = None) -> float:
        """Per-class (or overall) TTFT tail quantile in seconds — the
        SLO gate the overload bench reports per priority class."""
        return self._tail(self.ttft_s, q, priority)

    def itl_tail(self, q: float = 95.0,
                 priority: int | None = None) -> float:
        return self._tail(self.itl_s, q, priority)

    @property
    def exec_hit_rate(self) -> float:
        return self.hits / max(self.compiles + self.hits, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of full prompt blocks served from the index —
        hit blocks over prompt blocks WALKED, not over lookups issued.
        The old lookups-based denominator undercounted the miss side
        whenever the walk stopped early (and with interior-hole
        splicing the walk never stops early, so lookups ≈ walked and
        the two now differ only in old recorded data)."""
        return self.prefix_block_hits / max(self.prefix_prompt_blocks, 1)

    @property
    def prefix_lookup_hit_rate(self) -> float:
        """Deprecated: hits over index LOOKUPS, the pre-chunk-addressing
        definition of ``prefix_hit_rate``. Kept one release for
        dashboards pinned to the old denominator."""
        return self.prefix_block_hits / max(self.prefix_block_lookups, 1)

    @property
    def retrieval_chunk_hit_rate(self) -> float:
        """Fraction of retrieved-chunk blocks spliced from the KV index
        rather than prefilled — the chunk-sharing payoff metric."""
        return (self.retrieval_chunk_hits
                / max(self.retrieval_chunk_blocks, 1))

    @property
    def retrieval_overlap_frac(self) -> float:
        """Fraction of retrievals that ran while a decode segment was
        in flight (their host time hidden behind accelerator work)."""
        return self.retrieval_overlapped / max(self.retrievals, 1)

    @property
    def pool_occupancy(self) -> float:
        return self.pool_in_use / max(self.pool_blocks, 1)

    @property
    def wasted_step_frac(self) -> float:
        return self.wasted_steps / max(self.decode_steps, 1)

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of drafted tokens the target accepted. 1.0 means
        every draft guessed the target's position-keyed token (the
        oracle-draft ceiling); output correctness never depends on this
        number — only throughput does."""
        return self.spec_accepted / max(self.spec_drafted, 1)

    def summary(self) -> str:
        """One printable line per concern — the serving example's stats
        report."""
        lines = [
            f"executable cache: {self.compiles} compiles, {self.hits} hits "
            f"({self.exec_hit_rate:.0%} hit rate)",
            f"admission: {self.admitted} admitted, "
            f"{self.admit_deferrals} deferrals",
            f"decode: {self.segments} segments, {self.decode_steps} "
            f"slot-steps, wasted_step_frac {self.wasted_step_frac:.2f}",
        ]
        if self.pool_blocks:
            lines.append(
                f"kv pool: {self.pool_in_use}/{self.pool_blocks} blocks "
                f"(peak {self.pool_in_use_peak}), "
                f"prefix hit rate {self.prefix_hit_rate:.0%} "
                f"({self.prefix_block_hits}/{self.prefix_prompt_blocks} "
                f"blocks, {self.chunk_interior_hits} interior), "
                f"{self.stage_chunks} staged chunks, "
                f"{self.stage_stalls} stalls, {self.cow_copies} COW, "
                f"{self.evictions} evictions",
            )
        if self.spec_steps:
            lines.append(
                f"speculative: {self.spec_steps} steps, "
                f"{self.spec_accepted}/{self.spec_drafted} drafts accepted "
                f"({self.spec_acceptance_rate:.0%}), "
                f"{self.spec_commit_copies} commit copies",
            )
        if self.retrievals:
            lines.append(
                f"retrieval: {self.retrievals} queries "
                f"({self.retrieval_overlap_frac:.0%} overlapped), "
                f"chunk hit rate {self.retrieval_chunk_hit_rate:.0%} "
                f"({self.retrieval_chunk_hits}/"
                f"{self.retrieval_chunk_blocks} blocks)",
            )
        if (self.preemptions or self.restores or self.cancelled
                or self.watchdog_events):
            lines.append(
                f"robustness: {self.preemptions} preemptions "
                f"({self.spilled_blocks} blocks spilled), "
                f"{self.restores} restores, {self.unstaged} unstaged, "
                f"{self.cancelled} cancelled, "
                f"{self.watchdog_events} watchdog events",
            )
        return "\n".join(lines)


class ContinuousBatchingServer:
    """Slot-based continuous batching with batched segment decode.

    >>> srv = ContinuousBatchingServer(cfg, params, num_slots=4)
    >>> srv.submit([1, 2, 3], max_new_tokens=16)
    >>> srv.submit([4, 5], 16, sample=SamplingParams(temperature=0.8))
    >>> done = srv.run()          # drain pending + active
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 num_slots: int = 4, max_len: int = 256,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 segment: int = 8, admit_batch: int = 2,
                 scheduling: str = "edf",
                 faults: FaultInjector | None = None,
                 plan: LayerPlan | ExecutionPlan | ExecutionMode | str |
                 None = None) -> None:
        if scheduling not in ("edf", "fifo"):
            raise ValueError(
                f"scheduling must be 'edf' or 'fifo', got {scheduling!r}")
        if cfg.family not in _SUPPORTED_FAMILIES:
            raise ValueError(
                f"continuous batching supports families {_SUPPORTED_FAMILIES}"
                f", got {cfg.family!r} (encoder-memory families need "
                "per-request memory plumbing — see module docstring)"
            )
        if plan is None:
            plan = ExecutionMode.SIDEBAR
        if isinstance(plan, ExecutionPlan):
            if not plan.is_uniform:
                cfg = dataclasses.replace(cfg, scan_layers=False)
            self._plan_key: Any = plan.cache_key()
        else:
            plan = coerce_layer_plan(plan)
            self._plan_key = plan
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.mesh = mesh
        self.api = get_model(cfg)
        # mesh => tensor-parallel serving: every step program below runs
        # under shard_map with params/pool partitioned on "model"
        self.tp = make_tp_spec(cfg, self.api, mesh) if mesh is not None \
            else None
        self.minfo = self.tp.minfo if self.tp is not None else L.HOST
        # folded into EVERY executable-cache key by _compiled: a server
        # on a different mesh (or none) can never reuse a stale program
        self._mesh_key = self.tp.mesh_key if self.tp is not None else None
        if self.tp is not None:
            self.params = self.tp.place_params(params)
        if not self.api.rowwise_decode_pos:
            raise ValueError(
                f"family {cfg.family!r} decode_step takes scalar positions "
                "only; batched segment decode needs per-row (B,) positions "
                "(ModelApi.rowwise_decode_pos)"
            )
        self.num_slots = num_slots
        self.max_len = max_len
        # a bucket longer than the KV cache could never be prefilled into
        # it; submit() bounds every prompt to max_len, so exact-fit covers
        # whatever the dropped buckets would have
        self.buckets = tuple(sorted(b for b in buckets if b <= max_len))
        self.segment = segment
        self.admit_batch = max(1, min(admit_batch, num_slots))
        self.slots = [_Slot() for _ in range(num_slots)]
        self.pending: collections.deque = collections.deque()
        self.finished: list[FinishedRequest] = []
        self._next_rid = 0
        self._exec: dict[tuple, Callable] = {}
        # the running token of every slot, device-side (N, 1): written
        # ONLY by program outputs (segment final carry / admission
        # correction scatter), so the drain loop never blocks on it
        self._toks = jnp.zeros((num_slots, 1), jnp.int32)
        self._done_raw: list[tuple] = []   # retired, not yet materialized
        self._deferred = False             # admission hysteresis armed
        self.stats = SchedulerStats()
        # SLO scheduling: "edf" admits/stages by (priority, deadline,
        # arrival); "fifo" is the strict-arrival baseline the overload
        # bench compares against (preemption still guards lazy growth)
        self.scheduling = scheduling
        self.faults = faults
        self._seq = 0                      # arrival index (score tie-break)
        self._clock = time.monotonic       # injectable for deterministic tests
        self._timer = time.perf_counter    # injectable (watchdog timing)
        # segment watchdog: a dispatch past k * median segment wall is a
        # recorded (non-fatal) event — a wedged compile or device hang
        # becomes observable instead of silent
        self.watchdog = SegmentWatchdog()
        self._init_kv()

    def _init_kv(self) -> None:
        """Allocate the KV memory (hook: the paged subclass builds a
        block pool here instead of the dense slab)."""
        self.axes = probe_batch_axes(self.api, self.cfg, self.minfo,
                                     self.max_len)
        # THE slot cache: allocated once, lives as long as the server.
        self.cache = self.api.init_cache(self.cfg, self.minfo,
                                         self.num_slots, self.max_len)
        if self.tp is not None:
            # KV heads live on the model axis; everything else replicates
            self.cache = self.tp.place_cache(self.cache)

    # -- executable cache --------------------------------------------------
    def _compiled(self, key: tuple, builder: Callable[[], Callable]):
        """(kind, shape-key..., plan, mesh) -> compiled program. Repeat
        traffic hits the cache; a new bucket or plan is a recorded
        compile. The (mesh shape, axis names) tail means a server
        rebuilt on a different mesh can never replay a program whose
        shard_map was specialized to another device grid."""
        key = key + (self._mesh_key,)
        fn = self._exec.get(key)
        if fn is None:
            fn = self._exec[key] = builder()
            self.stats.compiles += 1
        else:
            self.stats.hits += 1
        return fn

    def executable_cache_keys(self) -> list[tuple]:
        return sorted(self._exec, key=repr)

    # -- submission --------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (prefill length); exact fit past the end."""
        for b in self.buckets:
            if b >= n:
                return b
        return n

    def submit(self, prompt, max_new_tokens: int,
               sample: SamplingParams | None = None, *,
               priority: int = 0, ttft_target: float | None = None,
               itl_target: float | None = None) -> int:
        """Enqueue a request; returns its rid (echoed on the
        ``FinishedRequest``). ``sample=None`` decodes greedy; a
        ``SamplingParams`` gives the request its own temperature/
        truncation/seed (the position-keyed PRNG makes the stream
        independent of batching and scheduling). ``priority`` ranks
        requests for staging/admission (higher first under
        ``scheduling="edf"``; ignored by FIFO), and ``ttft_target`` /
        ``itl_target`` (seconds) attach SLO targets: the TTFT target
        sets the EDF deadline (``submit time + target``), both are
        reported per-request (``r.ttft`` / ``r.itl``) and as per-class
        distributions in ``stats``. No-target requests are best-effort
        — they sort behind every deadline but are never starved of a
        free slot."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new_tokens} exceeds "
                f"max_len {self.max_len}"
            )
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(_Request(
            rid, prompt, max_new_tokens, sample,
            priority=int(priority), ttft_target=ttft_target,
            itl_target=itl_target, submit_t=self._clock(), seq=self._seq,
        ))
        self._seq += 1
        return rid

    def _score(self, req: _Request) -> tuple:
        """Scheduling order, smaller = sooner. EDF: priority class first
        (higher wins), earliest deadline inside a class, arrival as the
        strict tie-break; no-target requests (deadline inf) are
        best-effort behind every deadline. FIFO: arrival only — the
        overload bench's baseline."""
        if self.scheduling == "fifo":
            return (req.seq,)
        return (-req.priority, req.deadline, req.seq)

    def cancel(self, rid: int) -> bool:
        """Client abort: drop the request wherever it lives. A pending
        request vanishes; an active one frees its slot (the paged
        subclass releases its pool blocks — refcounts back, COW parents
        intact) at the current boundary. Cancelled requests never
        appear in ``finished``; sibling rows are untouched (their KV
        lives in other slots/blocks). Returns False for unknown/already
        finished rids."""
        for req in self.pending:
            if req.rid == rid:
                self.pending.remove(req)
                self.stats.cancelled += 1
                return True
        for i, slot in enumerate(self.slots):
            if slot.rid == rid:
                self._free_slot(i)
                self.stats.cancelled += 1
                return True
        return False

    # -- admission ---------------------------------------------------------
    def _admit_fn(self, *, with_prefill: bool) -> Callable:
        """ONE compiled program for a whole admission round, in place on
        the slot cache: gather the freed rows (probed batch axes),
        right-padded batched prefill of every co-admitted ``prompt[:-1]``
        (skipped when all prompts are single tokens), the per-row-
        position correction step, and the scatter back. The gathered
        rows still hold retired requests' KV — stale state is
        overwritten by the prefill/decode writes or masked by the causal
        ``kpos <= pos`` read before it is ever visible (the same
        argument as prompt bucketing)."""
        prefill_step = make_prefill_step(self.cfg, self.api, self.minfo,
                                         self.mesh, tp=self.tp)
        serve_step = make_serve_step(self.cfg, self.api, self.minfo,
                                     self.mesh, tp=self.tp)
        axes = self.axes

        def admit(params, padded, full, prev_toks, toks, pos, slots,
                  sample=None):
            rows = jax.tree.map(
                lambda f, ax: jnp.take(f, slots, axis=ax), full, axes)
            if with_prefill:
                _, rows = prefill_step(params, {"tokens": padded}, rows)
            nxt, rows = serve_step(params, toks, rows, pos, None, sample)
            # single-advanced-index scatter: the axis keeps its position
            full = jax.tree.map(
                lambda f, o, ax: f.at[(slice(None),) * ax + (slots,)].set(
                    o.astype(f.dtype)),
                full, rows, axes,
            )
            # merge the correction tokens into the running (N, 1) token
            # vector so the next segment feeds them without a host sync
            prev_toks = prev_toks.at[slots].set(nxt)
            return nxt, prev_toks, full

        return jax.jit(admit, donate_argnums=(2, 3))

    def _admit_batch(self, slot_idxs: list[int],
                     reqs: list[_Request]) -> None:
        """Admit ``k`` requests in ONE dispatch: gather the freed slot
        rows, right-padded batched prefill (to the largest needed
        bucket), the correction step at per-row true positions (the same
        rowwise-position machinery as segment decode), and the scatter
        back — all fused into one compiled program per admission ROUND
        instead of three dispatches per request.

        Padding is still invisible in tokens: each row's pad KV beyond
        its true length is overwritten by the correction step / later
        decode writes or masked by the causal ``kpos <= pos`` attention
        mask before it is ever read. (MoE caveat: co-admitted rows share
        expert capacity in the batched prefill — as with bucket padding,
        serve MoE with a no-drop capacity factor for bit-parity.)
        """
        k = len(reqs)
        s_true = np.asarray([r.prompt.size for r in reqs], np.int32)
        need = int(s_true.max()) - 1
        bucket = self.bucket_for(need) if need > 0 else 0
        padded = None
        if bucket:
            buf = np.zeros((k, bucket), np.int32)
            for j, r in enumerate(reqs):
                buf[j, : r.prompt.size - 1] = r.prompt[:-1]
            padded = jnp.asarray(buf)
        # prefill + correction fused into ONE program: each row's true
        # last prompt token decodes at its true per-row position,
        # overwriting the first pad's KV and yielding the first new token
        # from the right logits row. A sampled request samples it with
        # key (base, s_true) — exactly the key a solo Server.generate
        # folds for its first new token.
        keys = [None if r.sample is None else np.asarray(
            sampling.request_key(r.sample.seed)) for r in reqs]
        sampled = any(r.sample is not None for r in reqs)
        zero = np.zeros((2,), np.uint32)
        state = sampling.merge_rows(
            [(zero if key is None else key, r.sample)
             for key, r in zip(keys, reqs)]) if sampled else None
        admit = self._compiled(
            ("prefill", k, bucket, self._plan_key,
             "sampled" if sampled else "greedy"),
            lambda: self._admit_fn(with_prefill=bool(bucket)),
        )
        toks = np.asarray([[r.prompt[-1]] for r in reqs], np.int32)
        nxt, self._toks, self.cache = admit(
            self.params, padded, self.cache, self._toks, jnp.asarray(toks),
            jnp.asarray(s_true - 1), jnp.asarray(slot_idxs, jnp.int32),
            state,
        )
        now = self._clock()
        for j, slot_idx in enumerate(slot_idxs):
            r = reqs[j]
            slot = self.slots[slot_idx]
            slot.rid = r.rid
            slot.pos = int(s_true[j])
            slot.remaining = r.max_new - 1
            slot.generated = 1
            slot.chunks = [(nxt, j, 1)]
            slot.prompt = r.prompt
            slot.sample = r.sample
            slot.key = keys[j]
            slot.req = r
            slot.first_t = now     # first token dispatched here
            self.stats.record_ttft(r.priority, now - r.submit_t)
            self.stats.admitted += 1
            if slot.remaining == 0:
                self._retire(slot_idx)

    def _free_slot(self, slot_idx: int) -> None:
        """Vacate a slot without retiring it (cancel path; the paged
        subclass also releases the request's pool blocks)."""
        self.slots[slot_idx] = _Slot()

    def _retire(self, slot_idx: int) -> None:
        slot = self.slots[slot_idx]
        ttft = itl = float("nan")
        if slot.req is not None and slot.first_t is not None:
            ttft = slot.first_t - slot.req.submit_t
            if slot.generated > 1:
                itl = (self._clock() - slot.first_t) / (slot.generated - 1)
                self.stats.record_itl(slot.req.priority, itl)
        self._done_raw.append((slot.rid, slot.prompt, slot.chunks,
                               slot.generated, ttft, itl))
        self._free_slot(slot_idx)

    @staticmethod
    def _chunks_to_np(chunks: list[tuple], fetched: dict) -> np.ndarray:
        """Host tokens from (device_array, row, take) handles — the one
        place the async pipeline blocks. ``fetched`` memoizes whole-
        array transfers (many chunks share one segment buffer)."""
        if not chunks:
            # a request preempted before its first token has no chunks
            return np.zeros((0,), np.int32)
        parts = []
        for arr, row, take in chunks:
            host = fetched.get(id(arr))
            if host is None:
                host = fetched[id(arr)] = np.asarray(arr)
            parts.append(host[row, :take])
        return np.concatenate(parts).astype(np.int32)

    def slot_tokens(self, slot_idx: int) -> np.ndarray:
        """Tokens generated so far by the request in ``slot_idx`` (syncs
        that slot's chunks; used for mid-stream inspection/restart)."""
        return self._chunks_to_np(self.slots[slot_idx].chunks, {})

    def _materialize(self) -> list[FinishedRequest]:
        """Convert retired-but-raw requests into FinishedRequests."""
        if not self._done_raw:
            return []
        fetched: dict = {}
        out = []
        for rid, prompt, chunks, generated, ttft, itl in self._done_raw:
            tokens = self._chunks_to_np(chunks, fetched)
            assert tokens.size == generated
            out.append(FinishedRequest(
                rid=rid, prompt=prompt, tokens=tokens,
                prompt_len=int(prompt.size), generated=generated,
                ttft=ttft, itl=itl,
            ))
        self._done_raw.clear()
        self.finished.extend(out)
        return out

    def admit(self) -> int:
        """Fill free slots from the pending queue (one batched admission
        round); returns #admitted.

        Admission hysteresis: with a backlog and other slots still
        decoding, wait until ``admit_batch`` slots are free before
        admitting — a batch-1 prefill GEMM is several times less
        efficient than a batched one, and a short wait for a second free
        slot costs less than it saves (knob: ``admit_batch=1`` restores
        eager admission). The wait times out after ONE deferred
        boundary: ``_segment_steps`` caps the next segment at
        ``self.segment`` while a deferral is pending, and the boundary
        after that admits whatever is free — a held-open slot never
        idles longer than ``segment`` steps behind a long-running
        neighbour.
        """
        free = [i for i, slot in enumerate(self.slots) if slot.free]
        take = min(len(free), len(self.pending))
        if take == 0:
            self._deferred = False
            return 0
        threshold = min(self.admit_batch, len(self.pending))
        if (take < threshold and len(free) < self.num_slots
                and not self._deferred):
            self._deferred = True
            self.stats.admit_deferrals += 1
            return 0
        self._deferred = False
        # out-of-order admission: the best-scored pending requests go
        # first (EDF inside priority classes); default traffic (no
        # priorities, no deadlines) scores by arrival — exactly FIFO
        reqs = sorted(self.pending, key=self._score)[:take]
        for r in reqs:
            self.pending.remove(r)
        with kops.execution_plan(self.plan):
            self._admit_batch(free[:take], reqs)
        return take

    # -- segment decode ----------------------------------------------------
    def _segment_fn(self, num_steps: int) -> Callable:
        """All slots advance ``num_steps`` tokens in one compiled program:
        ONE batched ``make_serve_step`` over the whole slot cache,
        scanned over steps with the cache in the (donated) carry and the
        output buffer written via ``dynamic_update_slice``. ``pos`` is a
        per-row ``(N,)`` vector (unaligned slots: the attention layer
        scatters each row's KV write to its own position) or a scalar
        (every slot at the same position: dense-slab KV writes, the same
        program shape as ``serve.make_decode_scan``). Either way the
        matmuls stay dense over slots — no per-slot vmap into batch-1
        programs.
        """
        step = make_serve_step(self.cfg, self.api, self.minfo, self.mesh,
                               tp=self.tp)
        max_pos = self.max_len - 1

        def segment(params, toks, cache, pos, sample=None):
            # toks (N, 1); pos (N,) or scalar; cache = the full slot
            # cache. Finished/free slots idle at a clamped position:
            # their writes land on a dead row and are overwritten
            # wholesale at the next admission.
            buf = jnp.zeros((toks.shape[0], num_steps), jnp.int32)

            def body(carry, i):
                tok, cache, buf = carry
                p = jnp.minimum(pos + i, max_pos)
                nxt, cache = step(params, tok, cache, p, None, sample)
                buf = jax.lax.dynamic_update_slice(buf, nxt, (0, i))
                return (nxt, cache, buf), None

            (last, cache, buf), _ = jax.lax.scan(
                body, (toks, cache, buf),
                jnp.arange(num_steps, dtype=jnp.int32),
            )
            # the final carry token feeds the next segment directly —
            # the drain loop never syncs token values (async dispatch)
            return buf, last, cache

        # params as an ARGUMENT (not a closure constant): the cached
        # executable never bakes weights into its jaxpr, and a params
        # swap on a live server takes effect on the next segment.
        return jax.jit(segment, donate_argnums=(2,))

    def _segment_sample_state(self, active: list[int]) -> dict | None:
        """Per-row traced sampling state for one segment, or ``None``
        when every active slot decodes greedily (keeps the pure-greedy
        segment program free of sampling math). Greedy slots inside a
        mixed batch ride along as temperature-0 rows — exact argmax."""
        if not any(self.slots[i].sample is not None for i in active):
            return None
        zero = np.zeros((2,), np.uint32)
        rows = []
        for slot in self.slots:
            if slot.free or slot.sample is None:
                rows.append((zero, None))
            else:
                rows.append((slot.key, slot.sample))
        return sampling.merge_rows(rows)

    def _segment_steps(self, active: list[int], *,
                       draining: bool = False) -> int:
        """How many tokens this segment decodes — shrink-to-fit.

        The segment ends exactly when the earliest active slot finishes
        (``min remaining``): running past it wastes slot-steps, and with
        EVERY slot busy a boundary before it is pure dispatch overhead —
        admission needs a free slot, and only a retirement frees one, so
        nothing can enter earlier (holds for live submits too). Whenever
        entry IS possible at the boundary — a free slot exists and a
        live submit could arrive (``step()``-driven serving; inside a
        blocking ``run()`` drain nothing can be submitted, so the cap
        would be pure dispatch overhead on the tail) or an admission
        deferral is armed (the hysteresis must time out) — the length is
        capped at ``self.segment``, the admission-latency knob. Above
        ``self.segment`` the length rounds down to a power of two, so
        long stretches cost O(log) dispatches while the distinct
        compiled segment variants stay bounded (``segment`` exact
        lengths + log2(max_len) strides).
        """
        min_rem = min(self.slots[i].remaining for i in active)
        entry_possible = self._deferred or (
            not draining and any(s.free for s in self.slots))
        if entry_possible:
            return min(min_rem, self.segment)
        if min_rem <= self.segment:
            return min_rem
        return 1 << (min_rem.bit_length() - 1)

    def _advance(self, *, draining: bool = False) -> None:
        """One scheduler iteration, fully async: admit into free slots,
        then enqueue one segment over all active slots. All decisions
        (segment length, alignment, retirement) derive from host-side
        COUNTS; token values stay on device — the admission program
        merges its correction tokens into the running token vector and
        the segment program returns its final carry, so dispatches
        pipeline without a single host round-trip. ``draining`` marks a
        blocking ``run()`` loop, where no live submit can arrive."""
        self.admit()
        active = [i for i, s in enumerate(self.slots)
                  if not s.free and s.remaining > 0]
        if not active:
            return
        steps = self._segment_steps(active, draining=draining)
        pos = np.full((self.num_slots,), self.max_len - 1, np.int32)
        for i in active:
            pos[i] = self.slots[i].pos
        # aligned fast path: every slot occupied at the same position
        # -> scalar-pos program (dense dynamic_update_slice KV writes)
        aligned = (len(active) == self.num_slots
                   and len({self.slots[i].pos for i in active}) == 1)
        state = self._segment_sample_state(active)
        seg = self._compiled(
            ("segment", self.num_slots, steps,
             "aligned" if aligned else "ragged",
             "sampled" if state is not None else "greedy",
             self._plan_key),
            lambda: self._segment_fn(steps),
        )
        pos_arg = (jnp.int32(self.slots[active[0]].pos) if aligned
                   else jnp.asarray(pos))
        t0 = self._timer()
        with kops.execution_plan(self.plan):
            buf, self._toks, self.cache = seg(
                self.params, self._toks, self.cache, pos_arg, state)
        # segment dispatch wall (trace + enqueue; execution is async) —
        # a wedged compile shows up here, and on the host backends the
        # dispatch is effectively synchronous so hangs do too
        if self.watchdog.observe(self._timer() - t0):
            self.stats.watchdog_events += 1
        self.stats.segments += 1
        self.stats.decode_steps += steps * len(active)
        # shrink-to-fit guarantees steps <= every active slot's remaining
        # (no active slot overshoots); the waste that remains is the
        # free/dead rows the batched program decodes alongside them
        self.stats.wasted_steps += steps * (self.num_slots - len(active))
        for i in active:
            slot = self.slots[i]
            take = min(steps, slot.remaining)
            slot.chunks.append((buf, i, take))
            slot.generated += take
            slot.remaining -= take
            slot.pos += take
            if slot.remaining == 0:
                self._retire(i)

    def step(self, *, draining: bool = False) -> list[FinishedRequest]:
        """Admit into free slots, then decode one segment on all active
        slots; returns requests that finished this step (synced).
        ``draining=True`` tells segment sizing no live submit can arrive
        (the router's step-wise drain uses it to keep boundaries
        identical to a blocking ``run()``)."""
        self._advance(draining=draining)
        return self._materialize()

    def _has_work(self) -> bool:
        return bool(self.pending) or any(not s.free for s in self.slots)

    @property
    def load(self) -> int:
        """Outstanding requests on this server: queued + occupying a
        slot. The replica router's least-loaded signal."""
        return len(self.pending) + sum(not s.free for s in self.slots)

    def run(self) -> list[FinishedRequest]:
        """Drain every pending + active request; returns all finished
        requests (ordered by rid). The whole drain is enqueued without
        host syncs; tokens are fetched once at the end."""
        while self._has_work():
            self._advance(draining=True)
        self._materialize()
        out, self.finished = self.finished, []
        return sorted(out, key=lambda r: r.rid)


# ---------------------------------------------------------------------------
# Paged KV pool scheduler: block tables + prefix caching + prefill-ahead.
# ---------------------------------------------------------------------------


_rag_io_pool: Any = None


def _rag_io():
    """The shared single-thread retrieval worker. ONE worker on
    purpose: queries retrieve strictly in submission order, and
    ``RagPipeline.retrieve`` is a pure function of the query over a
    read-only index, so backgrounding it cannot reorder or change any
    result — only move its wall time off the dispatch thread (where
    sleeps in a modeled payload fetch and numpy BLAS both release the
    GIL and genuinely overlap the synchronous segment dispatch)."""
    global _rag_io_pool
    if _rag_io_pool is None:
        import concurrent.futures
        _rag_io_pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="rag-io")
    return _rag_io_pool


@dataclasses.dataclass(eq=False)
class _PendingQuery:
    """A RAG query waiting for its retrieval turn: everything a
    ``_Request`` needs except the prompt, which retrieval + assembly
    produce. ``seq`` is reserved at submit, so a query's scheduling
    score is its ARRIVAL order — retrieval latency never reorders it
    behind later plain submits."""

    rid: int
    query: np.ndarray
    max_new: int
    sample: SamplingParams | None
    priority: int
    ttft_target: float | None
    itl_target: float | None
    submit_t: float
    seq: int


@dataclasses.dataclass(eq=False)
class _Spilled:
    """A preempted request waiting to resume: its generated tokens are
    synced to host numpy and its KV block payload parked in the
    ``SidebarSpillRegion`` (keyed by rid). Holds ZERO pool blocks — a
    spilled request can never pin memory or block an eviction."""

    req: _Request
    generated: int
    tokens: np.ndarray        # (generated,) int32
    valid_end: int            # KV valid on [0, valid_end) at restore
    n_blocks: int             # payload blocks (stats/bookkeeping)
    first_t: float | None     # original first-token time (TTFT keeps it)


def _hole_spans(hit_idx: tuple[int, ...], target: int,
                block_size: int) -> list[list[int]]:
    """Position spans ``[start, end)`` of ``[0, target)`` NOT covered by
    spliced hit blocks — what staging must still prefill. Contiguous
    misses merge into one span; with no interior hits this degenerates
    to the classic single ``[hit_len, target)`` frontier."""
    spans: list[list[int]] = []
    hit = set(hit_idx)
    p = 0
    while p < target:
        j = p // block_size
        if j in hit:
            p = (j + 1) * block_size
            continue
        e = min((j + 1) * block_size, target)
        if spans and spans[-1][1] == p:
            spans[-1][1] = e
        else:
            spans.append([p, e])
        p = e
    return spans


@dataclasses.dataclass(eq=False)
class _Staging:
    """A request whose prompt KV is being staged block-by-block into
    the pool (chunked prefill-ahead), before it owns any slot — or a
    restored spill (``resume`` set) that re-enters through the same
    staged -> admitted path with its KV already in place.

    ``todo`` holds the position spans still needing prefill, in order.
    Interior-hole splicing makes hits sparse, so this is a span LIST,
    not a single frontier: hit blocks between spans already hold valid
    KV and are never written. Spans complete front to back (a later
    span's prefill attends to everything before it, so the earlier
    span's KV must land first)."""

    req: _Request
    rb: kvp.RequestBlocks
    todo: list[list[int]]     # [start, end) spans, ascending, disjoint
    resume: _Spilled | None = None

    @property
    def done(self) -> bool:
        return not self.todo


class PagedContinuousBatchingServer(ContinuousBatchingServer):
    """Continuous batching over a block-granular paged KV pool.

    Same external contract as the slab scheduler (``submit`` / ``step``
    / ``run``, bit-identical tokens), different memory and admission
    disciplines:

      * **Paged KV** — ONE physical block pool (``launch.kvpool``)
        instead of per-slot max-length rows; each request maps its
        positions onto pooled blocks through a logical block table.
        With the default ``kernel="paged"`` the segment program decodes
        IN PLACE on the pool: per-step writes land through the tables
        and attention walks them directly
        (``kernels.ops.paged_attention_*``), no pool-wide copies.
        ``kernel="slab"`` keeps the original gather → dense decode →
        scatter segment as the reference implementation. Capacity is
        ``num_blocks * block_size`` *positions*, shared: short requests
        no longer reserve max_len rows.
      * **Prefix caching** — full prompt blocks are hash-consed: a
        request whose prompt starts with an already-served prefix
        splices those blocks (refcount bump) instead of recomputing
        their KV; retired requests' published blocks stay cached until
        LRU eviction. Copy-on-write isolates any write into shared
        state (structurally unreachable today — sharing stops before
        every write range — but enforced, not assumed).
      * **Chunked prefill-ahead** — pending requests' prompt KV stages
        in fixed-size chunks BETWEEN decode segments (one bounded
        staging program per boundary while slots decode), so by the
        time a slot frees, admission is a host-side block-table splice.
        The correction step — decode of the true last prompt token at
        its true position — is the admitted row's FIRST step of the
        very next segment program: admission costs zero extra
        dispatches, closing the admission/segment-fusion open item (one
        program per scheduler iteration, vs prefill + correction +
        segment at the slab scheduler's boundary).
      * **Lazy allocation + preemption** — ``begin_request`` reserves
        the staged span only; ``_grow_active`` takes decode blocks as
        each span crosses a block boundary, so a small pool
        oversubscribes until it genuinely can't. When a higher-scored
        arrival (see ``_score``: priority class, then EDF deadline)
        cannot stage, the scheduler reclaims from strictly worse-scored
        holders — unstage first, then spill the worst active span's KV
        to the host-side ``SidebarSpillRegion`` and hand over its slot.
        Restore splices the blocks back and resumes position-exact; a
        preempted-then-restored drain is token-identical to an
        unpressured one (the position-keyed PRNG never sees scheduling
        history).
      * **Speculative decoding** — with ``spec=SpecConfig(...)`` each
        segment step becomes draft → verify → commit: the draft model
        proposes ``spec.k`` tokens per row from its own dense slot
        cache (never the pool), the target verifies all k+1 positions
        in ONE batched rowwise prefill through the block tables, and
        the host commits the accepted prefix (+ the target's own token
        at the first mismatch) by lazy span growth + scratch→pool
        block copies. Verify KV for not-yet-granted positions lands in
        per-slot spare scratch blocks outside the allocator, so a
        rejected draft allocates nothing; a fully-rejected step still
        emits one token. Emitted tokens are bit-identical to plain
        decode for any draft, greedy and sampled.

    Numerics: the table-ordered (B, nb*block_size) view — gathered by
    the slab segment, walked in place by the paged kernel — equals the
    slab cache wherever the causal mask looks (junk in unwritten blocks
    sits behind ``kpos <= pos`` exactly like a slab's stale tail), and
    masked logits at -1e30 underflow to exactly 0.0 in f32, so slicing
    the table to the active frontier changes no sum — generation is
    bit-identical to the slab scheduler AND to solo decode, prefix hits
    and chunk boundaries included. (MoE under a
    dropping capacity factor: chunk boundaries change which tokens
    compete, the same caveat as prompt bucketing — serve no-drop for
    bit-parity.) Sampling needs nothing new: the position-keyed PRNG
    never sees block geometry.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 block_size: int = 16, num_blocks: int | None = None,
                 prefill_chunk: int | None = None,
                 stage_ahead: int | None = None,
                 spill_region: SidebarSpillRegion | None = None,
                 kernel: str = "paged",
                 spec: SpecConfig | None = None,
                 rag=None, rag_overlap: bool = True, **kw) -> None:
        if kernel not in ("paged", "slab"):
            raise ValueError(
                f"kernel must be 'paged' or 'slab', got {kernel!r}"
            )
        # speculative decoding (launch.spec): replaces segment decode
        # with draft -> one-program verify -> host-side accept/commit.
        # spec.k == 0 (or None) keeps plain segment decode, bit-exactly.
        self.spec = spec
        self._spec_on = spec is not None and spec.k > 0
        if self._spec_on:
            spec.validate(cfg)
        # ``kernel="paged"`` (default): segment decode runs IN PLACE on
        # the block pool through ``kernels.ops.paged_attention_*`` —
        # zero pool-wide gather/scatter copies, tables sliced to the
        # active frontier. ``kernel="slab"`` keeps the dense round-trip
        # segment (gather_blocks / scatter_blocks) as the reference.
        self.kernel = kernel
        # consumed by _init_kv, which super().__init__ calls
        self.block_size = int(block_size)
        self._num_blocks_arg = num_blocks
        self.prefill_chunk = int(prefill_chunk or block_size)
        self._stage_ahead_arg = stage_ahead
        self._spill_region_arg = spill_region
        # retrieval stage (``rag=RagPipeline(...)``): ``submit_query``
        # parks queries here. With ``rag_overlap`` (default) the search
        # itself — the expensive, I/O-shaped half — starts immediately
        # on a background worker (``_rag_io``), so it runs concurrently
        # with whatever the scheduler does next, including the segment
        # dispatch (which on the CPU backend blocks for the whole
        # segment: donated cache buffers make dispatch synchronous, so
        # single-threaded retrieve-after-dispatch would hide nothing).
        # ``_drain_queries`` then collects the ranked result and does
        # the cheap assembly + staging at the boundary AFTER the
        # dispatch — retrieval for request N+1 hidden behind the
        # accelerator decoding active requests, the sidebar overlap
        # schedule at serving granularity. ``rag_overlap=False`` never
        # kicks off the worker: it quiesces in-flight device work and
        # retrieves serially before staging — the retrieve-then-decode
        # pipeline, the bench's comparison arm.
        self.rag = rag
        if rag is not None and rag.block_size != int(block_size):
            raise ValueError(
                f"RagPipeline block_size {rag.block_size} != scheduler "
                f"block_size {block_size}: chunk boundaries must land on "
                "pool block boundaries"
            )
        self.rag_overlap = bool(rag_overlap)
        self._queries: collections.deque[_PendingQuery] = (
            collections.deque())
        self._rag_futures: dict[int, Any] = {}      # rid -> Future
        self._rag_meta: dict[int, list[int]] = {}   # rid -> chunk blocks
        self.rag_results: dict[int, Any] = {}       # rid -> RagPrompt
        super().__init__(cfg, params, **kw)
        if self.faults is not None:
            # allocation-failure site: every alloc consults the injector
            self.mgr.alloc.fault_hook = (
                lambda: self.faults.fire("alloc"))

    def _init_kv(self) -> None:
        if self.max_len % self.block_size:
            raise ValueError(
                f"max_len {self.max_len} must be a multiple of "
                f"block_size {self.block_size} (tables are fixed-width; "
                "the gathered view must equal the slab shape)"
            )
        self.blocks_per_table = self.max_len // self.block_size
        nb = self._num_blocks_arg
        if nb is None:
            # full tables for every slot + staging/prefix slack + scratch
            nb = (self.num_slots + 2) * self.blocks_per_table + 1
        # speculative decoding: each slot owns a fixed private slice of
        # SPARE pool rows (outside the allocator — never refcounted,
        # never spilled) big enough for the worst-case drafted overhang:
        # k positions past a block-aligned frontier is ceil(k/bs) blocks.
        spec_k = self.spec.k if self._spec_on else 0
        self._n_scratch = -(-spec_k // self.block_size)
        self.mgr = kvp.PagedKVManager(
            self.api, self.cfg, self.minfo,
            num_blocks=nb, block_size=self.block_size,
            place=self.tp.place_cache if self.tp is not None else None,
            spare_blocks=self.num_slots * self._n_scratch,
        )
        if self._spec_on:
            base = self.mgr.alloc.num_blocks
            self._scratch = [
                list(range(base + i * self._n_scratch,
                           base + (i + 1) * self._n_scratch))
                for i in range(self.num_slots)
            ]
            self.draft_api = self.spec.draft_api()
            self._draft_params = self.spec.draft_params
            # the draft's own dense slot cache — it NEVER takes pool
            # blocks; always unsharded (the draft is small by design)
            self._draft_cache = self.draft_api.init_cache(
                self.spec.draft_cfg, L.HOST, self.num_slots, self.max_len)
            # slot -> (rid, draft ingest frontier); keying by rid makes
            # slot reuse / spill / restore reset the frontier for free
            self._dpos: dict[int, tuple[int, int]] = {}
        self.cache = None  # the pool replaces the slab entirely
        self.stage_ahead = (self._stage_ahead_arg
                            if self._stage_ahead_arg is not None
                            else self.num_slots)
        # logical -> physical tables, host-side; unoccupied entries point
        # at the reserved scratch block (dead writes land in junk)
        self._tables = np.full((self.num_slots, self.blocks_per_table),
                               kvp.SCRATCH_BLOCK, np.int32)
        self._slot_rb: list[kvp.RequestBlocks | None] = (
            [None] * self.num_slots)
        self._staging: collections.deque[_Staging] = collections.deque()
        # preemption: spilled requests wait here; payloads live in the
        # host-side sidebar region keyed by rid
        # NOTE: explicit None test — an empty region is len() == 0,
        # i.e. falsy, and ``or`` would silently drop the caller's region
        self.spill = (self._spill_region_arg
                      if self._spill_region_arg is not None
                      else SidebarSpillRegion())
        self._spilled: list[_Spilled] = []
        # slot -> correction token for rows admitted this boundary (the
        # merge the segment program fuses); a dict so a victim spilled
        # between admission and dispatch just drops its entry
        self._admit_pending: dict[int, int] = {}
        self.stats.pool_blocks = self.mgr.alloc.capacity

    # -- bookkeeping -------------------------------------------------------
    def _sync_pool_stats(self) -> None:
        c = self.mgr.counters
        self.stats.cow_copies = c.cow_copies
        self.stats.evictions = c.evictions
        self.stats.prefix_block_lookups = c.prefix_block_lookups
        self.stats.prefix_block_hits = c.prefix_block_hits
        self.stats.prefix_prompt_blocks = c.prompt_blocks
        self.stats.chunk_interior_hits = c.chunk_interior_hits
        self.stats.pool_in_use = self.mgr.alloc.in_use
        self.stats.pool_in_use_peak = c.in_use_peak

    def _has_work(self) -> bool:
        return (super()._has_work() or bool(self._staging)
                or bool(self._spilled) or bool(self._queries))

    @property
    def load(self) -> int:
        return (super().load + len(self._staging) + len(self._spilled)
                + len(self._queries))

    def submit(self, prompt, max_new_tokens: int,
               sample: SamplingParams | None = None, *,
               priority: int = 0, ttft_target: float | None = None,
               itl_target: float | None = None) -> int:
        prompt_arr = np.asarray(prompt, np.int32).reshape(-1)
        if prompt_arr.size >= 1 and max_new_tokens >= 1:
            # allocation is lazy (the span grows segment by segment),
            # but the WORST-CASE span must fit the pool alone, or the
            # request could preempt everything and still wedge
            need = self.mgr.blocks_needed(
                prompt_arr.size + max_new_tokens - 1)
            if need > self.mgr.alloc.capacity:
                raise ValueError(
                    f"request needs {need} blocks, pool holds "
                    f"{self.mgr.alloc.capacity} — raise num_blocks or "
                    "shrink the request"
                )
        return super().submit(prompt, max_new_tokens, sample,
                              priority=priority, ttft_target=ttft_target,
                              itl_target=itl_target)

    # -- retrieval stage (RAG) ---------------------------------------------
    def submit_query(self, query, max_new_tokens: int,
                     sample: SamplingParams | None = None, *,
                     priority: int = 0, ttft_target: float | None = None,
                     itl_target: float | None = None) -> int:
        """Enqueue a RAG query: retrieval + prompt assembly run later as
        host work between segment dispatches (``rag_overlap`` hides them
        behind the in-flight decode segment), then the assembled prompt
        enters the normal pending -> staging -> admission path. Returns
        the rid; the assembled ``RagPrompt`` (tokens + per-chunk
        provenance) lands in ``rag_results[rid]`` when retrieval runs.

        Validation is EAGER: the assembled length is deterministic
        before retrieval (system prefix + top_k chunks are fixed-size,
        the query rides verbatim), so a too-long or pool-overflowing
        request raises here, not mid-drain."""
        if self.rag is None:
            raise ValueError(
                "submit_query needs a RagPipeline: construct the server "
                "with rag=RagPipeline(...)")
        q = np.asarray(query, np.int32).reshape(-1)
        if q.size < 1:
            raise ValueError("empty query")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        s = self.rag.prompt_len_for + q.size
        if s + max_new_tokens > self.max_len:
            raise ValueError(
                f"assembled prompt {s} + max_new {max_new_tokens} "
                f"exceeds max_len {self.max_len}"
            )
        need = self.mgr.blocks_needed(s + max_new_tokens - 1)
        if need > self.mgr.alloc.capacity:
            raise ValueError(
                f"assembled request needs {need} blocks, pool holds "
                f"{self.mgr.alloc.capacity} — raise num_blocks or "
                "shrink the request"
            )
        rid = self._next_rid
        self._next_rid += 1
        self._queries.append(_PendingQuery(
            rid=rid, query=q, max_new=int(max_new_tokens), sample=sample,
            priority=int(priority), ttft_target=ttft_target,
            itl_target=itl_target, submit_t=self._clock(), seq=self._seq,
        ))
        self._seq += 1
        if self.rag_overlap:
            # start the search NOW on the I/O worker — it overlaps all
            # host work and dispatches until the drain collects it
            self._rag_futures[rid] = _rag_io().submit(
                self.rag.retrieve, q)
        return rid

    def _drain_queries(self, *, overlapped: bool) -> None:
        """Collect retrieval + run assembly for every parked query and
        promote it to a pending ``_Request``. Called at one of two
        points in the boundary: right AFTER a segment dispatch
        (``overlapped=True`` — the search has been running on the I/O
        worker since submit, hidden behind the dispatch; collecting it
        here costs only the uncovered remainder) or at the top of
        ``_advance`` when nothing is decoding / overlap is off (with
        overlap off there is no future and retrieval runs inline, on
        the critical path)."""
        while self._queries:
            pq = self._queries.popleft()
            fut = self._rag_futures.pop(pq.rid, None)
            rp = self.rag.assemble(
                pq.query, ranked=None if fut is None else fut.result())
            self.rag_results[pq.rid] = rp
            self._rag_meta[pq.rid] = rp.chunk_blocks(self.block_size)
            self.stats.retrievals += 1
            if overlapped:
                self.stats.retrieval_overlapped += 1
            self.pending.append(_Request(
                pq.rid, rp.tokens, pq.max_new, pq.sample,
                priority=pq.priority, ttft_target=pq.ttft_target,
                itl_target=pq.itl_target, submit_t=pq.submit_t,
                seq=pq.seq,
            ))

    def cancel(self, rid: int) -> bool:
        for pq in self._queries:
            if pq.rid == rid:
                self._queries.remove(pq)
                # an in-flight search is harmless (pure, read-only) —
                # just drop the handle so its result is never collected
                self._rag_futures.pop(rid, None)
                self.stats.cancelled += 1
                return True
        for st in self._staging:
            if st.req.rid == rid:
                # staged (or restored-but-unadmitted): release the
                # blocks; a cancelled request's KV needs no preserving
                self._staging.remove(st)
                self.mgr.release_request(st.rb)
                self.stats.cancelled += 1
                return True
        for sp in self._spilled:
            if sp.req.rid == rid:
                self._spilled.remove(sp)
                self.spill.release(rid)
                self.stats.cancelled += 1
                return True
        return super().cancel(rid)

    # -- chunked prefill-ahead (staging) -----------------------------------
    def _stage_fn(self) -> Callable:
        return jax.jit(
            make_prefill_step(self.cfg, self.api, self.minfo, self.mesh,
                              tp=self.tp),
            donate_argnums=(2,),
        )

    def _stage_round(self, entries: list[_Staging]) -> None:
        """ONE bounded staging program advances every incomplete staging
        entry by up to ``prefill_chunk`` tokens, each row writing at its
        own frontier through its own block table (the same rowwise-
        position machinery as ragged segment decode, at prefill width).
        Fixed chunk length + batch size keyed executables; the zero-
        padded tail of a final chunk writes junk beyond the prompt that
        later decode writes overwrite or the causal mask hides (the
        bucket-padding argument; MoE: padded/co-staged rows share expert
        capacity — serve no-drop for bit-parity, as with bucketing)."""
        k, c = len(entries), self.prefill_chunk
        bs = self.block_size
        toks = np.zeros((k, c), np.int32)
        pos = np.empty((k,), np.int32)
        bt = np.empty((k, self.blocks_per_table), np.int32)
        for j, st in enumerate(entries):
            s, e = st.todo[0]
            valid = min(e - s, c)
            toks[j, :valid] = st.req.prompt[s:s + valid]
            pos[j] = s
            row = np.asarray(st.rb.table_row(self.blocks_per_table)).copy()
            # the chunk's zero-padded tail writes junk past ``valid`` —
            # harmless when the following blocks are this request's own
            # fresh staged blocks (the classic case), fatal if one is a
            # SPLICED hit past an interior hole (junk would overwrite
            # live shared KV). Divert every block past the last validly
            # written one to the scratch row: junk lands in junk, and
            # no valid position in this chunk ever READS that far ahead
            # (causal attention looks backward only).
            row[(s + valid - 1) // bs + 1:] = kvp.SCRATCH_BLOCK
            bt[j] = row
        kvp.validate_tables(bt, self.mgr.pool.num_blocks)
        fn = self._compiled(
            ("stage", k, c, self.blocks_per_table, self._plan_key),
            self._stage_fn)
        with kops.execution_plan(self.plan):
            _, self.mgr.pool.cache = fn(
                self.params, {"tokens": jnp.asarray(toks)},
                self.mgr.pool.cache, None, jnp.asarray(pos),
                jnp.asarray(bt),
            )
        for st in entries:
            s, e = st.todo[0]
            if s + c >= e:
                st.todo.pop(0)
            else:
                st.todo[0][0] = s + c
        self.stats.stage_chunks += k

    def _stage(self, *, catch_up: bool) -> None:
        """Prefill-ahead: restore spilled requests into free slots'
        staging (they are furthest along), start staging the best-scored
        pending requests (prefix splice + staging-span allocation — the
        span is LAZY: only the prompt's blocks, growth comes per
        segment), then advance every incomplete staging entry by one
        batched chunk round — or to completion when there is no active
        decode to overlap behind (``catch_up``).

        Under pool pressure a better-scored request reclaims from
        strictly worse holders (``_reclaim_for``): a lower-priority
        staging entry is unstaged, an active slot preempted — this is
        how a high-priority arrival jumps a saturated replica."""
        self._try_restore()
        while self.pending:
            req = min(self.pending, key=self._score)
            if len(self._staging) >= self.stage_ahead:
                # staging entry slots are a resource too: a strictly
                # worse entry yields its place (EDF jump); FIFO scores
                # never reorder, so the baseline behaves as before
                worst = max(self._staging,
                            key=lambda st: self._score(st.req))
                if not self._score(req) < self._score(worst.req):
                    break
                self._unstage(worst)
            n_stage = max(int(req.prompt.size) - 1, 1)
            rb = self.mgr.begin_request(req.prompt, n_stage)
            while rb is None and self._reclaim_for(self._score(req)):
                rb = self.mgr.begin_request(req.prompt, n_stage)
            if rb is None:
                self.stats.stage_stalls += 1
                break
            self.pending.remove(req)
            meta = self._rag_meta.pop(req.rid, None)
            if meta is not None:
                # chunk-reuse accounting: of the retrieved-chunk blocks
                # this assembled prompt staged, how many spliced from
                # the index instead of prefilling
                self.stats.retrieval_chunk_blocks += len(meta)
                self.stats.retrieval_chunk_hits += len(
                    set(rb.hit_idx) & set(meta))
            self._staging.append(_Staging(
                req=req, rb=rb,
                todo=_hole_spans(rb.hit_idx, int(req.prompt.size) - 1,
                                 self.block_size),
            ))
        if self.faults is not None and self.faults.fire("stage_stall"):
            # injected wedged staging round: no prefill work this
            # boundary; incomplete entries pick up at the next one
            self.stats.stage_stalls += 1
            return
        while True:
            work = [st for st in self._staging if not st.done]
            if not work:
                return
            self._stage_round(work)
            if not catch_up:
                return

    # -- preemption: spill / restore / reclaim -----------------------------
    def _spill_slot(self, i: int) -> None:
        """Preempt the request in slot ``i``: sync its generated tokens,
        copy its live KV blocks to the host spill region, release every
        pool block it owns, free the slot. Restore resumes bit-exactly:
        the KV round-trips losslessly and the position-keyed PRNG makes
        the sampled stream a pure function of (seed, position)."""
        slot = self.slots[i]
        rb = self._slot_rb[i]
        tokens = self.slot_tokens(i)
        payload = self.mgr.spill_request(rb, slot.pos)
        self.spill.stage(slot.rid)
        self.spill.commit(slot.rid, payload, payload["nbytes"])
        self._spilled.append(_Spilled(
            req=slot.req, generated=slot.generated, tokens=tokens,
            valid_end=slot.pos, n_blocks=payload["n_blocks"],
            first_t=slot.first_t,
        ))
        self._slot_rb[i] = None
        self._tables[i] = kvp.SCRATCH_BLOCK
        self._admit_pending.pop(i, None)   # dies with the slot
        self.slots[i] = _Slot()
        self.stats.preemptions += 1
        self.stats.spilled_blocks += payload["n_blocks"]

    def _unstage(self, st: _Staging) -> None:
        """Reclaim a staging entry's blocks. A fresh entry requeues to
        pending (prompt KV is recomputable); a restored spill re-spills
        — its generated KV is not recomputable from the prompt."""
        self._staging.remove(st)
        if st.resume is None:
            self.mgr.release_request(st.rb)
            self.pending.append(st.req)
            self.stats.unstaged += 1
        else:
            sp = st.resume
            payload = self.mgr.spill_request(st.rb, sp.valid_end)
            self.spill.stage(sp.req.rid)
            self.spill.commit(sp.req.rid, payload, payload["nbytes"])
            self._spilled.append(sp)
            self.stats.preemptions += 1
            self.stats.spilled_blocks += payload["n_blocks"]

    def _reclaim_for(self, score: tuple,
                     exclude_slot: int | None = None) -> bool:
        """Free pool resources for a request scoring ``score`` by
        victimizing the WORST strictly-worse holder: an unadmitted
        staging entry is unstaged, an active slot is spilled. Strict
        ordering (scores are a total order via ``seq``) means A can
        preempt B and never vice versa — no thrash, guaranteed
        progress. Returns False when no worse victim exists (the
        requester is itself the worst — it waits or self-spills)."""
        victims: list[tuple[tuple, int, object]] = []
        for st in self._staging:
            victims.append((self._score(st.req), 0, st))
        for i, slot in enumerate(self.slots):
            if i != exclude_slot and not slot.free:
                victims.append((self._score(slot.req), 1, i))
        victims = [v for v in victims if v[0] > score]
        if not victims:
            return False
        _, kind, victim = max(victims, key=lambda v: v[0])
        if kind == 0:
            self._unstage(victim)
        else:
            self._spill_slot(victim)
        return True

    def _try_restore(self) -> None:
        """Splice spilled requests back, best score first, one per free
        slot: re-acquire blocks (prefix-index hits splice bit-identical
        content; misses rewrite the host copy), re-publish, and enter
        the staged-done queue — admission treats a restore exactly like
        a fully staged arrival. A restore may itself reclaim from
        strictly worse holders; on failure the request stays spilled
        (payload untouched) for the next boundary."""
        if not self._spilled:
            return
        reserved = 0   # restores this call, each owed a free slot
        for sp in sorted(self._spilled,
                         key=lambda s: self._score(s.req)):
            if sum(s.free for s in self.slots) - reserved <= 0:
                return
            payload = self.spill.fetch(sp.req.rid)
            rb = self.mgr.restore_request(sp.req.prompt, payload)
            while rb is None and self._reclaim_for(self._score(sp.req)):
                rb = self.mgr.restore_request(sp.req.prompt, payload)
            if rb is None:
                return
            self._spilled.remove(sp)
            self.spill.release(sp.req.rid)
            self._staging.append(_Staging(
                req=sp.req, rb=rb, todo=[], resume=sp,
            ))
            self.stats.restores += 1
            self.stats.restored_blocks += sp.n_blocks

    # -- work-stealing handoff (router-level migration) --------------------
    def take_spilled(self, rid: int) -> tuple[_Spilled, dict] | None:
        """Detach a spilled request for migration to a sibling replica:
        returns its resume state and host-side KV payload (both plain
        numpy — device-agnostic), releasing the local spill-region
        reservation. The router steals work this way when another
        replica holds the victim's prefix warm (or simply has room)."""
        for sp in self._spilled:
            if sp.req.rid == rid:
                self._spilled.remove(sp)
                payload = self.spill.fetch(rid)
                self.spill.release(rid)
                return sp, payload
        return None

    def submit_spilled(self, sp: _Spilled, payload: dict) -> int:
        """Adopt a request stolen from a sibling: re-key it into THIS
        server's rid/seq space (priority, deadline and first-token time
        travel with it — SLO accounting does not reset on migration)
        and park it in the local spill region; the normal restore path
        does the rest at the next boundary."""
        rid = self._next_rid
        self._next_rid += 1
        sp.req.rid = rid
        sp.req.seq = self._seq
        self._seq += 1
        self.spill.stage(rid)
        self.spill.commit(rid, payload, payload["nbytes"])
        self._spilled.append(sp)
        return rid

    # -- admission: a block-table splice, zero dispatches ------------------
    def _admit_ready(self) -> None:
        """Move fully staged requests into free slots, best score first
        (EDF jumps the done-queue too; FIFO scores keep arrival order).
        Pure host bookkeeping — the admitted row's correction step
        (decode of ``prompt[-1]`` at position S-1, exactly the logits
        solo decode computes there) runs as its first step INSIDE the
        next segment program, so admission adds no dispatch of its own.
        The correction token parks in ``_admit_pending`` until that
        dispatch; a row preempted in between just drops its entry.

        A restored spill (``st.resume``) re-enters here with its KV
        already spliced: the slot picks up at ``valid_end`` with its
        synced tokens as a host chunk and its original first-token time
        — downstream accounting cannot tell it was ever gone."""
        ready = sorted((st for st in self._staging if st.done),
                       key=lambda st: self._score(st.req))
        free = [i for i, s in enumerate(self.slots) if s.free]
        for st in ready:
            if not free:
                return
            i = free.pop(0)
            self._staging.remove(st)
            r, sp = st.req, st.resume
            slot = self.slots[i]
            slot.rid = r.rid
            slot.prompt = r.prompt
            slot.sample = r.sample
            slot.key = (None if r.sample is None else
                        np.asarray(sampling.request_key(r.sample.seed)))
            slot.req = r
            if sp is None:
                self.mgr.publish_prompt(r.prompt, st.rb)
                # the first write position S-1 must be exclusively
                # owned; structurally it always is (sharing covers only
                # full prompt[:-1] blocks) — enforced, not assumed
                wb = (int(r.prompt.size) - 1) // self.block_size
                if wb < len(st.rb.bids):
                    self.mgr.ensure_exclusive(st.rb, wb)
                slot.pos = int(r.prompt.size) - 1
                slot.remaining = r.max_new
                slot.generated = 0
                slot.chunks = []
                slot.first_t = None
                tok = int(r.prompt[-1])
                self.stats.admitted += 1
            else:
                # resume: KV valid on [0, valid_end); next input token
                # is the last one generated (or prompt[-1] if preempted
                # before any) — exactly where the stream left off
                slot.pos = sp.valid_end
                slot.remaining = r.max_new - sp.generated
                slot.generated = sp.generated
                slot.chunks = ([(sp.tokens.reshape(1, -1), 0,
                                 sp.generated)] if sp.generated else [])
                slot.first_t = sp.first_t
                tok = (int(sp.tokens[-1]) if sp.generated
                       else int(r.prompt[-1]))
            self._tables[i] = st.rb.table_row(self.blocks_per_table)
            self._slot_rb[i] = st.rb
            self._admit_pending[i] = tok

    def _free_slot(self, slot_idx: int) -> None:
        rb = self._slot_rb[slot_idx]
        if rb is not None:
            self.mgr.release_request(rb)
            self._slot_rb[slot_idx] = None
        self._tables[slot_idx] = kvp.SCRATCH_BLOCK
        self._admit_pending.pop(slot_idx, None)
        super()._free_slot(slot_idx)

    # -- segment decode (admission fused in) -------------------------------
    def _paged_segment_fn(self, num_steps: int, admit_k: int) -> Callable:
        """The slab scheduler's batched segment scan, bracketed by block
        bookkeeping: gather the tables' blocks into a dense slab view
        ONCE, decode every step on it with the existing dense machinery
        (the aligned/ragged fast paths kept verbatim — paging costs O(1)
        gathers per segment, not per token), scatter the blocks back at
        the end. Plus the admission token merge: newly admitted rows
        enter the scan at their correction position, so one program
        covers admit + decode — no separate admission dispatch."""
        step = make_serve_step(self.cfg, self.api, self.minfo, self.mesh,
                               tp=self.tp)
        max_pos = self.max_len - 1
        baxes, laxes = self.mgr.pool.batch_axes, self.mgr.pool.length_axes

        def segment(params, toks, pool, pos, bt, admit_slots, admit_toks,
                    sample=None):
            if admit_k:
                toks = toks.at[admit_slots].set(admit_toks)
            dense = kvp.gather_blocks(pool, baxes, laxes, bt)
            buf = jnp.zeros((toks.shape[0], num_steps), jnp.int32)

            def body(carry, i):
                tok, dense, buf = carry
                p = jnp.minimum(pos + i, max_pos)
                nxt, dense = step(params, tok, dense, p, None, sample)
                buf = jax.lax.dynamic_update_slice(buf, nxt, (0, i))
                return (nxt, dense, buf), None

            (last, dense, buf), _ = jax.lax.scan(
                body, (toks, dense, buf),
                jnp.arange(num_steps, dtype=jnp.int32),
            )
            pool = kvp.scatter_blocks(pool, dense, baxes, laxes, bt)
            return buf, last, pool

        return jax.jit(segment, donate_argnums=(1, 2))

    def _paged_kernel_segment_fn(self, num_steps: int,
                                 admit_k: int) -> Callable:
        """The slab-free segment: decode runs IN PLACE on the block
        pool. No ``gather_blocks`` / ``scatter_blocks`` brackets — the
        scan carries the pool itself and every step's attention walks
        the block table directly (``kernels.ops.paged_attention_*``),
        so the segment's cache traffic is the ~steps × slots KV rows it
        actually touches instead of two pool-wide copies. The table is
        already sliced to the active frontier by ``_advance``, so the
        attention width tracks the longest live prefix, not
        ``max_len``. Admission merge as in the slab segment."""
        step = make_serve_step(self.cfg, self.api, self.minfo, self.mesh,
                               tp=self.tp)
        max_pos = self.max_len - 1

        def segment(params, toks, pool, pos, bt, admit_slots, admit_toks,
                    sample=None):
            if admit_k:
                toks = toks.at[admit_slots].set(admit_toks)
            buf = jnp.zeros((toks.shape[0], num_steps), jnp.int32)

            def body(carry, i):
                tok, pool, buf = carry
                p = jnp.minimum(pos + i, max_pos)
                nxt, pool = step(params, tok, pool, p, None, sample, bt)
                buf = jax.lax.dynamic_update_slice(buf, nxt, (0, i))
                return (nxt, pool, buf), None

            (last, pool, buf), _ = jax.lax.scan(
                body, (toks, pool, buf),
                jnp.arange(num_steps, dtype=jnp.int32),
            )
            return buf, last, pool

        return jax.jit(segment, donate_argnums=(1, 2))

    def _segment_table_width(self, active: list[int], steps: int) -> int:
        """Block-table width for this segment: cover the farthest
        position any active row will attend to (its write frontier
        after ``steps``), rounded up to a power of two so executable
        shapes stay few, clamped to the full table. Narrower tables
        mean the paged kernel's grid only walks blocks that can hold
        live KV."""
        frontier = max(self.slots[i].pos + steps for i in active)
        nbu = -(-frontier // self.block_size)
        nbu = 1 << max(0, (nbu - 1).bit_length())
        return max(1, min(self.blocks_per_table, nbu))

    def _segment_steps(self, active: list[int], *,
                       draining: bool = False) -> int:
        """Shrink-to-fit as in the slab scheduler, with one more reason
        to cap at ``segment``: an INCOMPLETE staging entry needs
        boundaries to interleave its chunks behind decode — that cadence
        IS the prefill-ahead overlap. (Merely having a backlog does not:
        ``_stage`` already ran this iteration, so whatever could start
        staging has, and fully staged entries just wait for a
        retirement, which is itself a boundary — capping for them would
        be pure dispatch overhead, the mistake the slab scheduler's
        hysteresis timeout exists to bound.) Parked RAG queries count
        too: overlapped retrieval runs right after this dispatch and
        the assembled prompts stage at the NEXT boundary — an uncapped
        segment would turn the overlap into an admission-latency tax
        larger than the retrieval it hides."""
        min_rem = min(self.slots[i].remaining for i in active)
        staging_wants_boundaries = (
            any(not st.done for st in self._staging)
            or bool(self._spilled)    # spills restore only at boundaries
            or bool(self._queries))   # park -> retrieve -> stage next
        entry_possible = staging_wants_boundaries or (
            not draining and any(s.free for s in self.slots))
        if entry_possible:
            return min(min_rem, self.segment)
        if min_rem <= self.segment:
            return min_rem
        return 1 << (min_rem.bit_length() - 1)

    def _grow_active(self, draining: bool,
                     steps_override: int | None = None
                     ) -> tuple[list[int], int]:
        """Grow every active row's span to cover the coming segment —
        the lazy-allocation flip side: staging allocated only the
        prompt's blocks, so each boundary must secure ``pos + steps``
        before dispatch. Best-scored rows grow first; a row that cannot
        grow reclaims from strictly worse holders, and if none exist it
        spills ITSELF (it is the worst — yielding now beats wedging the
        segment). Any membership change restarts the pass, so the
        returned (active, steps) is a fixpoint: every listed row owns
        its full segment span. Terminates: every restart consumed a
        victim, and victims are finite. ``steps_override`` fixes the
        span target instead of ``_segment_steps`` — the speculative path
        secures ONE position (its ``t_in`` write); drafted positions go
        to scratch and only accepted ones ever allocate (at commit)."""
        while True:
            active = [i for i, s in enumerate(self.slots)
                      if not s.free and s.remaining > 0]
            if not active:
                return [], 0
            steps = (steps_override if steps_override is not None
                     else self._segment_steps(active, draining=draining))
            changed = False
            for i in sorted(active,
                            key=lambda j: self._score(self.slots[j].req)):
                slot = self.slots[i]
                if slot.free:       # spilled by an earlier row's growth
                    changed = True
                    continue
                rb = self._slot_rb[i]
                need = slot.pos + steps
                ok = self.mgr.ensure_span(rb, need)
                while not ok and self._reclaim_for(
                        self._score(slot.req), exclude_slot=i):
                    changed = True
                    ok = self.mgr.ensure_span(rb, need)
                if not ok:
                    self._spill_slot(i)
                    changed = True
            if not changed:
                return active, steps

    def _advance(self, *, draining: bool = False) -> None:
        if self.faults is not None and self.faults.fire("evict_storm"):
            # injected eviction storm: every cached block force-evicted,
            # prefix index flushed — restores must survive a cold pool
            self.mgr.alloc.evict_cached()
        active_now = any(not s.free and s.remaining > 0
                         for s in self.slots)
        if self._queries and not (self.rag_overlap and active_now):
            # nothing decoding to hide behind (or overlap disabled):
            # collect/retrieve now, so the queries stage THIS boundary
            if not self.rag_overlap:
                # serial means serial — quiesce the enqueued device
                # work first (an async backlog would otherwise hide
                # retrieval behind it for free), so this arm models
                # the retrieve-then-decode pipeline the overlap path
                # beats. (With overlap on, the search already ran on
                # the I/O worker; collecting it needs no quiesce.)
                jax.block_until_ready(self._toks)
            self._drain_queries(overlapped=False)
        self._stage(catch_up=not active_now)
        self._admit_ready()
        self._sync_pool_stats()
        if self._spec_on:
            self._advance_spec(draining)
            return
        active, steps = self._grow_active(draining)
        if not active:
            return
        # growth may have extended (or preemption rebuilt) block spans —
        # refresh the dispatched tables from the live RequestBlocks
        for i in active:
            self._tables[i] = self._slot_rb[i].table_row(
                self.blocks_per_table)
        admits = sorted(self._admit_pending.items())
        self._admit_pending.clear()
        admit_slots = [i for i, _ in admits]
        admit_toks = [t for _, t in admits]
        pos = np.full((self.num_slots,), self.max_len - 1, np.int32)
        for i in active:
            pos[i] = self.slots[i].pos
        aligned = (len(active) == self.num_slots
                   and len({self.slots[i].pos for i in active}) == 1)
        state = self._segment_sample_state(active)
        admit_k = len(admit_slots)
        if self.kernel == "paged":
            width = self._segment_table_width(active, steps)
            seg_fn = self._paged_kernel_segment_fn
        else:
            width = self.blocks_per_table
            seg_fn = self._paged_segment_fn
        # host-side guards for the drop-sentinel write path: every table
        # entry must be a real pool block (gathers promise in-bounds)
        # and every active row's write frontier must stay inside its
        # allocated span (writes past it would silently drop)
        bt_np = self._tables[:, :width]
        kvp.validate_tables(bt_np, self.mgr.pool.num_blocks)
        for i in active:
            rb = self._slot_rb[i]
            if rb is not None:
                self.mgr.check_span(rb, self.slots[i].pos + steps)
        seg = self._compiled(
            ("pseg", self.num_slots, steps,
             "aligned" if aligned else "ragged",
             "sampled" if state is not None else "greedy",
             admit_k, self.kernel, width, self._plan_key),
            lambda: seg_fn(steps, admit_k),
        )
        pos_arg = (jnp.int32(self.slots[active[0]].pos) if aligned
                   else jnp.asarray(pos))
        bt = jnp.asarray(bt_np)
        a_slots = jnp.asarray(admit_slots, jnp.int32)
        a_toks = jnp.asarray(np.asarray(admit_toks,
                                        np.int32).reshape(-1, 1))
        t0 = self._timer()
        with kops.execution_plan(self.plan):
            buf, self._toks, self.mgr.pool.cache = seg(
                self.params, self._toks, self.mgr.pool.cache, pos_arg,
                bt, a_slots, a_toks, state,
            )
        if self.watchdog.observe(self._timer() - t0):
            self.stats.watchdog_events += 1
        if self._queries:
            # the parked queries' searches have been running on the I/O
            # worker throughout the dispatch above — collect and stage
            # them now, paying only whatever the segment didn't cover
            self._drain_queries(overlapped=True)
        self.stats.segments += 1
        self.stats.decode_steps += steps * len(active)
        self.stats.wasted_steps += steps * (self.num_slots - len(active))
        now = self._clock()
        for i in active:
            slot = self.slots[i]
            take = min(steps, slot.remaining)
            slot.chunks.append((buf, i, take))
            slot.generated += take
            slot.remaining -= take
            slot.pos += take
            if slot.first_t is None:
                # first token dispatched this segment (admitted rows'
                # correction step ran inside it)
                slot.first_t = now
                if slot.req is not None:
                    self.stats.record_ttft(slot.req.priority,
                                           now - slot.req.submit_t)
            if slot.remaining == 0:
                self._retire(i)
        # re-sync after the retirements so stats read at a quiescent
        # boundary (e.g. the serving example's summary after run())
        # reflect the released blocks, not the pre-segment snapshot
        self._sync_pool_stats()

    # -- speculative decoding (launch.spec) --------------------------------
    def _hist(self, i: int) -> np.ndarray:
        """Committed token history of the request in slot ``i`` (prompt
        + accepted generations): ``hist[p]`` is the token at sequence
        index ``p``, so ``hist[slot.pos]`` is the verifier's ``t_in``."""
        slot = self.slots[i]
        return np.concatenate(
            [slot.prompt, self.slot_tokens(i)]).astype(np.int32)

    def _draft_fn(self) -> Callable:
        # the draft always runs unsharded and under the DEFAULT execution
        # plan: per-layer plan entries are keyed to the TARGET's layers
        return jax.jit(
            make_draft_program(self.spec.draft_cfg, self.draft_api,
                               self.spec.k, self.max_len),
            donate_argnums=(4,),
        )

    def _verify_fn(self) -> Callable:
        return jax.jit(
            make_verify_step(self.cfg, self.api, self.minfo, self.mesh,
                             tp=self.tp),
            donate_argnums=(2,),
        )

    def _draft_tokens(self, active: list[int]) -> np.ndarray:
        """Run the combined ingest+draft program; returns (N, k) drafts.

        Each round feeds every active row the next <= k+1 committed
        tokens past its draft frontier (``_dpos``). In steady state the
        lag is exactly last step's commit (<= k+1), so ONE dispatch
        ingests and drafts; after admission or a restore the loop runs
        catch-up rounds until every frontier reaches ``pos + 1``. Rows
        already caught up re-feed ``t_in`` as a 1-token chunk at ``pos``
        — an idempotent KV rewrite (same token, same prefix) that keeps
        the batch shape static. Only the FINAL round's drafts are used
        (every row is fully ingested by then)."""
        k = self.spec.k
        w = k + 1
        n = self.num_slots
        max_pos = self.max_len - 1
        hists = {i: self._hist(i) for i in active}
        dpos = {}
        for i in active:
            rid, dp = self._dpos.get(i, (None, 0))
            dpos[i] = dp if rid == self.slots[i].rid else 0
        fn = self._compiled(("draft", n, k), self._draft_fn)
        while True:
            chunk = np.zeros((n, w), np.int32)
            clen = np.ones((n,), np.int32)
            start = np.full((n,), max_pos, np.int32)
            final = True
            for i in active:
                pos = self.slots[i].pos
                lag = pos + 1 - dpos[i]
                if lag <= 0:
                    start[i] = pos
                    chunk[i, 0] = hists[i][pos]
                else:
                    take = min(lag, w)
                    start[i] = dpos[i]
                    chunk[i, :take] = hists[i][dpos[i]:dpos[i] + take]
                    clen[i] = take
                    dpos[i] += take
                    if dpos[i] < pos + 1:
                        final = False
            drafts, self._draft_cache = fn(
                self._draft_params, jnp.asarray(chunk), jnp.asarray(clen),
                jnp.asarray(start), self._draft_cache)
            if final:
                break
        for i in active:
            self._dpos[i] = (self.slots[i].rid, dpos[i])
        return np.asarray(drafts)

    def _advance_spec(self, draining: bool) -> None:
        """One speculative iteration: draft k, verify k+1 in ONE rowwise
        program, accept/commit host-side. The pool only ever grows by
        ACCEPTED positions: the verifier writes drafted positions into
        the slot's private scratch rows (spliced into its table past the
        allocated span), and commit copies just the blocks the accepted
        span reaches into allocator-owned blocks — a rejected draft
        triggers no allocation and no copy."""
        k = self.spec.k
        active, _ = self._grow_active(draining, steps_override=1)
        if not active:
            return
        # admitted rows' correction token comes from host history here
        # (toks[i, 0] = hist[pos]); the plain path's fused merge is moot
        self._admit_pending.clear()
        drafts = self._draft_tokens(active)
        width = self._segment_table_width(active, k + 1)
        bt_np = np.full((self.num_slots, width), kvp.SCRATCH_BLOCK,
                        np.int32)
        toks = np.zeros((self.num_slots, k + 1), np.int32)
        pos = np.full((self.num_slots,), self.max_len - 1, np.int32)
        for i in active:
            slot = self.slots[i]
            rb = self._slot_rb[i]
            row = rb.table_row(self.blocks_per_table)[:width].copy()
            # scratch splice: drafted positions past the allocated span
            # land in this slot's private spare rows (never shared, so
            # concurrent in-chunk reads through the table stay private)
            need = min(self.mgr.blocks_needed(slot.pos + k + 1), width)
            for j in range(len(rb.bids), need):
                row[j] = self._scratch[i][j - len(rb.bids)]
            bt_np[i] = row
            toks[i, 0] = self._hist(i)[slot.pos]
            toks[i, 1:] = drafts[i]
            pos[i] = slot.pos
        # no check_span here BY DESIGN: drafted writes intentionally
        # exceed the span into scratch (coverage is by construction);
        # table validity is still enforced
        kvp.validate_tables(bt_np, self.mgr.pool.num_blocks)
        state = self._segment_sample_state(active)
        vf = self._compiled(
            ("specv", self.num_slots, k, width,
             "sampled" if state is not None else "greedy",
             self._plan_key),
            self._verify_fn)
        t0 = self._timer()
        with kops.execution_plan(self.plan):
            tgt, self.mgr.pool.cache = vf(
                self.params, jnp.asarray(toks), self.mgr.pool.cache,
                jnp.asarray(pos), jnp.asarray(bt_np), state)
        if self._queries:
            # searches ran on the I/O worker behind the verify dispatch
            # (the spec path's only dispatch->sync window) — collect
            self._drain_queries(overlapped=True)
        # accept policy is host-side (the Sidebar split: flexible policy
        # on the host, static program on the accelerator) — sync here
        tgt = np.asarray(tgt)
        if self.watchdog.observe(self._timer() - t0):
            self.stats.watchdog_events += 1
        self.stats.segments += 1
        self.stats.spec_steps += 1
        rids = {i: self.slots[i].rid for i in active}
        wasted = (k + 1) * (self.num_slots - len(active))
        now = self._clock()
        for i in sorted(active, key=lambda j: self._score(self.slots[j].req)
                        if self.slots[j].req is not None else ()):
            slot = self.slots[i]
            if slot.free or slot.rid != rids[i]:
                # spilled by a better row's commit growth below — its
                # whole round is discarded; the restore redoes it
                # deterministically, so the stream stays bit-exact
                wasted += k + 1
                continue
            m = accepted_prefix(drafts[i], tgt[i])
            emit = min(m + 1, slot.remaining)
            self.stats.spec_drafted += k
            self.stats.spec_accepted += m
            rb = self._slot_rb[i]
            old_nb = len(rb.bids)
            ok = self.mgr.ensure_span(rb, slot.pos + emit)
            while not ok and self._reclaim_for(
                    self._score(slot.req), exclude_slot=i):
                ok = self.mgr.ensure_span(rb, slot.pos + emit)
            if not ok:
                # pool genuinely can't hold the accepted span: keep what
                # the existing span covers (>= 1 token — growth above
                # secured pos + 1), drop the rest; progress holds
                emit = max(1, min(emit, rb.span - slot.pos))
            new_nb = len(rb.bids)
            if new_nb > old_nb:
                dst = rb.bids[old_nb:new_nb]
                self.mgr.pool.copy_blocks(
                    dst, self._scratch[i][:len(dst)])
                kops.record_dispatch("spec_commit_copy", "dma")
                self.stats.spec_commit_copies += len(dst)
            self.stats.decode_steps += emit
            wasted += (k + 1) - emit
            slot.chunks.append((tgt[i].reshape(1, -1), 0, emit))
            slot.generated += emit
            slot.remaining -= emit
            slot.pos += emit
            if slot.first_t is None:
                slot.first_t = now
                if slot.req is not None:
                    self.stats.record_ttft(slot.req.priority,
                                           now - slot.req.submit_t)
            if slot.remaining == 0:
                self._retire(i)
        self.stats.wasted_steps += wasted
        self._sync_pool_stats()
