"""Continuous batching: slot-based KV cache + segment-synchronous admission.

The PR-2 ``Server`` is a static-batch driver: every ``generate`` call
allocates a fresh KV cache, and a request that finishes early keeps its
batch row busy until the whole batch drains. This module adds the serving
discipline the ROADMAP's "heavy traffic" north star actually needs:

  * **Slot cache** — ONE persistent KV cache with ``num_slots`` batch
    rows, allocated once. Each request owns a slot for its lifetime; a
    freed slot is overwritten wholesale by the next admission (so no
    cross-request state leaks, for attention and recurrent caches alike).
    The batch axis of every cache leaf is *probed*, not assumed: specs
    for batch=2 vs batch=3 are diffed, which keeps the scheduler family-
    agnostic about cache layouts (GQA 5-D KV, MLA latent, int8 scales).
  * **Prompt bucketing + batched admission** — one admission round
    prefills EVERY co-admitted prompt's ``prompt[:-1]`` together, right-
    padded to the round's largest bucket, then runs ONE single-token
    decode of each true last prompt token at its true per-row position
    (the same rowwise-position machinery as segment decode), then
    scatters all rows into the slot cache in one insert. The correction
    step overwrites the first pad's KV slot and returns the first
    generated token from the right logits row, so bucketing never
    changes tokens: pad KV beyond the true length is overwritten by
    later decode writes or masked by the causal ``kpos <= pos``
    attention mask.
  * **Segment decode** — between admissions, ALL occupied slots advance
    ``segment`` tokens in ONE batched scan-compiled dispatch: the serve
    step runs over the whole slot cache with a per-row ``(B,)`` position
    vector threaded down to the attention math (RoPE, causal mask, and
    KV writes all key off each row's own position — see
    ``models.attention.rowwise_pos``). This keeps the matmuls dense over
    slots instead of vmapping into ``num_slots`` batch-1 programs with
    scatter KV writes (the "vmap tax" that made continuous batching lose
    to static batching at smoke scale). When every slot is occupied at
    the SAME position the scheduler dispatches the aligned fast path — a
    scalar-position program whose KV write is one dense
    ``dynamic_update_slice``, exactly like ``serve.make_decode_scan``.
    Requests finish mid-batch without stalling neighbours; their slots
    re-enter the free list at the next segment boundary.
  * **Sampling** — ``submit(..., sample=SamplingParams(...))`` gives a
    request temperature / top-k / top-p decoding. The request's PRNG
    stream is position-keyed (``launch.sampling``): its base key lives
    in the slot state and the token at sequence index p is keyed by
    (base key, p), so admission order, slot churn, segment length, and
    even a scheduler restart mid-stream (resubmit prompt + tokens-so-far
    with the same seed) never change the stream. Greedy and sampled
    requests share one batched segment program: greedy rows carry
    temperature 0, which is exact argmax.
  * **Executable cache** — every compiled program is keyed by
    ``(kind, shape-key, plan)``: repeat traffic (same bucket, same plan)
    never re-traces. ``stats["compiles"]`` / ``stats["hits"]`` make the
    no-retrace property testable.

Scope: families whose decode is batch-row independent and memory-free
(``dense`` — GQA and MLA — and ``moe``). Audio/VLM need per-request
encoder memory threaded through admission; that is an open item. MoE
caveat: pad tokens in a bucketed prefill compete for expert capacity, so
under a dropping ``capacity_factor`` a padded prefill can route real
tokens differently than an exact-length one — serve MoE with a no-drop
capacity factor (or exact-fit buckets) when bit-parity with solo decode
matters.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    LayerPlan,
    coerce_layer_plan,
)
from repro.kernels import ops as kops
from repro.launch import sampling
from repro.launch.sampling import SamplingParams
from repro.launch.serve import (
    PER_LAYER_PLAN_FAMILIES,
    make_prefill_step,
    make_serve_step,
)
from repro.models import layers as L
from repro.models.registry import get_model

Array = jax.Array

# memory-free, batch-row-independent decode — currently the same set
# whose stacks realize per-layer plans, so the constant is shared
_SUPPORTED_FAMILIES = PER_LAYER_PLAN_FAMILIES

DEFAULT_BUCKETS = (16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class FinishedRequest:
    """One drained request: the prompt plus every generated token."""

    rid: int
    prompt: np.ndarray        # (S,) int32 — as submitted
    tokens: np.ndarray        # (generated,) int32
    prompt_len: int
    generated: int


@dataclasses.dataclass
class _Slot:
    rid: int | None = None
    pos: int = 0              # next KV write position (= current length)
    remaining: int = 0
    generated: int = 0        # tokens produced so far (host-side count)
    # generated tokens as (device_array, row, take) chunk handles — the
    # async drain loop never syncs token VALUES; chunks materialize to
    # numpy only when a request is handed back (see _materialize)
    chunks: list[tuple] = dataclasses.field(default_factory=list)
    prompt: np.ndarray | None = None
    sample: SamplingParams | None = None
    # the request's PRNG base key ((2,) uint32): position-keyed at use,
    # so the stream survives slot churn and scheduler restarts
    key: np.ndarray | None = None

    @property
    def free(self) -> bool:
        return self.rid is None


def probe_batch_axes(api, cfg: ModelConfig, minfo, max_len: int):
    """Which axis of each cache leaf is the batch (slot) axis?

    Diff the spec shapes for batch=2 vs batch=3 — the axis whose size
    changed is the batch axis. Works for every cache layout without
    hardcoding family knowledge.
    """
    s2 = api.cache_specs(cfg, minfo, 2, max_len)
    s3 = api.cache_specs(cfg, minfo, 3, max_len)

    def axis(a, b) -> int:
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(
            f"cache leaf {a.shape} has no batch axis; the slot scheduler "
            "cannot place requests into it"
        )

    return jax.tree.map(axis, s2, s3, is_leaf=L.is_spec)


class ContinuousBatchingServer:
    """Slot-based continuous batching with batched segment decode.

    >>> srv = ContinuousBatchingServer(cfg, params, num_slots=4)
    >>> srv.submit([1, 2, 3], max_new_tokens=16)
    >>> srv.submit([4, 5], 16, sample=SamplingParams(temperature=0.8))
    >>> done = srv.run()          # drain pending + active
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 num_slots: int = 4, max_len: int = 256,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 segment: int = 8, admit_batch: int = 2,
                 plan: LayerPlan | ExecutionPlan | ExecutionMode | str |
                 None = None) -> None:
        if cfg.family not in _SUPPORTED_FAMILIES:
            raise ValueError(
                f"continuous batching supports families {_SUPPORTED_FAMILIES}"
                f", got {cfg.family!r} (encoder-memory families need "
                "per-request memory plumbing — see module docstring)"
            )
        if plan is None:
            plan = ExecutionMode.SIDEBAR
        if isinstance(plan, ExecutionPlan):
            if not plan.is_uniform:
                cfg = dataclasses.replace(cfg, scan_layers=False)
            self._plan_key: Any = plan.cache_key()
        else:
            plan = coerce_layer_plan(plan)
            self._plan_key = plan
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.mesh = mesh
        self.minfo = (
            L.MeshInfo.from_axes(tuple(mesh.axis_names)) if mesh else L.HOST
        )
        self.api = get_model(cfg)
        if not self.api.rowwise_decode_pos:
            raise ValueError(
                f"family {cfg.family!r} decode_step takes scalar positions "
                "only; batched segment decode needs per-row (B,) positions "
                "(ModelApi.rowwise_decode_pos)"
            )
        self.num_slots = num_slots
        self.max_len = max_len
        # a bucket longer than the KV cache could never be prefilled into
        # it; submit() bounds every prompt to max_len, so exact-fit covers
        # whatever the dropped buckets would have
        self.buckets = tuple(sorted(b for b in buckets if b <= max_len))
        self.segment = segment
        self.admit_batch = max(1, min(admit_batch, num_slots))
        self.axes = probe_batch_axes(self.api, cfg, self.minfo, max_len)
        # THE slot cache: allocated once, lives as long as the server.
        self.cache = self.api.init_cache(cfg, self.minfo, num_slots, max_len)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.pending: collections.deque = collections.deque()
        self.finished: list[FinishedRequest] = []
        self._next_rid = 0
        self._exec: dict[tuple, Callable] = {}
        # the running token of every slot, device-side (N, 1): written
        # ONLY by program outputs (segment final carry / admission
        # correction scatter), so the drain loop never blocks on it
        self._toks = jnp.zeros((num_slots, 1), jnp.int32)
        self._done_raw: list[tuple] = []   # retired, not yet materialized
        self._deferred = False             # admission hysteresis armed
        self.stats = {"compiles": 0, "hits": 0, "admitted": 0,
                      "segments": 0, "decode_steps": 0, "wasted_steps": 0,
                      "admit_deferrals": 0}

    # -- executable cache --------------------------------------------------
    def _compiled(self, key: tuple, builder: Callable[[], Callable]):
        """(kind, shape-key..., plan) -> compiled program. Repeat traffic
        hits the cache; a new bucket or plan is a recorded compile."""
        fn = self._exec.get(key)
        if fn is None:
            fn = self._exec[key] = builder()
            self.stats["compiles"] += 1
        else:
            self.stats["hits"] += 1
        return fn

    def executable_cache_keys(self) -> list[tuple]:
        return sorted(self._exec, key=repr)

    # -- submission --------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (prefill length); exact fit past the end."""
        for b in self.buckets:
            if b >= n:
                return b
        return n

    def submit(self, prompt, max_new_tokens: int,
               sample: SamplingParams | None = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt {prompt.size} + max_new {max_new_tokens} exceeds "
                f"max_len {self.max_len}"
            )
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append((rid, prompt, max_new_tokens, sample))
        return rid

    # -- admission ---------------------------------------------------------
    def _admit_fn(self, *, with_prefill: bool) -> Callable:
        """ONE compiled program for a whole admission round, in place on
        the slot cache: gather the freed rows (probed batch axes),
        right-padded batched prefill of every co-admitted ``prompt[:-1]``
        (skipped when all prompts are single tokens), the per-row-
        position correction step, and the scatter back. The gathered
        rows still hold retired requests' KV — stale state is
        overwritten by the prefill/decode writes or masked by the causal
        ``kpos <= pos`` read before it is ever visible (the same
        argument as prompt bucketing)."""
        prefill_step = make_prefill_step(self.cfg, self.api, self.minfo,
                                         self.mesh)
        serve_step = make_serve_step(self.cfg, self.api, self.minfo,
                                     self.mesh)
        axes = self.axes

        def admit(params, padded, full, prev_toks, toks, pos, slots,
                  sample=None):
            rows = jax.tree.map(
                lambda f, ax: jnp.take(f, slots, axis=ax), full, axes)
            if with_prefill:
                _, rows = prefill_step(params, {"tokens": padded}, rows)
            nxt, rows = serve_step(params, toks, rows, pos, None, sample)
            # single-advanced-index scatter: the axis keeps its position
            full = jax.tree.map(
                lambda f, o, ax: f.at[(slice(None),) * ax + (slots,)].set(
                    o.astype(f.dtype)),
                full, rows, axes,
            )
            # merge the correction tokens into the running (N, 1) token
            # vector so the next segment feeds them without a host sync
            prev_toks = prev_toks.at[slots].set(nxt)
            return nxt, prev_toks, full

        return jax.jit(admit, donate_argnums=(2, 3))

    def _admit_batch(self, slot_idxs: list[int], reqs: list[tuple]) -> None:
        """Admit ``k`` requests in ONE dispatch: gather the freed slot
        rows, right-padded batched prefill (to the largest needed
        bucket), the correction step at per-row true positions (the same
        rowwise-position machinery as segment decode), and the scatter
        back — all fused into one compiled program per admission ROUND
        instead of three dispatches per request.

        Padding is still invisible in tokens: each row's pad KV beyond
        its true length is overwritten by the correction step / later
        decode writes or masked by the causal ``kpos <= pos`` attention
        mask before it is ever read. (MoE caveat: co-admitted rows share
        expert capacity in the batched prefill — as with bucket padding,
        serve MoE with a no-drop capacity factor for bit-parity.)
        """
        k = len(reqs)
        s_true = np.asarray([p.size for _, p, _, _ in reqs], np.int32)
        need = int(s_true.max()) - 1
        bucket = self.bucket_for(need) if need > 0 else 0
        padded = None
        if bucket:
            buf = np.zeros((k, bucket), np.int32)
            for j, (_, p, _, _) in enumerate(reqs):
                buf[j, : p.size - 1] = p[:-1]
            padded = jnp.asarray(buf)
        # prefill + correction fused into ONE program: each row's true
        # last prompt token decodes at its true per-row position,
        # overwriting the first pad's KV and yielding the first new token
        # from the right logits row. A sampled request samples it with
        # key (base, s_true) — exactly the key a solo Server.generate
        # folds for its first new token.
        keys = [None if sp is None else np.asarray(
            sampling.request_key(sp.seed)) for _, _, _, sp in reqs]
        sampled = any(sp is not None for _, _, _, sp in reqs)
        zero = np.zeros((2,), np.uint32)
        state = sampling.merge_rows(
            [(zero if key is None else key, sp)
             for key, (_, _, _, sp) in zip(keys, reqs)]) if sampled else None
        admit = self._compiled(
            ("prefill", k, bucket, self._plan_key,
             "sampled" if sampled else "greedy"),
            lambda: self._admit_fn(with_prefill=bool(bucket)),
        )
        toks = np.asarray([[p[-1]] for _, p, _, _ in reqs], np.int32)
        nxt, self._toks, self.cache = admit(
            self.params, padded, self.cache, self._toks, jnp.asarray(toks),
            jnp.asarray(s_true - 1), jnp.asarray(slot_idxs, jnp.int32),
            state,
        )
        for j, slot_idx in enumerate(slot_idxs):
            rid, prompt, max_new, sample = reqs[j]
            slot = self.slots[slot_idx]
            slot.rid = rid
            slot.pos = int(s_true[j])
            slot.remaining = max_new - 1
            slot.generated = 1
            slot.chunks = [(nxt, j, 1)]
            slot.prompt = prompt
            slot.sample = sample
            slot.key = keys[j]
            self.stats["admitted"] += 1
            if slot.remaining == 0:
                self._retire(slot_idx)

    def _retire(self, slot_idx: int) -> None:
        slot = self.slots[slot_idx]
        self._done_raw.append((slot.rid, slot.prompt, slot.chunks,
                               slot.generated))
        self.slots[slot_idx] = _Slot()

    @staticmethod
    def _chunks_to_np(chunks: list[tuple], fetched: dict) -> np.ndarray:
        """Host tokens from (device_array, row, take) handles — the one
        place the async pipeline blocks. ``fetched`` memoizes whole-
        array transfers (many chunks share one segment buffer)."""
        parts = []
        for arr, row, take in chunks:
            host = fetched.get(id(arr))
            if host is None:
                host = fetched[id(arr)] = np.asarray(arr)
            parts.append(host[row, :take])
        return np.concatenate(parts).astype(np.int32)

    def slot_tokens(self, slot_idx: int) -> np.ndarray:
        """Tokens generated so far by the request in ``slot_idx`` (syncs
        that slot's chunks; used for mid-stream inspection/restart)."""
        return self._chunks_to_np(self.slots[slot_idx].chunks, {})

    def _materialize(self) -> list[FinishedRequest]:
        """Convert retired-but-raw requests into FinishedRequests."""
        if not self._done_raw:
            return []
        fetched: dict = {}
        out = []
        for rid, prompt, chunks, generated in self._done_raw:
            tokens = self._chunks_to_np(chunks, fetched)
            assert tokens.size == generated
            out.append(FinishedRequest(
                rid=rid, prompt=prompt, tokens=tokens,
                prompt_len=int(prompt.size), generated=generated,
            ))
        self._done_raw.clear()
        self.finished.extend(out)
        return out

    def admit(self) -> int:
        """Fill free slots from the pending queue (one batched admission
        round); returns #admitted.

        Admission hysteresis: with a backlog and other slots still
        decoding, wait until ``admit_batch`` slots are free before
        admitting — a batch-1 prefill GEMM is several times less
        efficient than a batched one, and a short wait for a second free
        slot costs less than it saves (knob: ``admit_batch=1`` restores
        eager admission). The wait times out after ONE deferred
        boundary: ``_segment_steps`` caps the next segment at
        ``self.segment`` while a deferral is pending, and the boundary
        after that admits whatever is free — a held-open slot never
        idles longer than ``segment`` steps behind a long-running
        neighbour.
        """
        free = [i for i, slot in enumerate(self.slots) if slot.free]
        take = min(len(free), len(self.pending))
        if take == 0:
            self._deferred = False
            return 0
        threshold = min(self.admit_batch, len(self.pending))
        if (take < threshold and len(free) < self.num_slots
                and not self._deferred):
            self._deferred = True
            self.stats["admit_deferrals"] += 1
            return 0
        self._deferred = False
        reqs = [self.pending.popleft() for _ in range(take)]
        with kops.execution_plan(self.plan):
            self._admit_batch(free[:take], reqs)
        return take

    # -- segment decode ----------------------------------------------------
    def _segment_fn(self, num_steps: int) -> Callable:
        """All slots advance ``num_steps`` tokens in one compiled program:
        ONE batched ``make_serve_step`` over the whole slot cache,
        scanned over steps with the cache in the (donated) carry and the
        output buffer written via ``dynamic_update_slice``. ``pos`` is a
        per-row ``(N,)`` vector (unaligned slots: the attention layer
        scatters each row's KV write to its own position) or a scalar
        (every slot at the same position: dense-slab KV writes, the same
        program shape as ``serve.make_decode_scan``). Either way the
        matmuls stay dense over slots — no per-slot vmap into batch-1
        programs.
        """
        step = make_serve_step(self.cfg, self.api, self.minfo, self.mesh)
        max_pos = self.max_len - 1

        def segment(params, toks, cache, pos, sample=None):
            # toks (N, 1); pos (N,) or scalar; cache = the full slot
            # cache. Finished/free slots idle at a clamped position:
            # their writes land on a dead row and are overwritten
            # wholesale at the next admission.
            buf = jnp.zeros((toks.shape[0], num_steps), jnp.int32)

            def body(carry, i):
                tok, cache, buf = carry
                p = jnp.minimum(pos + i, max_pos)
                nxt, cache = step(params, tok, cache, p, None, sample)
                buf = jax.lax.dynamic_update_slice(buf, nxt, (0, i))
                return (nxt, cache, buf), None

            (last, cache, buf), _ = jax.lax.scan(
                body, (toks, cache, buf),
                jnp.arange(num_steps, dtype=jnp.int32),
            )
            # the final carry token feeds the next segment directly —
            # the drain loop never syncs token values (async dispatch)
            return buf, last, cache

        # params as an ARGUMENT (not a closure constant): the cached
        # executable never bakes weights into its jaxpr, and a params
        # swap on a live server takes effect on the next segment.
        return jax.jit(segment, donate_argnums=(2,))

    def _segment_sample_state(self, active: list[int]) -> dict | None:
        """Per-row traced sampling state for one segment, or ``None``
        when every active slot decodes greedily (keeps the pure-greedy
        segment program free of sampling math). Greedy slots inside a
        mixed batch ride along as temperature-0 rows — exact argmax."""
        if not any(self.slots[i].sample is not None for i in active):
            return None
        zero = np.zeros((2,), np.uint32)
        rows = []
        for slot in self.slots:
            if slot.free or slot.sample is None:
                rows.append((zero, None))
            else:
                rows.append((slot.key, slot.sample))
        return sampling.merge_rows(rows)

    def _segment_steps(self, active: list[int], *,
                       draining: bool = False) -> int:
        """How many tokens this segment decodes — shrink-to-fit.

        The segment ends exactly when the earliest active slot finishes
        (``min remaining``): running past it wastes slot-steps, and with
        EVERY slot busy a boundary before it is pure dispatch overhead —
        admission needs a free slot, and only a retirement frees one, so
        nothing can enter earlier (holds for live submits too). Whenever
        entry IS possible at the boundary — a free slot exists and a
        live submit could arrive (``step()``-driven serving; inside a
        blocking ``run()`` drain nothing can be submitted, so the cap
        would be pure dispatch overhead on the tail) or an admission
        deferral is armed (the hysteresis must time out) — the length is
        capped at ``self.segment``, the admission-latency knob. Above
        ``self.segment`` the length rounds down to a power of two, so
        long stretches cost O(log) dispatches while the distinct
        compiled segment variants stay bounded (``segment`` exact
        lengths + log2(max_len) strides).
        """
        min_rem = min(self.slots[i].remaining for i in active)
        entry_possible = self._deferred or (
            not draining and any(s.free for s in self.slots))
        if entry_possible:
            return min(min_rem, self.segment)
        if min_rem <= self.segment:
            return min_rem
        return 1 << (min_rem.bit_length() - 1)

    def _advance(self, *, draining: bool = False) -> None:
        """One scheduler iteration, fully async: admit into free slots,
        then enqueue one segment over all active slots. All decisions
        (segment length, alignment, retirement) derive from host-side
        COUNTS; token values stay on device — the admission program
        merges its correction tokens into the running token vector and
        the segment program returns its final carry, so dispatches
        pipeline without a single host round-trip. ``draining`` marks a
        blocking ``run()`` loop, where no live submit can arrive."""
        self.admit()
        active = [i for i, s in enumerate(self.slots)
                  if not s.free and s.remaining > 0]
        if not active:
            return
        steps = self._segment_steps(active, draining=draining)
        pos = np.full((self.num_slots,), self.max_len - 1, np.int32)
        for i in active:
            pos[i] = self.slots[i].pos
        # aligned fast path: every slot occupied at the same position
        # -> scalar-pos program (dense dynamic_update_slice KV writes)
        aligned = (len(active) == self.num_slots
                   and len({self.slots[i].pos for i in active}) == 1)
        state = self._segment_sample_state(active)
        seg = self._compiled(
            ("segment", self.num_slots, steps,
             "aligned" if aligned else "ragged",
             "sampled" if state is not None else "greedy",
             self._plan_key),
            lambda: self._segment_fn(steps),
        )
        pos_arg = (jnp.int32(self.slots[active[0]].pos) if aligned
                   else jnp.asarray(pos))
        with kops.execution_plan(self.plan):
            buf, self._toks, self.cache = seg(
                self.params, self._toks, self.cache, pos_arg, state)
        self.stats["segments"] += 1
        self.stats["decode_steps"] += steps * len(active)
        # shrink-to-fit guarantees steps <= every active slot's remaining
        # (no active slot overshoots); the waste that remains is the
        # free/dead rows the batched program decodes alongside them
        self.stats["wasted_steps"] += steps * (self.num_slots - len(active))
        for i in active:
            slot = self.slots[i]
            take = min(steps, slot.remaining)
            slot.chunks.append((buf, i, take))
            slot.generated += take
            slot.remaining -= take
            slot.pos += take
            if slot.remaining == 0:
                self._retire(i)

    def step(self) -> list[FinishedRequest]:
        """Admit into free slots, then decode one segment on all active
        slots; returns requests that finished this step (synced)."""
        self._advance()
        return self._materialize()

    def run(self) -> list[FinishedRequest]:
        """Drain every pending + active request; returns all finished
        requests (ordered by rid). The whole drain is enqueued without
        host syncs; tokens are fetched once at the end."""
        while self.pending or any(not s.free for s in self.slots):
            self._advance(draining=True)
        self._materialize()
        out, self.finished = self.finished, []
        return sorted(out, key=lambda r: r.rid)
