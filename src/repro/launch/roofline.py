"""Roofline extraction from compiled artifacts (no real hardware).

Per (arch x shape x mesh) cell:

  compute    = HLO_FLOPs          / (chips * peak)       [s]
  memory     = HLO_bytes_accessed / (chips * hbm_bw)     [s]
  collective = collective_bytes   / (chips * ici_bw)     [s]

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the post-SPMD HLO text (``compiled.as_text()``) by summing
the result-shape bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute. Post-SPMD shapes are per-device, so
the sum is already bytes *per chip*; ring-algorithm constants (~2x for
all-reduce) are folded into an ``ALGO_FACTOR`` per op kind.

MODEL_FLOPS (the useful-compute yardstick):
  train:   6 * N_active * tokens
  prefill: 2 * N_active * tokens  (+ attention term, reported separately)
  decode:  2 * N_active * new_tokens
"""

from __future__ import annotations

import dataclasses
import re

from repro.core import constants

# bytes per element for HLO type strings
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ring-algorithm wire multiplier per result byte
ALGO_FACTOR = {
    "all-gather": 1.0,        # result is the gathered (full) buffer
    "all-reduce": 2.0,        # reduce-scatter + all-gather ring
    "reduce-scatter": 1.0,    # input is the big buffer; result is shard
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind result bytes (per device) x algo factor."""
    out: dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        out[kind] += _shape_bytes(type_str) * ALGO_FACTOR[kind]
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: dict
    chips: int
    # seconds
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs (global)
    roofline_s: float            # max of the three terms
    bytes_per_device: dict       # memory_analysis summary

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def analyze(compiled, *, chips: int, model_flops: float,
            chip: constants.ChipSpec = constants.V5E) -> RooflineTerms:
    cost = compiled.cost_analysis()
    # cost_analysis is per-device program flops; multiply by chips for global
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    coll_total = float(sum(coll.values()))

    t_compute = flops_dev / chip.peak_flops
    t_memory = bytes_dev / chip.hbm_bytes_per_s
    t_collective = coll_total / chip.ici_bytes_per_s
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bottleneck = max(terms, key=terms.get)

    mem = compiled.memory_analysis()
    bytes_per_device = {
        "argument": getattr(mem, "argument_size_in_bytes", 0),
        "output": getattr(mem, "output_size_in_bytes", 0),
        "temp": getattr(mem, "temp_size_in_bytes", 0),
        "alias": getattr(mem, "alias_size_in_bytes", 0),
        "code": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    global_flops = flops_dev * chips
    return RooflineTerms(
        flops=flops_dev,
        bytes_accessed=bytes_dev,
        coll_bytes=coll_total,
        coll_breakdown=coll,
        chips=chips,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_collective,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops else 0.0,
        roofline_s=max(terms.values()),
        bytes_per_device=bytes_per_device,
    )


# ---------------------------------------------------------------------------
# Tensor-parallel serving: analytic per-step collective model.
# ---------------------------------------------------------------------------

def tp_step_collectives(cfg, *, batch: int, tp: int, seq: int = 1,
                        steps: int = 1) -> dict[str, float]:
    """Modeled per-device collective bytes for ``steps`` iterations of
    the tensor-parallel serve/segment step (``launch.serve``'s shard_map
    program), with the same accounting conventions as
    ``collective_bytes``/``hlo_analysis.analyze_hlo`` (result-shape
    bytes per device x ``ALGO_FACTOR``), so model and measurement
    subtract to ~0 on the compiled HLO.

    Per decode step the Megatron partition emits exactly:

      * one fp32 all-reduce of the (B, S, D) embedding partial — the
        vocab-row-sharded lookup accumulates in fp32 before the cast,
        keeping the (1,1)-mesh path bit-exact;
      * per layer, TWO activation-dtype all-reduces of (B, S, D): the
        attention output projection's row-parallel partial and the
        MLP / MoE down-projection's (MoE folds routed + shared expert
        partials into ONE psum);
      * one fp32 all-gather assembling the (B, S, V_padded) logits from
        the vocab-column-sharded unembed (result bytes = the full
        gathered buffer, as the parsers count them).

    The KV cache never moves: heads are model-sharded, so paged reads /
    writes (and the Pallas kernel's table walks) are shard-local. At
    ``tp <= 1`` every collective degenerates to identity and the model
    returns zeros.
    """
    import jax.numpy as jnp

    from repro.models.layers import padded_vocab

    out = {k: 0.0 for k in _COLL_KINDS}
    if tp <= 1:
        return out
    act_bytes = jnp.dtype(cfg.dtype).itemsize
    tok = batch * seq
    ar = tok * cfg.d_model * 4                      # embed partial, fp32
    ar += cfg.num_layers * 2 * tok * cfg.d_model * act_bytes
    ag = tok * padded_vocab(cfg.vocab_size) * 4     # gathered logits, fp32
    out["all-reduce"] = ar * ALGO_FACTOR["all-reduce"] * steps
    out["all-gather"] = ag * ALGO_FACTOR["all-gather"] * steps
    return out


# ---------------------------------------------------------------------------
# MODEL_FLOPS helpers.
# ---------------------------------------------------------------------------

def count_params(spec_tree) -> tuple[int, int, int]:
    """(total, embedding, routed_expert) parameter counts from specs."""
    import math

    import jax

    from repro.models.layers import is_spec

    total = emb = routed = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=is_spec
    )[0]:
        n = math.prod(spec.shape)
        keys = [str(p) for p in path]
        total += n
        if any("embed" in k for k in keys):
            emb += n
        in_moe = any("'moe'" in k or '"moe"' in k or "moe" == k.strip("'[]\"")
                     for k in keys)
        is_expert_w = any(k.strip("[]'\"") in ("w_gate", "w_up", "w_down")
                          for k in keys)
        is_shared = any(k.strip("[]'\"") == "shared" for k in keys)
        if in_moe and is_expert_w and not is_shared:
            routed += n
    return total, emb, routed


def model_flops(cfg, cell, spec_tree) -> float:
    total, emb, routed = count_params(spec_tree)
    n = total - emb
    if cfg.num_experts:
        n_active = n - routed * (1.0 - cfg.experts_per_token / cfg.num_experts)
    else:
        n_active = n
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    factor = 6.0 if cell.kind == "train" else 2.0
    return factor * n_active * tokens
