"""Speculative decoding: host-side draft/accept policy, static programs.

This module is the Sidebar thesis applied at the serving level. The
fast-evolving part of speculative decoding — which draft model to run,
how many tokens to gamble, when to accept, how to roll back — is a HOST
policy that changes every time someone has a better idea. The expensive
part — the target model scoring K+1 positions — is one static batched
accelerator program. So the split mirrors the paper's scratchpad
protocol: the accelerator keeps two hot executables (the draft program
and the verifier), and everything speculative about speculative decoding
lives in plain Python between dispatches:

  * **Draft.** A small model (its own dense slot cache — it never takes
    pool blocks) greedily proposes K tokens per active row in one
    combined program: a W-wide rowwise prefill ingests the tokens the
    target committed since the draft's frontier, then a K-1 step scan
    extends greedily. One dispatch per scheduler iteration in steady
    state (the commit of step N is at most K+1 tokens, which is <= W).
  * **Verify.** The target runs ``launch.serve.make_verify_step`` — the
    PR-5 multi-token rowwise prefill through block tables with
    ``all_logits=True`` — writing the K drafted positions into per-slot
    SCRATCH blocks spliced into the table by the scheduler, and
    returning its own (position-key sampled) token at all K+1 positions.
  * **Accept / rollback.** Pure host arithmetic: the accepted prefix is
    the longest run of drafts that equal the target's tokens, the row
    emits ``m+1`` tokens (the target's correction rides for free, so
    every step makes progress), and rollback is just *not copying* the
    rejected scratch blocks — rejected tokens never touch the pool and
    never appear in allocator counters.

Bit-exactness is the contract, not a hope: the verifier samples each
position with the same position-keyed PRNG rule plain decode uses, and a
draft is "accepted" exactly when it guessed what plain decode would have
emitted — so the OUTPUT stream (greedy and sampled alike) is
token-identical to non-speculative decode, regardless of the draft
model's quality. A worthless draft only costs throughput.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.registry import ModelApi, get_model

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding policy for a paged continuous-batching server.

    ``k`` is the number of tokens drafted (and verified) per row per
    scheduler iteration; ``k == 0`` disables speculation (the server
    degenerates to plain segment decode — bit-identical, same
    executables). ``draft_cfg``/``draft_params`` are the draft model;
    passing the TARGET's own config and params is the "oracle draft"
    (acceptance 1.0 under greedy — useful for smoke tests and for
    benching pure verifier overhead). ``validate(cfg)`` raises
    ``ValueError`` against a target config when the pairing can't be
    bit-exact: mismatched vocab (token ids wouldn't be shared) or a
    draft family without the rowwise multi-token prefill the combined
    draft program needs.
    """

    draft_cfg: ModelConfig
    draft_params: Any
    k: int = 4

    def __post_init__(self) -> None:
        if self.k < 0:
            raise ValueError(f"spec k must be >= 0, got {self.k}")

    def validate(self, cfg: ModelConfig) -> None:
        from repro.launch.serve import PER_LAYER_PLAN_FAMILIES

        if self.draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft vocab_size {self.draft_cfg.vocab_size} != target "
                f"vocab_size {cfg.vocab_size}: draft and target must share "
                "token ids"
            )
        if self.draft_cfg.family not in PER_LAYER_PLAN_FAMILIES:
            raise ValueError(
                f"draft family {self.draft_cfg.family!r} does not support "
                "the rowwise multi-token prefill the draft program needs "
                f"(supported: {PER_LAYER_PLAN_FAMILIES})"
            )

    def draft_api(self) -> ModelApi:
        return get_model(self.draft_cfg)


def make_draft_program(cfg: ModelConfig, api: ModelApi, k: int,
                       max_len: int):
    """Build the combined ingest-and-draft program (one dispatch/step).

    ``draft(params, chunk (B, W), chunk_len (B,), start (B,), cache) ->
    (drafts (B, k), cache)`` with ``W = k + 1``. Per row: a rowwise
    prefill writes ``chunk[:chunk_len]`` into the draft's dense slot
    cache at positions ``start .. start+chunk_len-1`` (the tokens the
    target committed since this row's draft frontier), the logits at the
    chunk's last real token give draft #1 by argmax, and a ``k-1`` step
    greedy scan extends from there. Greedy drafting is deliberate even
    for sampled rows — the draft is only a GUESS at the target's
    position-keyed sample; guessing the mode maximizes acceptance
    without touching the output distribution (acceptance compares
    against the target's own sampled token).

    Junk-write safety: pad positions beyond ``chunk_len`` and scan
    positions past a short row's frontier write garbage KV *ahead* of
    that row's frontier — every such position is either re-ingested
    (contiguous catch-up overwrites it before the row's frontier
    reaches it) or at the clamped index ``max_len - 1``, which no valid
    stream ever writes (the last emitted token is never fed back), so
    garbage there is dead by the ``kpos <= pos`` attention mask.
    """
    w = k + 1
    max_pos = max_len - 1

    def draft_fn(params, chunk, chunk_len, start, cache):
        logits, cache = api.prefill(
            params, cfg, {"tokens": chunk}, cache, minfo=L.HOST, mesh=None,
            cache_pos=start, all_logits=True,
        )
        logits = L.mask_pad_logits(logits, cfg.vocab_size)
        idx = jnp.clip(chunk_len - 1, 0, w - 1)
        last = jnp.take_along_axis(
            logits, idx[:, None, None], axis=1)[:, 0, :]
        d0 = jnp.argmax(last, axis=-1).astype(jnp.int32)
        if k == 0:
            return jnp.zeros((chunk.shape[0], 0), jnp.int32), cache
        if k == 1:
            return d0[:, None], cache
        pos0 = start + chunk_len

        def body(carry, i):
            tok, cache = carry
            p = jnp.minimum(pos0 + i, max_pos)
            lg, cache = api.decode_step(
                params, cfg, tok[:, None], cache, p, minfo=L.HOST,
                mesh=None,
            )
            lg = L.mask_pad_logits(lg, cfg.vocab_size)
            nxt = jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)
            return (nxt, cache), nxt

        (_, cache), rest = jax.lax.scan(
            body, (d0, cache), jnp.arange(k - 1, dtype=jnp.int32))
        drafts = jnp.concatenate([d0[:, None], rest.T], axis=1)
        return drafts, cache

    return draft_fn


def accepted_prefix(drafts: np.ndarray, target: np.ndarray) -> int:
    """Length of the accepted draft prefix for one row.

    ``drafts`` (k,) vs ``target`` (k+1,): draft i is accepted iff it
    equals the token the target model itself emitted at that position
    AND every earlier draft was accepted (a later "match" after a miss
    is meaningless — the target's logits there were conditioned on the
    rejected token). The row then emits ``target[:m+1]``: the m accepted
    tokens re-derived from the target plus its correction/bonus token,
    which is why even a full rejection makes one token of progress.
    """
    m = 0
    k = len(drafts)
    while m < k and drafts[m] == target[m]:
        m += 1
    return m
