"""ShapeDtypeStruct stand-ins for every model input (dry-run, no alloc).

``input_specs(cfg, cell)`` — the data batch for one step at the cell's
global shape. ``batch_shardings(...)`` — the matching NamedSharding tree
(batch dim over the mesh batch axes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.layers import MeshInfo


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "decode":
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs = {"tokens": tok}
    if cell.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.dtype
        )
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), cfg.dtype
        )
    return specs


def batch_shardings(cfg: ModelConfig, cell: ShapeCell, mesh,
                    minfo: MeshInfo) -> dict:
    from repro.models.layers import sanitize_pspec

    batch_axes = tuple(minfo.fsdp) or None

    def shard(spec):
        pspec = P(batch_axes, *([None] * (len(spec.shape) - 1)))
        return NamedSharding(mesh, sanitize_pspec(mesh, pspec, spec.shape))

    return {k: shard(v) for k, v in input_specs(cfg, cell).items()}
