"""Serving: batched prefill + decode loop.

``make_serve_step`` builds the jit-able single-token decode (the function
the decode_32k / long_500k dry-run cells lower); ``Server`` is a small
batched-request driver (pad-to-bucket, prefill once, greedy decode) used
by the serving example and integration tests.

``Server(plan=...)`` selects which sidebar kernel variant backs the
model's fused MLP ops: ``ExecutionMode.SIDEBAR`` (single VMEM scratch) or
``ExecutionMode.SIDEBAR_PIPELINED`` (T-deep VMEM ring — the host-side
flexible function of tile t overlaps the MXU work of up to T-1 in-flight
neighbours; the ring depth comes from the plan). The plan may be a
``LayerPlan``, a whole ``ExecutionPlan`` (its default layer plan is used
at trace time — kernels are layer-agnostic), an ``ExecutionMode``, or a
mode string; ``execution_mode=`` remains as the PR-1 spelling. The choice
is applied as ambient state around trace time, so the same model code
serves under any variant with no signature changes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    LayerPlan,
    coerce_layer_plan,
)
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.registry import ModelApi, get_model

Array = jax.Array


def make_serve_step(cfg: ModelConfig, api: ModelApi, minfo: L.MeshInfo, mesh):
    """decode one token: (params, tokens(B,1), cache, pos[, memory])."""

    from repro.parallel.hints import sharding_hints

    def serve_step(params, tokens, cache, pos, memory=None):
        with sharding_hints(mesh, minfo):
            logits, cache = api.decode_step(
                params, cfg, tokens, cache, pos, minfo=minfo, mesh=mesh,
                memory=memory,
            )
        logits = L.mask_pad_logits(logits, cfg.vocab_size)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, api: ModelApi, minfo: L.MeshInfo, mesh):
    from repro.parallel.hints import sharding_hints

    def prefill_step(params, batch, cache):
        with sharding_hints(mesh, minfo):
            logits, cache = api.prefill(
                params, cfg, batch, cache, minfo=minfo, mesh=mesh
            )
        logits = L.mask_pad_logits(logits, cfg.vocab_size)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    return prefill_step


@dataclasses.dataclass
class ServeResult:
    tokens: Any           # (B, prompt+generated)
    prompt_len: int
    generated: int


class Server:
    """Minimal batched greedy-decoding server."""

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 max_len: int = 256,
                 execution_mode: ExecutionMode | str | None = None,
                 plan: LayerPlan | ExecutionPlan | ExecutionMode | str |
                 None = None,
                 ) -> None:
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.mesh = mesh
        self.minfo = (
            L.MeshInfo.from_axes(tuple(mesh.axis_names)) if mesh else L.HOST
        )
        self.max_len = max_len
        if plan is not None and execution_mode is not None:
            raise ValueError("pass either plan= or execution_mode=, not both")
        if plan is None:
            plan = (ExecutionMode.SIDEBAR if execution_mode is None
                    else execution_mode)
        plan = coerce_layer_plan(plan)
        if plan.mode not in (
            ExecutionMode.SIDEBAR, ExecutionMode.SIDEBAR_PIPELINED
        ):
            raise ValueError(
                "Server serves through the sidebar fast path; "
                "the plan's mode must be SIDEBAR or SIDEBAR_PIPELINED, got "
                f"{plan.mode}"
            )
        self.plan = plan
        self.execution_mode = plan.mode
        self._prefill = jax.jit(
            make_prefill_step(cfg, self.api, self.minfo, mesh)
        )
        self._decode = jax.jit(
            make_serve_step(cfg, self.api, self.minfo, mesh),
            donate_argnums=(2,),
        )

    def generate(self, prompts: Array, num_tokens: int,
                 extra: dict | None = None) -> ServeResult:
        """prompts: (B, S) int32 — one bucket; greedy decode num_tokens."""
        b, s = prompts.shape
        if s + num_tokens > self.max_len:
            raise ValueError(
                f"prompt {s} + generate {num_tokens} exceeds max_len "
                f"{self.max_len}"
            )
        cache = self.api.init_cache(self.cfg, self.minfo, b, self.max_len)
        batch = {"tokens": prompts, **(extra or {})}
        # ambient kernel-variant selection must wrap trace time (the first
        # _prefill/_decode call below traces the model through kops)
        with kops.execution_plan(self.plan):
            memory = None
            if self.cfg.family == "audio":
                from repro.models import whisper as W

                memory = W.encode(self.params, self.cfg, batch["frames"])
            if self.cfg.family == "vlm":
                memory = batch.get("image_embeds")
            nxt, cache = self._prefill(self.params, batch, cache)
            out = [prompts, nxt]
            pos = s
            for _ in range(num_tokens - 1):
                nxt, cache = self._decode(
                    self.params, nxt, cache, jnp.int32(pos), memory
                )
                out.append(nxt)
                pos += 1
        return ServeResult(
            tokens=jnp.concatenate(out, axis=1), prompt_len=s,
            generated=num_tokens,
        )
