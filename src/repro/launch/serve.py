"""Serving: batched prefill + scan-compiled decode.

``make_serve_step`` builds the jit-able single-token decode (the function
the decode_32k / long_500k dry-run cells lower); ``make_decode_scan``
compiles N of those steps into ONE program — a ``jax.lax.scan`` over
steps whose carry holds the running token, the (donated) KV cache, and a
preallocated output buffer written with ``dynamic_update_slice`` — so N
generated tokens cost one dispatch instead of N Python-driven dispatches.
``Server`` is a batched-request driver (prefill once, then greedy or
sampled decode — see ``launch.sampling`` for the position-keyed PRNG
rule) used by the serving example, the continuous-batching scheduler
(``launch.scheduler``), and integration tests.

``Server(plan=...)`` selects which sidebar kernel variant backs the
model's fused MLP ops: ``ExecutionMode.SIDEBAR`` (single VMEM scratch) or
``ExecutionMode.SIDEBAR_PIPELINED`` (T-deep VMEM ring — the host-side
flexible function of tile t overlaps the MXU work of up to T-1 in-flight
neighbours; the ring depth comes from the plan). The plan may be a
``LayerPlan``, an ``ExecutionMode``, a mode string, or a whole
``ExecutionPlan``. A *heterogeneous* ``ExecutionPlan`` (per-layer entries
differing from the default) is applied per layer: the layer stack is
unrolled at trace time (``cfg.scan_layers=False``) and each layer's trace
runs under ``kernels.ops.layer_scope(i)``, so ``plan.for_layer(i)``
selects that layer's kernel variant and ring depth — the planner's
per-layer depth sweep reaches the kernels. The choice is applied as
ambient state around trace time, so the same model code serves under any
variant with no signature changes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.modes import (
    ExecutionMode,
    ExecutionPlan,
    LayerPlan,
    coerce_layer_plan,
)
from repro.kernels import ops as kops
from repro.launch import sampling
from repro.launch.sampling import SamplingParams
from repro.models import layers as L
from repro.models.registry import ModelApi, get_model
from repro.parallel import tp as tplib
from repro.parallel.compat import shard_map as _shard_map

Array = jax.Array

# Families whose caches are pure position-masked KV: a reused buffer's
# stale tail is invisible (decode attends kpos <= pos), so prefill can
# overwrite in place. Recurrent state (ssm/hybrid/rwkv) and the audio
# decoder integrate unmasked state and need a fresh (zeroed) cache.
_CACHE_REUSE_FAMILIES = ("dense", "moe", "vlm")

# Families whose generic-transformer layer stack unrolls under
# scan_layers=False and announces kops.layer_scope — the only ones a
# heterogeneous (per-layer) ExecutionPlan can reach. vlm groups always
# scan; ssm/hybrid/audio use their own stack modules without layer_scope.
# launch.scheduler reuses this as its supported-family set (its own
# memory-free-decode constraint currently binds the same families).
PER_LAYER_PLAN_FAMILIES = ("dense", "moe")


# ---------------------------------------------------------------------------
# Tensor-parallel serving: the whole step under ONE shard_map.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TpSpec:
    """Everything the step builders need to shard_map a serve/prefill
    step over the mesh's "model" axis.

    The partitioning is classic Megatron TP driven entirely by the
    model's own ParamSpecs: every param/cache dim whose pspec mentions
    "model" is split (column-parallel wq/wk/wv/w_up/w_gate and the
    vocab-row-sharded embed table, row-parallel wo/w_down, the KV pool's
    head axis, expert stacks over experts); everything else — tokens,
    positions, block tables, per-row lengths, sampling state, MLA latent
    caches — is replicated host metadata. Inside the shard_map body the
    ambient ``parallel.tp`` context makes the model functions psum their
    row-parallel partials and all-gather the logits once per step.

    ``cfg_local`` is the per-shard view: ONLY the head counts change —
    all other shapes the forward pass derives from the (already sliced)
    arrays themselves, and global quantities (vocab_size for the padded-
    logit mask, num_experts for routing/capacity) must stay global.
    """

    mesh: Any
    axis: str                  # "model"
    size: int                  # shard count on that axis
    cfg_local: ModelConfig
    minfo: L.MeshInfo          # mesh axes WITH sizes (drives spec choices)
    param_pspecs: Any          # P tree matching the params tree
    cache_pspecs: Any          # P tree matching the cache/pool tree

    @property
    def mesh_key(self) -> tuple:
        """Hashable mesh identity for executable-cache keys."""
        return (tuple(self.mesh.devices.shape), tuple(self.mesh.axis_names))

    def param_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_pspecs)

    def cache_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.cache_pspecs)

    def place_params(self, params):
        return jax.device_put(params, self.param_shardings())

    def place_cache(self, cache):
        return jax.device_put(cache, self.cache_shardings())


def make_tp_spec(cfg: ModelConfig, api: ModelApi, mesh) -> TpSpec:
    """Validate cfg against the mesh and build the serving TpSpec.

    Head-axis sharding only: num_heads (and num_kv_heads for GQA) must
    divide by the model-axis size — the head-dim fallback some cache
    specs allow under GSPMD is excluded here because the paged kernels
    and the absorbed-MLA einsums want whole heads per shard. Every
    model-sharded param dim is checked for divisibility so a bad
    (config, mesh) pairing fails at construction, not inside XLA.
    """
    from repro.launch.mesh import mesh_info

    minfo = mesh_info(mesh)  # asserts the canonical axis names
    size = minfo.size("model")
    problems = []
    if cfg.num_heads % size:
        problems.append(f"num_heads {cfg.num_heads} % tp {size} != 0")
    if not cfg.use_mla and cfg.num_kv_heads % size:
        problems.append(f"num_kv_heads {cfg.num_kv_heads} % tp {size} != 0")
    specs = api.param_specs(cfg, minfo)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs, is_leaf=L.is_spec)
    for path, s in flat:
        pspec = tplib.model_only_pspec(s.pspec)
        for dim, entry in zip(s.shape, tuple(pspec)):
            if entry == "model" and dim % size:
                problems.append(
                    f"param{jax.tree_util.keystr(path)}: model-sharded "
                    f"dim {dim} % tp {size} != 0"
                )
    if problems:
        raise ValueError(
            f"config {cfg.arch_id!r} cannot tensor-parallel over "
            f"{dict(minfo.sizes)}: " + "; ".join(problems)
        )
    param_pspecs = jax.tree.map(
        lambda s: tplib.model_only_pspec(s.pspec), specs, is_leaf=L.is_spec)
    # cache pspecs depend only on (cfg, minfo), never on batch/length —
    # probe with nominal sizes; the same tree serves slab caches and the
    # paged pool (identical leaf structure, batch axis = blocks).
    cache_pspecs = jax.tree.map(
        lambda s: tplib.model_only_pspec(s.pspec),
        api.cache_specs(cfg, minfo, 1, 8), is_leaf=L.is_spec)
    cfg_local = cfg
    if size > 1:
        kw = {"num_heads": cfg.num_heads // size}
        if not cfg.use_mla:
            kw["num_kv_heads"] = cfg.num_kv_heads // size
        cfg_local = dataclasses.replace(cfg, **kw)
    return TpSpec(mesh=mesh, axis="model", size=size, cfg_local=cfg_local,
                  minfo=minfo, param_pspecs=param_pspecs,
                  cache_pspecs=cache_pspecs)


def make_serve_step(cfg: ModelConfig, api: ModelApi, minfo: L.MeshInfo, mesh,
                    tp: TpSpec | None = None):
    """decode one token: (params, tokens(B,1), cache, pos[, memory, sample]).

    ``pos`` is scalar (whole batch at one length) or per-row ``(B,)``
    (the scheduler's batched segment decode over unaligned slots).
    ``sample`` is a traced per-row state from ``sampling.sample_state``
    / ``sampling.merge_rows`` — ``None`` keeps exact greedy argmax; the
    token written at sequence index ``pos + 1`` is keyed by that index
    (see ``launch.sampling`` for the position-keyed PRNG rule).
    ``block_tables`` makes ``cache`` a paged block pool decoded IN
    PLACE: writes land through the tables and attention walks them
    directly (``kernels.ops.paged_attention_*``) — the paged
    scheduler's slab-free segment path.

    ``tp`` switches the step to manual tensor parallelism: the WHOLE
    body runs under one ``shard_map`` over the mesh's "model" axis with
    params/cache partitioned per ``TpSpec`` and everything else
    replicated, the ambient ``parallel.tp`` context supplying the psums
    and the single per-step logit all-gather. The Pallas paged kernel
    traces per-shard unmodified (it sees a dense local head slice). The
    inner api call gets ``mesh=None``/no sharding hints —
    ``with_sharding_constraint`` belongs to the auto-partitioned
    (GSPMD) route, not inside a manual region.
    """

    from repro.parallel.hints import sharding_hints

    if tp is not None:
        cfg_l, minfo_l, rep = tp.cfg_local, tp.minfo, P()

        def tp_body(params, tokens, cache, pos, memory, sample,
                    block_tables):
            kw = {} if block_tables is None else {"block_tables": block_tables}
            with tplib.tensor_parallel(tp.axis, tp.size):
                logits, cache = api.decode_step(
                    params, cfg_l, tokens, cache, pos, minfo=minfo_l,
                    mesh=None, memory=memory, **kw,
                )
            logits = L.mask_pad_logits(logits, cfg.vocab_size)
            next_tok = sampling.sample_tokens(logits[:, -1, :], sample,
                                              pos + 1)
            return next_tok[:, None], cache

        def tp_serve_step(params, tokens, cache, pos, memory=None,
                          sample=None, block_tables=None):
            fn = _shard_map(
                tp_body, mesh=tp.mesh,
                in_specs=(tp.param_pspecs, rep, tp.cache_pspecs, rep, rep,
                          rep, rep),
                out_specs=(rep, tp.cache_pspecs),
                check_vma=False,
            )
            return fn(params, tokens, cache, pos, memory, sample,
                      block_tables)

        return tp_serve_step

    def serve_step(params, tokens, cache, pos, memory=None, sample=None,
                   block_tables=None):
        kw = {} if block_tables is None else {"block_tables": block_tables}
        with sharding_hints(mesh, minfo):
            logits, cache = api.decode_step(
                params, cfg, tokens, cache, pos, minfo=minfo, mesh=mesh,
                memory=memory, **kw,
            )
        logits = L.mask_pad_logits(logits, cfg.vocab_size)
        next_tok = sampling.sample_tokens(logits[:, -1, :], sample, pos + 1)
        return next_tok[:, None], cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, api: ModelApi, minfo: L.MeshInfo,
                      mesh, tp: TpSpec | None = None):
    """Build the jit-able prompt-KV writer.

    ``cache_pos`` (scalar or per-row ``(B,)``) makes the step *chunked*:
    it writes S tokens starting at that position instead of 0, so a long
    prompt prefills as a sequence of bounded-length programs (the paged
    scheduler's prefill-ahead staging; ``Server.generate(prefill_chunk=)``
    for slab caches). ``block_tables`` routes the writes through the
    paged pool. Both default off, keeping the original signature/HLO for
    every existing caller (incl. non-transformer families that take
    neither kwarg). ``tp`` shard_maps the step exactly like
    ``make_serve_step`` — multi-token rowwise staging chunks write their
    KV through the same partitioned pool."""
    from repro.parallel.hints import sharding_hints

    if tp is not None:
        cfg_l, minfo_l, rep = tp.cfg_local, tp.minfo, P()

        def tp_body(params, batch, cache, sample, cache_pos, block_tables):
            kw = {}
            if cache_pos is not None:
                kw["cache_pos"] = cache_pos
            if block_tables is not None:
                kw["block_tables"] = block_tables
            with tplib.tensor_parallel(tp.axis, tp.size):
                logits, cache = api.prefill(
                    params, cfg_l, batch, cache, minfo=minfo_l, mesh=None,
                    **kw,
                )
            logits = L.mask_pad_logits(logits, cfg.vocab_size)
            idx = batch["tokens"].shape[1]
            if cache_pos is not None:
                idx = cache_pos + idx
            next_tok = sampling.sample_tokens(logits[:, -1, :], sample, idx)
            return next_tok[:, None], cache

        def tp_prefill_step(params, batch, cache, sample=None,
                            cache_pos=None, block_tables=None):
            fn = _shard_map(
                tp_body, mesh=tp.mesh,
                in_specs=(tp.param_pspecs, rep, tp.cache_pspecs, rep, rep,
                          rep),
                out_specs=(rep, tp.cache_pspecs),
                check_vma=False,
            )
            return fn(params, batch, cache, sample, cache_pos, block_tables)

        return tp_prefill_step

    def prefill_step(params, batch, cache, sample=None, cache_pos=None,
                     block_tables=None):
        kw = {}
        if cache_pos is not None:
            kw["cache_pos"] = cache_pos
        if block_tables is not None:
            kw["block_tables"] = block_tables
        with sharding_hints(mesh, minfo):
            logits, cache = api.prefill(
                params, cfg, batch, cache, minfo=minfo, mesh=mesh, **kw
            )
        logits = L.mask_pad_logits(logits, cfg.vocab_size)
        # prefill of S tokens starting at p emits the token at sequence
        # index p + S (p = 0 for the classic whole-prompt prefill)
        idx = batch["tokens"].shape[1]
        if cache_pos is not None:
            idx = cache_pos + idx
        next_tok = sampling.sample_tokens(logits[:, -1, :], sample, idx)
        return next_tok[:, None], cache

    return prefill_step


def make_verify_step(cfg: ModelConfig, api: ModelApi, minfo: L.MeshInfo,
                     mesh, tp: TpSpec | None = None):
    """Build the speculative-decode verifier: one batched rowwise program.

    ``verify(params, tokens (B, K+1), cache, pos (B,), block_tables,
    sample=None) -> (target (B, K+1), cache)``. Row r's chunk is its last
    committed token followed by K drafted tokens; the chunk writes KV at
    positions ``pos[r] .. pos[r]+K`` through the block table (drafted
    positions land in the row's private scratch blocks — the table splice
    is the caller's job) and ``target[r, i]`` is the token the target
    model emits at sequence index ``pos[r] + 1 + i``, sampled with the
    position-keyed PRNG (greedy rows: exact argmax). Comparing drafts
    against ``target`` host-side therefore reproduces the plain decode
    stream exactly: position ``pos+1`` is always plain decode's token,
    and each later position is too whenever every draft before it
    matched. This is the PR-5 multi-token rowwise prefill with
    ``all_logits=True`` — only transformer families (dense/moe) support
    it; with MoE, co-batched positions share expert capacity, so serve
    with a no-drop ``capacity_factor`` for bit-parity (same caveat as
    chunked prefill). ``tp`` shard_maps the body exactly like
    ``make_prefill_step``.
    """
    from repro.parallel.hints import sharding_hints

    if tp is not None:
        cfg_l, minfo_l, rep = tp.cfg_local, tp.minfo, P()

        def tp_body(params, tokens, cache, pos, block_tables, sample):
            with tplib.tensor_parallel(tp.axis, tp.size):
                logits, cache = api.prefill(
                    params, cfg_l, {"tokens": tokens}, cache, minfo=minfo_l,
                    mesh=None, cache_pos=pos, block_tables=block_tables,
                    all_logits=True,
                )
            logits = L.mask_pad_logits(logits, cfg.vocab_size)
            target = sampling.sample_token_block(logits, sample, pos)
            return target, cache

        def tp_verify_step(params, tokens, cache, pos, block_tables,
                           sample=None):
            fn = _shard_map(
                tp_body, mesh=tp.mesh,
                in_specs=(tp.param_pspecs, rep, tp.cache_pspecs, rep, rep,
                          rep),
                out_specs=(rep, tp.cache_pspecs),
                check_vma=False,
            )
            return fn(params, tokens, cache, pos, block_tables, sample)

        return tp_verify_step

    def verify_step(params, tokens, cache, pos, block_tables, sample=None):
        with sharding_hints(mesh, minfo):
            logits, cache = api.prefill(
                params, cfg, {"tokens": tokens}, cache, minfo=minfo,
                mesh=mesh, cache_pos=pos, block_tables=block_tables,
                all_logits=True,
            )
        logits = L.mask_pad_logits(logits, cfg.vocab_size)
        target = sampling.sample_token_block(logits, sample, pos)
        return target, cache

    return verify_step


def make_decode_scan(cfg: ModelConfig, api: ModelApi, minfo: L.MeshInfo,
                     mesh, num_steps: int,
                     tp: TpSpec | None = None) -> Callable:
    """``num_steps`` decode steps as one compiled program.

    Returns ``decode_scan(params, tok, cache, pos, memory=None,
    sample=None) -> (tokens (B, num_steps), cache)``. The scan carry is
    (running token, cache, output buffer): the cache threads through the
    carry so jit donation aliases it across all steps, and each step's
    token lands in the preallocated buffer via ``dynamic_update_slice``
    — no per-token host round-trip, no restacked ys. Sampling keys are
    folded from (request key, token position) inside the step, so the
    scan needs no PRNG carry and matches the loop decode bit-for-bit.
    Under ``tp`` the scanned step is the shard_mapped one; the sharded
    cache rides the carry with matched in/out specs, so no per-step
    resharding ever appears in the program.
    """
    step = make_serve_step(cfg, api, minfo, mesh, tp=tp)

    def decode_scan(params, tok, cache, pos, memory=None, sample=None):
        b = tok.shape[0]
        buf = jnp.zeros((b, num_steps), jnp.int32)

        def body(carry, i):
            tok, cache, buf = carry
            nxt, cache = step(params, tok, cache, pos + i, memory, sample)
            buf = jax.lax.dynamic_update_slice(buf, nxt, (0, i))
            return (nxt, cache, buf), None

        (_, cache, buf), _ = jax.lax.scan(
            body, (tok, cache, buf), jnp.arange(num_steps, dtype=jnp.int32)
        )
        return buf, cache

    return decode_scan


@dataclasses.dataclass
class ServeResult:
    tokens: Any           # (B, prompt+generated)
    prompt_len: int
    generated: int


class Server:
    """Minimal batched decoding server (scan-compiled; greedy by
    default, sampled via ``generate(sample=SamplingParams(...))``)."""

    def __init__(self, cfg: ModelConfig, params, *, mesh=None,
                 max_len: int = 256,
                 execution_mode: ExecutionMode | str | None = None,
                 plan: LayerPlan | ExecutionPlan | ExecutionMode | str |
                 None = None,
                 ) -> None:
        self.params = params
        self.mesh = mesh
        self.minfo = L.HOST
        self.max_len = max_len
        if plan is not None and execution_mode is not None:
            raise ValueError("pass either plan= or execution_mode=, not both")
        if plan is None:
            plan = (ExecutionMode.SIDEBAR if execution_mode is None
                    else execution_mode)
        if isinstance(plan, ExecutionPlan):
            base = plan.default
            if not plan.is_uniform:
                # Per-layer kernel variants need one trace per layer: a
                # scanned stack traces its body once and would flatten
                # the plan to its default. Trade HLO size for dispatch.
                # Only the generic transformer's dense/moe stacks unroll
                # under scan_layers=False and announce layer_scope; fail
                # loudly elsewhere instead of silently serving the
                # default for every layer.
                if cfg.family not in PER_LAYER_PLAN_FAMILIES:
                    raise ValueError(
                        "a heterogeneous (per-layer) ExecutionPlan is "
                        "realized by unrolling the transformer layer "
                        f"stack; family {cfg.family!r} traces a single "
                        "variant — pass a uniform plan or a LayerPlan"
                    )
                cfg = dataclasses.replace(cfg, scan_layers=False)
        else:
            plan = base = coerce_layer_plan(plan)
        if base.mode not in (
            ExecutionMode.SIDEBAR, ExecutionMode.SIDEBAR_PIPELINED
        ):
            raise ValueError(
                "Server serves through the sidebar fast path; "
                "the plan's (default) mode must be SIDEBAR or "
                f"SIDEBAR_PIPELINED, got {base.mode}"
            )
        self.cfg = cfg
        self.api = get_model(cfg)
        self.plan = plan
        self.execution_mode = base.mode
        # mesh => tensor-parallel serving: the step programs run under
        # shard_map with params/caches partitioned on the "model" axis
        self.tp = make_tp_spec(cfg, self.api, mesh) if mesh is not None \
            else None
        if self.tp is not None:
            self.minfo = self.tp.minfo
            self.params = self.tp.place_params(params)
        self._mesh_key = self.tp.mesh_key if self.tp is not None else None
        self._prefill = jax.jit(
            make_prefill_step(cfg, self.api, self.minfo, mesh, tp=self.tp),
            donate_argnums=(2,),
        )
        self._decode = jax.jit(
            make_serve_step(cfg, self.api, self.minfo, mesh, tp=self.tp),
            donate_argnums=(2,),
        )
        # executable cache: one compiled decode program per (step count,
        # mesh identity) — jit itself re-specializes on batch; repeat
        # traffic of the same (batch, gen) shape never re-traces, and a
        # server on a different mesh can never reuse a stale program.
        self._decode_scans: dict[tuple, Callable] = {}
        self._cache_pool: dict[int, Any] = {}

    # -- KV-cache pooling --------------------------------------------------
    def _take_cache(self, b: int):
        """A (B, max_len) cache: pooled buffer when the family's cache is
        position-masked KV (prefill overwrites, decode masks the stale
        tail), freshly zero-initialized otherwise."""
        if self.cfg.family in _CACHE_REUSE_FAMILIES:
            pooled = self._cache_pool.pop(b, None)
            if pooled is not None:
                return pooled
        cache = self.api.init_cache(self.cfg, self.minfo, b, self.max_len)
        return self.tp.place_cache(cache) if self.tp is not None else cache

    def _return_cache(self, b: int, cache) -> None:
        if self.cfg.family in _CACHE_REUSE_FAMILIES:
            self._cache_pool[b] = cache

    def _decode_scan(self, num_steps: int) -> Callable:
        key = (num_steps, self._mesh_key)
        fn = self._decode_scans.get(key)
        if fn is None:
            fn = jax.jit(
                make_decode_scan(self.cfg, self.api, self.minfo, self.mesh,
                                 num_steps, tp=self.tp),
                donate_argnums=(2,),
            )
            self._decode_scans[key] = fn
        return fn

    def generate(self, prompts: Array, num_tokens: int,
                 extra: dict | None = None, *,
                 decode: str = "scan",
                 sample: SamplingParams | None = None,
                 prefill_chunk: int | None = None) -> ServeResult:
        """prompts: (B, S) int32 — one bucket; decode num_tokens.

        ``decode="scan"`` (default) runs all steps as one compiled
        program; ``decode="loop"`` keeps the PR-2 one-dispatch-per-token
        Python loop (benchmark baseline — token-for-token identical).
        ``sample`` switches greedy argmax to temperature / top-k / top-p
        sampling with a position-keyed PRNG stream per batch row: the
        same seed reproduces the same tokens under scan and loop decode
        alike, and temperature 0 is bit-identical to greedy.
        ``prefill_chunk`` splits the prompt's KV build into bounded
        chunks (each written at its true offset) — token-for-token
        identical to whole-prompt prefill, and the building block the
        paged scheduler's prefill-ahead staging interleaves behind
        decode. (MoE caveat: under a dropping capacity factor, chunk
        boundaries — like bucket padding — change which tokens compete
        for expert capacity; serve MoE no-drop for bit-parity.)
        """
        if decode not in ("scan", "loop"):
            raise ValueError(f"decode must be 'scan' or 'loop', got {decode!r}")
        b, s = prompts.shape
        if s + num_tokens > self.max_len:
            raise ValueError(
                f"prompt {s} + generate {num_tokens} exceeds max_len "
                f"{self.max_len}"
            )
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{prefill_chunk}")
            if self.cfg.family not in PER_LAYER_PLAN_FAMILIES:
                raise ValueError(
                    "chunked prefill needs a prefill that takes a "
                    "cache_pos offset (the generic transformer's dense/"
                    f"moe stacks); family {self.cfg.family!r} does not"
                )
        state = sampling.sample_state(sample, b) if sample is not None else None
        cache = self._take_cache(b)
        batch = {"tokens": prompts, **(extra or {})}
        # ambient kernel-variant selection must wrap trace time (the first
        # _prefill/_decode call below traces the model through kops)
        with kops.execution_plan(self.plan):
            memory = None
            if self.cfg.family == "audio":
                from repro.models import whisper as W

                memory = W.encode(self.params, self.cfg, batch["frames"])
            if self.cfg.family == "vlm":
                memory = batch.get("image_embeds")
            if prefill_chunk is not None and s > prefill_chunk:
                c0 = 0
                while c0 < s:
                    c1 = min(c0 + prefill_chunk, s)
                    chunk = dict(batch, tokens=prompts[:, c0:c1])
                    nxt, cache = self._prefill(
                        self.params, chunk, cache, state, jnp.int32(c0))
                    c0 = c1
            else:
                nxt, cache = self._prefill(self.params, batch, cache, state)
            pieces = [prompts, nxt]
            steps = num_tokens - 1
            if steps > 0 and decode == "scan":
                buf, cache = self._decode_scan(steps)(
                    self.params, nxt, cache, jnp.int32(s), memory, state
                )
                pieces.append(buf)
            elif steps > 0:
                pos = s
                for _ in range(steps):
                    nxt, cache = self._decode(
                        self.params, nxt, cache, jnp.int32(pos), memory, state
                    )
                    pieces.append(nxt)
                    pos += 1
        self._return_cache(b, cache)
        return ServeResult(
            tokens=jnp.concatenate(pieces, axis=1), prompt_len=s,
            generated=num_tokens,
        )
