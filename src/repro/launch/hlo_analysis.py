"""Loop-aware HLO cost extraction.

XLA's ``cost_analysis()`` counts each ``while`` body ONCE. The compiled
HLO text, however, contains everything needed for exact accounting:

  * computation blocks (``%name (...) -> ... { ... }``),
  * the call graph (``to_apply= / calls= / body= / condition= /
    branch_computations=``),
  * per-while trip counts (``"known_trip_count":{"n":"N"}``).

``analyze_hlo`` walks the graph from ENTRY, accumulating a multiplicity
per computation (product of enclosing trip counts), and returns:

  * collective bytes per kind (result-shape bytes x ring factor x
    multiplicity) — per-device, since post-SPMD shapes are per-device;
  * dot FLOPs (2 x out-elements x contraction size x multiplicity);
  * loops seen with their trip counts (for the report).

This is the primary source for the §Roofline collective/compute terms;
``cost_analysis`` and the analytic model are cross-checks.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
ALGO_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE = re.compile(r"while\(")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COLL_OP = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}\d]+))\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)
# dot operands appear typed ("dot(f32[64,64]{1,0} %lhs, ...)") in newer
# HLO text and bare ("dot(%lhs, ...)") in older text; capture the inline
# lhs dims when present, else the lhs name for a symbol-table lookup.
_DOT = re.compile(
    r"=\s*\w+\[([0-9,]*)\][^ ]*\s+dot\(\s*"
    r"(?:\w+\[([0-9,]*)\]\S*\s+)?%?([\w.\-]+)"
)
_DEF = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*(\w+)\[([0-9,]*)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions.

    Older jax returns a single-element list of per-program dicts; newer
    jax returns the dict directly. Normalize to a plain dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class HloCosts:
    coll_bytes: dict
    coll_bytes_total: float
    dot_flops: float
    loops: list  # (body_comp, trips)
    unknown_trip_loops: int

    @property
    def coll_by_kind(self) -> dict:
        return self.coll_bytes


def _split_computations(txt: str) -> tuple[dict[str, list[str]], str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in txt.splitlines():
        m = _COMP_START.match(line.strip()) if "{" in line else None
        if cur is None and m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def analyze_hlo(txt: str) -> HloCosts:
    comps, entry = _split_computations(txt)

    # per-computation local costs + edges
    local_coll: dict[str, dict[str, float]] = {}
    local_flops: dict[str, float] = {}
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    loops: list[tuple[str, int]] = []
    unknown = 0

    for name, lines in comps.items():
        coll = defaultdict(float)
        flops = 0.0
        # symbol table: instruction name -> dims (for dot operand lookup)
        symtab: dict[str, list[int]] = {}
        for line in lines:
            dm0 = _DEF.match(line)
            if dm0:
                symtab[dm0.group(1)] = [
                    int(d) for d in dm0.group(3).split(",") if d
                ]
        for line in lines:
            cm = _COLL_OP.search(line)
            if cm:
                kind = cm.group(2).replace("-start", "")
                coll[kind] += _shape_bytes(cm.group(1)) * ALGO_FACTOR[kind]
            dm = _DOT.search(line)
            if dm:
                out_dims = [int(d) for d in dm.group(1).split(",") if d]
                if dm.group(2) is not None:
                    lhs_dims = [int(d) for d in dm.group(2).split(",") if d]
                else:
                    lhs_dims = symtab.get(dm.group(3), [])
                ct = _CONTRACT.search(line)
                cdims = [int(d) for d in ct.group(1).split(",") if d] if ct else []
                contract = 1
                for ci in cdims:
                    if ci < len(lhs_dims):
                        contract *= lhs_dims[ci]
                flops += 2.0 * math.prod(out_dims or [1]) * contract
            if _WHILE.search(line):
                bm = _BODY.search(line)
                cm2 = _COND.search(line)
                tm = _TRIP.search(line)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    unknown += 1
                if bm:
                    edges[name].append((bm.group(1), float(trips)))
                    loops.append((bm.group(1), trips))
                if cm2:
                    edges[name].append((cm2.group(1), float(trips + 1)))
                continue
            for m2 in _TO_APPLY.finditer(line):
                edges[name].append((m2.group(1), 1.0))
            for m2 in _CALLS.finditer(line):
                edges[name].append((m2.group(1), 1.0))
            bm2 = _BRANCHES.search(line)
            if bm2:
                for b in bm2.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        edges[name].append((b, 1.0))
        local_coll[name] = dict(coll)
        local_flops[name] = flops

    # multiplicities via topological walk (call graph is a DAG)
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        entry = next(iter(comps), None)
    if entry is not None:
        mult[entry] = 1.0
        # repeated relaxation (small graphs; avoids needing a topo sort)
        for _ in range(64):
            changed = False
            snapshot = dict(mult)
            new = defaultdict(float)
            new[entry] = 1.0
            for src, outs in edges.items():
                m = snapshot.get(src, 0.0)
                if m <= 0:
                    continue
                for dst, k in outs:
                    new[dst] += m * k
            if dict(new) != dict(mult):
                mult = new
                changed = True
            if not changed:
                break

    total_coll: dict[str, float] = defaultdict(float)
    total_flops = 0.0
    for name in comps:
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for kind, b in local_coll[name].items():
            total_coll[kind] += m * b
        total_flops += m * local_flops[name]

    out = {k: total_coll.get(k, 0.0) for k in _COLL_KINDS}
    return HloCosts(
        coll_bytes=out,
        coll_bytes_total=float(sum(out.values())),
        dot_flops=total_flops,
        loops=loops,
        unknown_trip_loops=unknown,
    )
