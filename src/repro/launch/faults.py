"""Deterministic fault injection for the serving fleet.

Robustness claims are worthless untested: an allocator error mid-stage,
an eviction storm, a wedged staging round, or a replica that errors on
dispatch are all paths the scheduler *says* it handles — this module
makes them happen on demand, reproducibly, so the property tests can
assert the strong invariants (no leaked blocks, no double frees,
token-bit-exact output vs the unfaulted run) under seeded random
interleavings instead of hoping.

One ``FaultInjector`` is threaded through the stack and consulted at
named **sites**:

  ============== =====================================================
  site           effect when it fires
  ============== =====================================================
  ``alloc``      ``BlockAllocator.alloc`` raises ``KVPoolError``
                 (hooked via ``fault_hook``) — exercises begin/ensure/
                 restore rollback atomicity
  ``evict_storm``the scheduler force-evicts every cached block at a
                 segment boundary (prefix index flushed) — exercises
                 restore-after-eviction and cold re-splice paths
  ``stage_stall``one staging round is skipped — prefill-ahead stalls,
                 admission slips a boundary
  ``dispatch:i`` the router's dispatch to replica ``i`` raises
                 ``ReplicaDispatchError`` — exercises quarantine +
                 exponential-backoff reprobe (the replica's queued
                 work is untouched; the step simply does not run)
  ============== =====================================================

Two triggering modes compose:

  * ``rates={"alloc": 0.05, ...}`` — seeded Bernoulli per consultation
    (``np.random.RandomState``; the draw sequence is a pure function of
    seed and consultation order, and the scheduler consults at
    deterministic points, so a seeded run replays exactly).
    A rate keyed ``"dispatch"`` applies to every ``dispatch:i`` site.
  * ``script={"alloc": [3, 7]}`` — fire on exactly the Nth consultation
    of a site (1-based), for pinpoint tests ("fail the 3rd alloc").

``max_per_site`` bounds Bernoulli firings so a drain always terminates
even at rate 1.0.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np


class ReplicaDispatchError(RuntimeError):
    """An injected failure dispatching work to a replica — the router's
    cue to count an error against that replica's health and move on."""


@dataclasses.dataclass(frozen=True)
class FaultRecord:
    """One injected fault: which site, on which consultation of it."""

    site: str
    call: int


class FaultInjector:
    """Seeded, site-addressed fault source (see module docstring)."""

    def __init__(self, seed: int = 0, *,
                 rates: dict[str, float] | None = None,
                 script: dict[str, list[int]] | None = None,
                 max_per_site: int | None = None) -> None:
        self._rng = np.random.RandomState(seed)
        self.rates = dict(rates or {})
        self.script = {k: set(v) for k, v in (script or {}).items()}
        self.max_per_site = max_per_site
        self.calls: collections.Counter = collections.Counter()
        self.injected: collections.Counter = collections.Counter()
        self.log: list[FaultRecord] = []

    def _base(self, site: str) -> str:
        return site.split(":", 1)[0]

    def fire(self, site: str) -> bool:
        """Consult the injector at ``site``; True = inject the fault."""
        self.calls[site] += 1
        n = self.calls[site]
        hit = False
        if n in self.script.get(site, ()):
            hit = True
        else:
            rate = self.rates.get(site)
            if rate is None:
                rate = self.rates.get(self._base(site), 0.0)
            if rate > 0.0 and self._rng.rand() < rate:
                budget = self.max_per_site
                if budget is None or self.injected[site] < budget:
                    hit = True
        if hit:
            self.injected[site] += 1
            self.log.append(FaultRecord(site, n))
        return hit

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())
