"""Training: sharded step builder + fault-tolerant driver.

``make_train_step`` builds the jit-able step:

  (params, opt_state, ef_state, batch) -> (params, opt_state, ef_state, metrics)

  * microbatch gradient accumulation (lax.scan over microbatches; fp32
    accumulator tree, sharded like params),
  * optional gradient compression codec at the sync boundary,
  * AdamW with warmup/inv-sqrt schedule and global-norm clipping,
  * in/out shardings derived from the ParamSpec trees (FSDP x TP).

``Trainer`` is the driver: auto-resume from the newest checkpoint,
async checkpointing every ``ckpt_every``, straggler watchdog with an
eviction hook (elastic restart), deterministic data stream keyed by step.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager, config_hash
from repro.configs.base import ModelConfig, ShapeCell, TrainConfig
from repro.data import pipeline
from repro.ft.watchdog import StragglerWatchdog, Verdict
from repro.launch.input_specs import batch_shardings
from repro.models import layers as L
from repro.models.registry import ModelApi, get_model
from repro.optim import compression
from repro.optim.optimizer import (
    AdamState,
    adamw_update,
    init_state,
    state_shardings,
)

log = logging.getLogger("repro.train")
Array = jax.Array


def _dp_size(mesh, minfo) -> int:
    if mesh is None:
        return 1
    n = 1
    for a in minfo.fsdp:
        n *= mesh.shape[a]
    return n


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, api: ModelApi,
                    minfo: L.MeshInfo, mesh, cell: ShapeCell):
    n_micro = max(
        1, cell.global_batch // max(1, tcfg.microbatch_per_device * _dp_size(mesh, minfo))
    )
    use_ef = tcfg.grad_compression == "int8_ef"

    from repro.parallel.hints import sharding_hints

    def loss_fn(params, mb):
        with sharding_hints(mesh, minfo):
            return api.loss(params, cfg, mb, minfo=minfo, mesh=mesh)

    def train_step(params, opt_state: AdamState, ef_state, batch):
        if n_micro > 1:
            def split(x):
                # STRIDED split: microbatch m takes rows {m, m+n_micro, ...}
                # so every microbatch spans all data shards. A contiguous
                # reshape(n_micro, mb, ...) puts the SCAN dim on the sharded
                # axis — XLA then replicates the batch inside the loop
                # (16x redundant attention compute; found via loop-aware
                # HLO analysis, see EXPERIMENTS.md §Perf iteration 1).
                b = x.shape[0]
                x = x.reshape(b // n_micro, n_micro, *x.shape[1:])
                x = jnp.swapaxes(x, 0, 1)
                if mesh is not None and minfo.fsdp:
                    from jax.sharding import NamedSharding, PartitionSpec as P

                    from repro.models.layers import sanitize_pspec

                    spec = P(None, tuple(minfo.fsdp),
                             *([None] * (x.ndim - 2)))
                    x = jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, sanitize_pspec(mesh, spec, x.shape))
                    )
                return x

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gacc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g
                )
                return (gacc, lacc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / n_micro), gsum)
            loss = lsum / n_micro
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        grads, ef_state = compression.compress(
            grads, tcfg.grad_compression, ef_state
        )
        params, opt_state, stats = adamw_update(params, grads, opt_state, tcfg)
        metrics = {"loss": loss.astype(jnp.float32), **stats}
        return params, opt_state, ef_state, metrics

    return train_step, n_micro, use_ef


def make_jitted_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                           api: ModelApi, mesh, cell: ShapeCell):
    """jit with explicit in/out shardings over the production mesh."""
    from repro.launch.mesh import mesh_info

    minfo = mesh_info(mesh)
    step_fn, n_micro, use_ef = make_train_step(cfg, tcfg, api, minfo, mesh, cell)
    specs = api.param_specs(cfg, minfo)
    p_shard = L.shardings(mesh, specs)
    o_shard = state_shardings(p_shard, mesh)
    ef_shard = compression.EFState(p_shard) if use_ef else None
    b_shard = batch_shardings(cfg, cell, mesh, minfo)
    metric_shard = None  # replicated scalars
    jitted = jax.jit(
        step_fn,
        in_shardings=(p_shard, o_shard, ef_shard, b_shard),
        out_shardings=(p_shard, o_shard, ef_shard, metric_shard),
        donate_argnums=(0, 1, 2),
    )
    return jitted, specs, p_shard, o_shard, n_micro, use_ef


@dataclasses.dataclass
class TrainerReport:
    steps_run: int
    final_loss: float
    resumed_from: int | None
    straggler_events: int
    evictions: int
    losses: list


class Trainer:
    """Fault-tolerant loop: resume -> train -> checkpoint -> (evict?)."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, cell: ShapeCell,
                 *, ckpt_dir: str, mesh=None, ckpt_every: int = 20,
                 keep: int = 3, data_cfg: pipeline.DataConfig | None = None,
                 batch_override: int | None = None,
                 watchdog: StragglerWatchdog | None = None,
                 on_evict: Callable[[], None] | None = None) -> None:
        self.cfg, self.tcfg, self.cell = cfg, tcfg, cell
        self.api = get_model(cfg)
        self.mesh = mesh
        self.minfo = (
            L.MeshInfo.from_axes(tuple(mesh.axis_names)) if mesh else L.HOST
        )
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.dcfg = data_cfg or pipeline.DataConfig()
        self.batch_override = batch_override
        self.watchdog = watchdog or StragglerWatchdog()
        self.on_evict = on_evict
        self.meta = {
            "config": config_hash(cfg),
            "arch": cfg.arch_id,
            "cell": cell.name,
        }

        self.step_fn, self.n_micro, self.use_ef = make_train_step(
            cfg, tcfg, self.api, self.minfo, mesh, cell
        )
        self.jitted = jax.jit(self.step_fn, donate_argnums=(0, 1, 2))

    # -- state --------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.api.init(jax.random.PRNGKey(seed), self.cfg, self.minfo)
        opt = init_state(params, self.tcfg)
        ef = compression.init_ef(params) if self.use_ef else None
        return params, opt, ef

    def _state_tree(self, params, opt, ef):
        tree = {"params": params, "opt": opt._asdict()}
        if ef is not None:
            tree["ef"] = ef._asdict()
        return tree

    def resume_or_init(self, seed: int = 0):
        params, opt, ef = self.init_state(seed)
        latest = self.ckpt.latest_step()
        if latest is None:
            return params, opt, ef, 0, None
        like = self._state_tree(params, opt, ef)
        restored, manifest = self.ckpt.restore(
            latest, like, expect_meta=self.meta
        )
        params = restored["params"]
        opt = AdamState(**restored["opt"])
        ef = compression.EFState(**restored["ef"]) if ef is not None else None
        return params, opt, ef, latest, latest

    # -- loop ---------------------------------------------------------------
    def run(self, num_steps: int, *, seed: int = 0,
            inject_step_times=None) -> TrainerReport:
        params, opt, ef, start, resumed = self.resume_or_init(seed)
        losses = []
        evictions = 0
        step = start
        while step < num_steps:
            batch = pipeline.make_batch(
                self.cfg, self.cell, step, self.dcfg,
                batch_override=self.batch_override,
            )
            self.watchdog.start()
            params, opt, ef, metrics = self.jitted(params, opt, ef, batch)
            jax.block_until_ready(metrics["loss"])
            if inject_step_times is not None:
                verdict = self.watchdog.observe(inject_step_times(step))
                self.watchdog._t0 = None
            else:
                verdict = self.watchdog.stop()
            losses.append(float(metrics["loss"]))
            step += 1
            if verdict is Verdict.EVICT:
                evictions += 1
                log.warning("straggler eviction at step %d", step)
                self.ckpt.save(step, self._state_tree(params, opt, ef),
                               meta=self.meta)
                if self.on_evict is not None:
                    self.on_evict()
            if step % self.ckpt_every == 0 or step == num_steps:
                self.ckpt.save_async(
                    step, self._state_tree(params, opt, ef), meta=self.meta
                )
        self.ckpt.wait()
        return TrainerReport(
            steps_run=num_steps - start,
            final_loss=losses[-1] if losses else float("nan"),
            resumed_from=resumed,
            straggler_events=len(self.watchdog.history),
            evictions=evictions,
            losses=losses,
        )
