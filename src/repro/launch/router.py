"""Prefix-affinity replica router: data parallelism over paged servers.

Tensor parallelism (``launch.serve.make_tp_spec`` + the shard_map step
programs) scales ONE model instance across a mesh; this module scales
*throughput* across N independent ``PagedContinuousBatchingServer``
replicas — the classic serving fleet shape (TP inside a replica, DP
across replicas).

The routing policy is what makes the fleet more than N queues: each
replica owns its own KV block pool and prefix index, so WHERE a request
lands decides whether its prompt prefix is recomputed or spliced. The
router probes every replica's prefix index (``PagedKVManager.
prefix_affinity`` — a side-effect-free ``peek`` walk, so probing does
not pollute the per-replica hit-rate stats) and steers the request to
the replica holding the longest run of full prompt blocks, breaking
ties (and handling the no-hit case) by least outstanding load. Traffic
with shared system prompts therefore *concentrates* per prefix family:
the first request of a family seeds one replica's index and every
follow-up lands on it, instead of re-prefilling the prefix once per
replica the way random/round-robin spraying does.

``policy="random"`` keeps the spray baseline in-tree — the bench's
affinity-over-random ratio is measured, not assumed.

Request ids are fleet-global: ``submit`` returns a fleet rid and the
router retags each replica's ``FinishedRequest`` on the way out, so
callers see one server. ``FleetStats`` sums the per-replica
``SchedulerStats`` counters and adds the routing-level ones.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.launch.scheduler import (
    FinishedRequest,
    PagedContinuousBatchingServer,
    SchedulerStats,
)


@dataclasses.dataclass
class FleetStats:
    """Routing counters + the element-wise sum of replica stats."""

    requests: int = 0
    affinity_routed: int = 0     # steered by a prefix-index hit
    fallback_routed: int = 0     # no hit anywhere -> least-loaded
    random_routed: int = 0       # policy="random" assignments
    totals: SchedulerStats = dataclasses.field(
        default_factory=SchedulerStats)

    @property
    def prefix_hit_rate(self) -> float:
        """Fleet-wide block-granular prefix hit rate (the bench's
        ``fleet_prefix_hit_rate`` row)."""
        return self.totals.prefix_hit_rate

    def summary(self) -> str:
        lines = [
            f"fleet: {self.requests} requests — "
            f"{self.affinity_routed} affinity-routed, "
            f"{self.fallback_routed} least-loaded, "
            f"{self.random_routed} random",
            self.totals.summary(),
        ]
        return "\n".join(lines)


def sum_stats(per_replica: list[SchedulerStats]) -> SchedulerStats:
    """Element-wise sum of the counter fields (every field of
    ``SchedulerStats`` is an additive count; the rates are properties
    derived from the summed counts, so they aggregate correctly)."""
    out = SchedulerStats()
    for st in per_replica:
        for f in dataclasses.fields(SchedulerStats):
            setattr(out, f.name, getattr(out, f.name) + getattr(st, f.name))
    return out


class ReplicaRouter:
    """Front end over N paged replicas with prefix-affinity steering.

    >>> fleet = ReplicaRouter([srv_a, srv_b])
    >>> fleet.submit(prompt, max_new_tokens=16)
    >>> done = fleet.run()        # drain every replica
    """

    POLICIES = ("prefix", "random")

    def __init__(self, replicas: list[PagedContinuousBatchingServer], *,
                 policy: str = "prefix", seed: int = 0) -> None:
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        if policy not in self.POLICIES:
            raise ValueError(
                f"policy must be one of {self.POLICIES}, got {policy!r}")
        self.replicas = list(replicas)
        self.policy = policy
        self._rng = np.random.RandomState(seed)
        self._next_fid = 0
        # fleet rid -> (replica index, replica-local rid)
        self._placement: dict[int, tuple[int, int]] = {}
        self._by_replica: list[dict[int, int]] = [
            {} for _ in self.replicas]
        self.stats = FleetStats()

    # -- routing -----------------------------------------------------------
    def _choose(self, prompt: np.ndarray) -> int:
        if self.policy == "random":
            self.stats.random_routed += 1
            return int(self._rng.randint(len(self.replicas)))
        affinity = [r.mgr.prefix_affinity(prompt) for r in self.replicas]
        best = max(affinity)
        if best > 0:
            # longest prefix wins; among equals, least loaded
            tied = [i for i, a in enumerate(affinity) if a == best]
            self.stats.affinity_routed += 1
            return min(tied, key=lambda i: self.replicas[i].load)
        self.stats.fallback_routed += 1
        return min(range(len(self.replicas)),
                   key=lambda i: self.replicas[i].load)

    def submit(self, prompt, max_new_tokens: int, sample=None) -> int:
        prompt_arr = np.asarray(prompt, np.int32).reshape(-1)
        idx = self._choose(prompt_arr)
        local = self.replicas[idx].submit(prompt_arr, max_new_tokens,
                                          sample)
        fid = self._next_fid
        self._next_fid += 1
        self._placement[fid] = (idx, local)
        self._by_replica[idx][local] = fid
        self.stats.requests += 1
        return fid

    # -- draining ----------------------------------------------------------
    def _retag(self, idx: int,
               finished: list[FinishedRequest]) -> list[FinishedRequest]:
        out = []
        for r in finished:
            fid = self._by_replica[idx].pop(r.rid)
            del self._placement[fid]
            out.append(dataclasses.replace(r, rid=fid))
        return out

    def step(self) -> list[FinishedRequest]:
        """One scheduler iteration on every replica that has work."""
        done: list[FinishedRequest] = []
        for idx, rep in enumerate(self.replicas):
            if rep._has_work():
                done.extend(self._retag(idx, rep.step()))
        self._roll_up()
        return sorted(done, key=lambda r: r.rid)

    def run(self) -> list[FinishedRequest]:
        """Drain every replica; finished requests ordered by fleet rid."""
        done: list[FinishedRequest] = []
        for idx, rep in enumerate(self.replicas):
            done.extend(self._retag(idx, rep.run()))
        self._roll_up()
        return sorted(done, key=lambda r: r.rid)

    def _roll_up(self) -> None:
        self.stats.totals = sum_stats([r.stats for r in self.replicas])

    @property
    def load(self) -> int:
        return sum(r.load for r in self.replicas)
