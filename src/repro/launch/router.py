"""Prefix-affinity replica router: data parallelism over paged servers.

Tensor parallelism (``launch.serve.make_tp_spec`` + the shard_map step
programs) scales ONE model instance across a mesh; this module scales
*throughput* across N independent ``PagedContinuousBatchingServer``
replicas — the classic serving fleet shape (TP inside a replica, DP
across replicas).

The routing policy is what makes the fleet more than N queues: each
replica owns its own KV block pool and prefix index, so WHERE a request
lands decides whether its prompt prefix is recomputed or spliced. The
router probes every replica's index (``PagedKVManager.chunk_affinity``
— a side-effect-free ``peek`` walk, so probing does not pollute the
per-replica hit-rate stats) and steers the request to the replica
holding the most warm prompt blocks — the leading prefix run PLUS any
interior chunk-boundary blocks (retrieved RAG chunks a sibling request
published) — breaking ties (and handling the no-hit case) by least
outstanding load. Traffic with shared system prompts or shared
retrieved chunks therefore *concentrates* per prefix/chunk family: the
first request of a family seeds one replica's index and every follow-up
lands on it, instead of re-prefilling the prefix once per replica the
way random/round-robin spraying does.

``policy="random"`` keeps the spray baseline in-tree — the bench's
affinity-over-random ratio is measured, not assumed.

Overload robustness rides on the same placement machinery:

  * **Work stealing** — a replica that preempted a request (spilled it
    to its host-side sidebar region) is by construction overloaded; if
    a sibling has a free slot and strictly less load, the router moves
    the spilled payload there (host numpy, device-agnostic), preferring
    a sibling whose prefix index still holds the victim's prompt warm.
    Priority, deadline, and first-token time travel with the request —
    SLO accounting does not reset on migration.
  * **Replica health** — ``quarantine_after`` consecutive dispatch
    errors (the fault injector's ``dispatch:i`` site) quarantines a
    replica: its steps are skipped for ``backoff_steps`` router steps,
    doubling on every failed reprobe (exponential backoff), reset on
    the first clean step. Queued work on a quarantined replica is
    untouched — an injected dispatch error models a transient transport
    fault, not state loss.

Request ids are fleet-global: ``submit`` returns a fleet rid and the
router retags each replica's ``FinishedRequest`` on the way out, so
callers see one server. ``FleetStats`` sums the per-replica
``SchedulerStats`` counters and adds the routing-level ones.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.launch.faults import FaultInjector
from repro.launch.scheduler import (
    FinishedRequest,
    PagedContinuousBatchingServer,
    SchedulerStats,
)


@dataclasses.dataclass
class FleetStats:
    """Routing counters + the element-wise sum of replica stats."""

    requests: int = 0
    affinity_routed: int = 0     # steered by a prefix-index hit
    fallback_routed: int = 0     # no hit anywhere -> least-loaded
    random_routed: int = 0       # policy="random" assignments
    stolen: int = 0              # spilled requests migrated to a sibling
    dispatch_errors: int = 0     # injected/raised replica dispatch faults
    quarantine_events: int = 0   # times a replica entered quarantine
    totals: SchedulerStats = dataclasses.field(
        default_factory=SchedulerStats)

    @property
    def prefix_hit_rate(self) -> float:
        """Fleet-wide block-granular prefix hit rate (the bench's
        ``fleet_prefix_hit_rate`` row)."""
        return self.totals.prefix_hit_rate

    def summary(self) -> str:
        lines = [
            f"fleet: {self.requests} requests — "
            f"{self.affinity_routed} affinity-routed, "
            f"{self.fallback_routed} least-loaded, "
            f"{self.random_routed} random",
        ]
        if self.stolen or self.dispatch_errors or self.quarantine_events:
            lines.append(
                f"fleet health: {self.stolen} stolen, "
                f"{self.dispatch_errors} dispatch errors, "
                f"{self.quarantine_events} quarantines")
        lines.append(self.totals.summary())
        return "\n".join(lines)


def sum_stats(per_replica: list[SchedulerStats]) -> SchedulerStats:
    """Element-wise sum of the counter fields (every scalar field of
    ``SchedulerStats`` is an additive count; the rates are properties
    derived from the summed counts, so they aggregate correctly). The
    per-priority latency-sample dicts concatenate instead — fleet tail
    percentiles must come from the pooled samples, not a sum."""
    out = SchedulerStats()
    for st in per_replica:
        for f in dataclasses.fields(SchedulerStats):
            mine, theirs = getattr(out, f.name), getattr(st, f.name)
            if isinstance(mine, dict):
                for k, v in theirs.items():
                    mine.setdefault(k, []).extend(v)
            else:
                setattr(out, f.name, mine + theirs)
    return out


@dataclasses.dataclass
class _ReplicaHealth:
    """Dispatch-fault bookkeeping for one replica."""

    consecutive_errors: int = 0
    quarantined_until: int = 0   # router step index; < means serving
    backoff: int = 0             # current quarantine length (steps)


class ReplicaRouter:
    """Front end over N paged replicas with prefix-affinity steering.

    >>> fleet = ReplicaRouter([srv_a, srv_b])
    >>> fleet.submit(prompt, max_new_tokens=16)
    >>> done = fleet.run()        # drain every replica
    """

    POLICIES = ("prefix", "random")

    def __init__(self, replicas: list[PagedContinuousBatchingServer], *,
                 policy: str = "prefix", seed: int = 0,
                 faults: FaultInjector | None = None,
                 quarantine_after: int = 3,
                 backoff_steps: int = 4,
                 steal: bool = True) -> None:
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        if policy not in self.POLICIES:
            raise ValueError(
                f"policy must be one of {self.POLICIES}, got {policy!r}")
        if quarantine_after < 1 or backoff_steps < 1:
            raise ValueError("quarantine_after and backoff_steps "
                             "must be >= 1")
        self.replicas = list(replicas)
        self.policy = policy
        self.faults = faults
        self.quarantine_after = quarantine_after
        self.backoff_steps = backoff_steps
        self.steal = steal
        self._rng = np.random.RandomState(seed)
        self._next_fid = 0
        self._step_i = 0
        # fleet rid -> (replica index, replica-local rid)
        self._placement: dict[int, tuple[int, int]] = {}
        self._by_replica: list[dict[int, int]] = [
            {} for _ in self.replicas]
        self._health = [_ReplicaHealth() for _ in self.replicas]
        self.stats = FleetStats()

    # -- routing -----------------------------------------------------------
    def _serving(self, idx: int) -> bool:
        return self._step_i >= self._health[idx].quarantined_until

    @property
    def quarantined(self) -> list[int]:
        """Indices of replicas currently under quarantine."""
        return [i for i in range(len(self.replicas))
                if not self._serving(i)]

    def _choose(self, prompt: np.ndarray) -> int:
        if self.policy == "random":
            self.stats.random_routed += 1
            return int(self._rng.randint(len(self.replicas)))
        # chunk_affinity counts EVERY warm prompt block — leading run
        # plus interior chunk-boundary hits (retrieved-chunk blocks a
        # sibling request published) — a strictly better reuse signal
        # than the leading run alone; both probes are side-effect-free
        affinity = [r.mgr.chunk_affinity(prompt) for r in self.replicas]
        best = max(affinity)
        if best > 0:
            # most warm blocks wins; among equals, least loaded
            tied = [i for i, a in enumerate(affinity) if a == best]
            self.stats.affinity_routed += 1
            return min(tied, key=lambda i: self.replicas[i].load)
        self.stats.fallback_routed += 1
        return min(range(len(self.replicas)),
                   key=lambda i: self.replicas[i].load)

    def submit(self, prompt, max_new_tokens: int, sample=None, *,
               priority: int = 0, ttft_target: float | None = None,
               itl_target: float | None = None) -> int:
        prompt_arr = np.asarray(prompt, np.int32).reshape(-1)
        idx = self._choose(prompt_arr)
        local = self.replicas[idx].submit(
            prompt_arr, max_new_tokens, sample, priority=priority,
            ttft_target=ttft_target, itl_target=itl_target)
        fid = self._next_fid
        self._next_fid += 1
        self._placement[fid] = (idx, local)
        self._by_replica[idx][local] = fid
        self.stats.requests += 1
        return fid

    def cancel(self, fid: int) -> bool:
        """Client abort by fleet rid — wherever the request lives now
        (migration keeps ``_placement`` current)."""
        placed = self._placement.get(fid)
        if placed is None:
            return False
        idx, local = placed
        if not self.replicas[idx].cancel(local):
            return False
        del self._placement[fid]
        self._by_replica[idx].pop(local, None)
        return True

    # -- health ------------------------------------------------------------
    def _on_dispatch_error(self, idx: int) -> None:
        h = self._health[idx]
        h.consecutive_errors += 1
        self.stats.dispatch_errors += 1
        if h.consecutive_errors >= self.quarantine_after:
            # enter (or re-enter) quarantine; each consecutive trip
            # doubles the backoff — a flapping replica gets probed ever
            # more rarely instead of eating a dispatch per step
            h.backoff = (h.backoff * 2 if h.backoff
                         else self.backoff_steps)
            h.quarantined_until = self._step_i + h.backoff
            self.stats.quarantine_events += 1

    # -- work stealing -----------------------------------------------------
    def _steal(self) -> None:
        """Migrate spilled (preempted) requests from overloaded replicas
        to siblings with room: a spill is the scheduler's signal that
        its replica cannot hold the working set, and the payload is
        already host-side numpy — moving it costs a dict handoff, not a
        device transfer. Prefer a sibling whose prefix index still
        holds the victim's prompt blocks warm (restore splices them);
        tie-break by least load. Steal only into a strictly less loaded
        replica with a free slot — never create pressure elsewhere."""
        if not self.steal:
            return
        for idx, rep in enumerate(self.replicas):
            if not getattr(rep, "_spilled", None):
                continue
            for sp in list(rep._spilled):
                need_len = int(sp.req.prompt.size) + sp.req.max_new - 1
                cands = [
                    j for j, o in enumerate(self.replicas)
                    if j != idx and self._serving(j)
                    and any(s.free for s in o.slots)
                    and o.load < rep.load
                    and need_len < o.max_len
                    and o.mgr.blocks_needed(need_len)
                    <= o.mgr.alloc.capacity
                ]
                if not cands:
                    continue
                aff = {j: self.replicas[j].mgr.chunk_affinity(
                    sp.req.prompt) for j in cands}
                best = max(aff.values())
                pool = [j for j in cands if aff[j] == best]
                j = min(pool, key=lambda j: self.replicas[j].load)
                taken = rep.take_spilled(sp.req.rid)
                if taken is None:
                    continue
                fid = self._by_replica[idx].pop(sp.req.rid)
                sp2, payload = taken
                local = self.replicas[j].submit_spilled(sp2, payload)
                self._placement[fid] = (j, local)
                self._by_replica[j][local] = fid
                self.stats.stolen += 1

    # -- draining ----------------------------------------------------------
    def _retag(self, idx: int,
               finished: list[FinishedRequest]) -> list[FinishedRequest]:
        out = []
        for r in finished:
            fid = self._by_replica[idx].pop(r.rid)
            del self._placement[fid]
            out.append(dataclasses.replace(r, rid=fid))
        return out

    def step(self, *, draining: bool = False) -> list[FinishedRequest]:
        """One scheduler iteration on every serving replica that has
        work; quarantined replicas are skipped until their backoff
        expires, and spilled requests migrate afterwards (stealing
        reacts to the preemptions this very step created)."""
        done: list[FinishedRequest] = []
        self._step_i += 1
        for idx, rep in enumerate(self.replicas):
            if not self._serving(idx) or not rep._has_work():
                continue
            if (self.faults is not None
                    and self.faults.fire(f"dispatch:{idx}")):
                # the replica's queued work is untouched — its step
                # simply does not run; health decides what happens next
                self._on_dispatch_error(idx)
                continue
            out = rep.step(draining=draining)
            h = self._health[idx]
            h.consecutive_errors = 0
            h.backoff = 0
            done.extend(self._retag(idx, out))
        self._steal()
        self._roll_up()
        return sorted(done, key=lambda r: r.rid)

    def run(self) -> list[FinishedRequest]:
        """Drain every replica; finished requests ordered by fleet rid.
        Step-wise (not per-replica ``run()`` calls) so quarantine
        backoff advances and work stealing operates mid-drain; each
        replica still sees the exact boundary sequence a blocking drain
        would (``draining=True``)."""
        done: list[FinishedRequest] = []
        while any(r._has_work() for r in self.replicas):
            done.extend(self.step(draining=True))
        self._roll_up()
        return sorted(done, key=lambda r: r.rid)

    def _roll_up(self) -> None:
        self.stats.totals = sum_stats([r.stats for r in self.replicas])

    @property
    def load(self) -> int:
        return sum(r.load for r in self.replicas)
