"""Paged Sidebar KV pool: block-granular cache manager with prefix caching.

``core/sidebar.py`` realizes the paper's scratchpad discipline — explicit
per-location ownership, a recycling free list, protocol errors on
reuse-before-release — for *intra-layer intermediates*. This module lifts
the same discipline up to the serving layer's KV memory: instead of one
max-length cache row per request (slot-granular, PR-3/4), the KV cache is
ONE physical pool of fixed-size **blocks** (``block_size`` token
positions each) and every request owns a *logical block table* mapping
its positions onto pooled blocks.

The allocator mirrors the sidebar protocol deliberately:

  * fixed-size placements recycled through a free list (``SidebarBuffer``
    regions ~ pool blocks);
  * an explicit lifecycle — ``free -> staged -> active`` (+ ``cached``,
    the refcount-0-but-indexed refinement of free) — with
    ``KVPoolError`` raised on any out-of-order transition, the exact
    analogue of ``SidebarProtocolError``'s reuse-before-release;
  * ownership is *refcounted* rather than binary: a block whose content
    is a pure function of a prompt prefix may be owned by several
    requests at once (prefix caching), and a write into shared state
    must copy first (copy-on-write) — the multi-reader generalization
    of the sidebar's single-owner mutex.

Prefix caching is **hash-consed**: a full block whose tokens are
``prompt[: (j+1) * block_size]`` is registered under the byte content of
that whole prefix (a radix-tree path collapsed into its content key —
exact, collision-free, and cheap at serving scale). A later request
whose prompt starts with the same tokens splices the physical block into
its table with a refcount bump instead of recomputing its KV. When the
last owner releases a registered block it becomes ``cached``: still
indexed, evicted LRU only when the free list runs dry.

Device side, the pool is family-agnostic: ``KVPool`` materializes the
model's own ``cache_specs`` with ``batch=num_blocks`` and
``max_len=block_size``, and probes each leaf's batch and length axes
from spec diffs — so GQA 5-D KV, int8 scales, and the MLA latent all
page identically. Attention gathers/scatters through the block table
(``models.attention``); the generic ``gather``/``copy_blocks`` here
serve copy-on-write and test-time reconstruction.

``launch.scheduler.PagedContinuousBatchingServer`` drives this: chunked
prefill-ahead stages pending requests' KV block-by-block between decode
segments, so admission is a block-table splice plus a first decode step
that the following segment program already performs — no synchronous
full-prompt prefill on the admission critical path.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import hashlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.models import layers as L

Array = jax.Array

# Block 0 is the reserved scratch block: free slots' block tables and the
# padded tail of every table point at it, so clamped/dead writes land in
# junk that no unmasked read ever sees (the same stale-KV-behind-the-
# causal-mask argument the slot scheduler already relies on).
SCRATCH_BLOCK = 0


class KVPoolError(RuntimeError):
    """Violation of the block lifecycle / refcount protocol (the serving-
    layer analogue of ``core.sidebar.SidebarProtocolError``)."""


class BlockState(enum.Enum):
    FREE = "free"        # on the free list, content meaningless
    STAGED = "staged"    # allocated; prefill-ahead is writing its KV
    ACTIVE = "active"    # owned (refcount >= 1) by live request(s)
    CACHED = "cached"    # refcount 0 but prefix-indexed; LRU-evictable


def prefix_key(tokens: np.ndarray, end: int) -> bytes:
    """Content key of the prefix ``tokens[:end]`` — the hash-consing key
    a full block is registered under. Byte-exact (no collision risk); a
    radix-tree path collapsed into its content."""
    return np.ascontiguousarray(tokens[:end], dtype=np.int32).tobytes()


def chunk_key(prev: bytes, tokens: np.ndarray) -> bytes:
    """Chained chunk-boundary key: ``H(prev_key || block_tokens)``.

    Semantically IDENTICAL to the whole-prefix byte key — the chain
    covers the block's content, its offset (the chain depth), and its
    entire preceding context, which is exactly what a transformer
    block's KV is a function of — but O(1) bytes per block instead of
    O(prefix) bytes, so a long RAG prompt's per-boundary keys stay
    cheap to compute, store, and probe fleet-wide. The ``ck:`` prefix
    keeps the namespace disjoint from raw whole-prefix keys; SHA-256
    stands in for byte exactness (collisions are not a serving-scale
    concern)."""
    h = hashlib.sha256(prev)
    h.update(np.ascontiguousarray(tokens, dtype=np.int32).tobytes())
    return b"ck:" + h.digest()


def chunk_keys(tokens: np.ndarray, n_blocks: int,
               block_size: int) -> list[bytes]:
    """Chunk-boundary keys for the first ``n_blocks`` full blocks of
    ``tokens``: ``keys[j]`` addresses the block backing positions
    ``[j*bs, (j+1)*bs)`` *in this exact context* (the chain threads
    every preceding block through the digest)."""
    t = np.asarray(tokens, np.int32).reshape(-1)
    keys: list[bytes] = []
    prev = b""
    for j in range(n_blocks):
        prev = chunk_key(prev, t[j * block_size:(j + 1) * block_size])
        keys.append(prev)
    return keys


@dataclasses.dataclass
class PoolCounters:
    """Allocator-level counters surfaced into ``SchedulerStats``."""

    allocs: int = 0
    evictions: int = 0
    cow_copies: int = 0
    prefix_block_lookups: int = 0
    prefix_block_hits: int = 0
    # full prompt[:-1] blocks that entered begin_request — the honest
    # hit-rate denominator (a lookup walk that stopped early would
    # otherwise undercount misses); with the full interior walk below,
    # lookups == prompt_blocks, but the counter keeps the denominator
    # exact by construction rather than by walk policy
    prompt_blocks: int = 0
    # interior splices: hits at a chunk boundary PAST the first miss —
    # the capability whole-prefix-walk prefix caching did not have
    chunk_interior_hits: int = 0
    in_use_peak: int = 0


class BlockAllocator:
    """Host-side block lifecycle: free list, refcounts, prefix index.

    Pure bookkeeping — no device arrays. ``num_blocks`` includes the
    reserved scratch block 0, which is never allocated.
    """

    def __init__(self, num_blocks: int) -> None:
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is the scratch)")
        self.num_blocks = int(num_blocks)
        self._state = [BlockState.FREE] * num_blocks
        self._ref = [0] * num_blocks
        self._state[SCRATCH_BLOCK] = BlockState.ACTIVE  # never handed out
        self._ref[SCRATCH_BLOCK] = 1
        self._free: collections.deque[int] = collections.deque(
            range(1, num_blocks))
        # LRU of cached (refcount-0 but indexed) blocks (an ordered set)
        self._evictable: "collections.OrderedDict[int, None]" = (
            collections.OrderedDict())
        self._index: dict[bytes, int] = {}
        # a block can be addressable under several aliases — its legacy
        # whole-prefix byte key AND its chained chunk-boundary key name
        # the same content — so the reverse map holds every key
        self._keys_of: dict[int, list[bytes]] = {}
        self.counters = PoolCounters()
        # fault-injection hook (launch.faults): consulted at every
        # alloc(); returning True makes the alloc raise KVPoolError —
        # callers' rollback paths must leave state untouched
        self.fault_hook: Callable[[], bool] | None = None

    # -- introspection -----------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable blocks (scratch excluded)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_evictable(self) -> int:
        return len(self._evictable)

    @property
    def in_use(self) -> int:
        """Blocks held by live owners (staged or refcount >= 1)."""
        return self.capacity - self.num_free - self.num_evictable

    @property
    def occupancy(self) -> float:
        return self.in_use / self.capacity

    def state(self, bid: int) -> BlockState:
        return self._state[bid]

    def refcount(self, bid: int) -> int:
        return self._ref[bid]

    def _check(self, bid: int) -> None:
        if not 0 < bid < self.num_blocks:
            raise KVPoolError(f"block id {bid} out of range "
                              f"(1..{self.num_blocks - 1}; 0 is scratch)")

    # -- lifecycle ---------------------------------------------------------
    def can_alloc(self, n: int) -> bool:
        return self.num_free + self.num_evictable >= n

    def alloc(self) -> int:
        """free -> staged. Recycles the free list first; when dry, evicts
        the least-recently-released cached block (dropping its prefix
        index entry). Raises ``KVPoolError`` when nothing is left — the
        scheduler's cue to defer staging until a release frees blocks."""
        if self.fault_hook is not None and self.fault_hook():
            raise KVPoolError(
                "injected allocation failure (fault harness)"
            )
        if self._free:
            bid = self._free.popleft()
        elif self._evictable:
            bid, _ = self._evictable.popitem(last=False)  # LRU
            self._drop_keys(bid)
            self.counters.evictions += 1
        else:
            raise KVPoolError(
                f"KV pool exhausted: all {self.capacity} blocks are "
                "staged or active (no cached block to evict)"
            )
        if self._ref[bid] != 0:
            raise KVPoolError(
                f"block {bid} on the free path with refcount "
                f"{self._ref[bid]} (double allocation)"
            )
        self._state[bid] = BlockState.STAGED
        self._ref[bid] = 1
        self.counters.allocs += 1
        self.counters.in_use_peak = max(self.counters.in_use_peak,
                                        self.in_use)
        return bid

    def activate(self, bid: int) -> None:
        """staged -> active: staging finished, the owning request is
        admitted. Activating a block that was never staged (or is shared)
        is a protocol error."""
        self._check(bid)
        if self._state[bid] is not BlockState.STAGED:
            raise KVPoolError(
                f"activate on block {bid} in state "
                f"{self._state[bid].value!r} (must be staged)"
            )
        self._state[bid] = BlockState.ACTIVE

    def retain(self, bid: int) -> None:
        """Add an owner: a prefix hit on an active or cached block. A
        cached block revives off the eviction list."""
        self._check(bid)
        st = self._state[bid]
        if st is BlockState.CACHED:
            self._evictable.pop(bid)
            self._state[bid] = BlockState.ACTIVE
            self._ref[bid] = 1
            # a revival raises in_use exactly like an allocation does
            self.counters.in_use_peak = max(self.counters.in_use_peak,
                                            self.in_use)
            return
        if st is not BlockState.ACTIVE:
            raise KVPoolError(
                f"retain on block {bid} in state {st.value!r} "
                "(only active/cached blocks can gain owners)"
            )
        self._ref[bid] += 1

    def release(self, bid: int) -> None:
        """Drop one owner. At refcount 0 a prefix-indexed block becomes
        cached (evictable, still addressable by content); an unindexed
        one returns to the free list. Releasing below zero raises."""
        self._check(bid)
        if self._state[bid] is BlockState.FREE or self._ref[bid] < 1:
            raise KVPoolError(
                f"release on block {bid} (state {self._state[bid].value!r}, "
                f"refcount {self._ref[bid]}): refcounts never go negative"
            )
        self._ref[bid] -= 1
        if self._ref[bid] > 0:
            return
        if self._keys_of.get(bid):
            self._state[bid] = BlockState.CACHED
            self._evictable[bid] = None  # most-recently released = MRU
        else:
            self._state[bid] = BlockState.FREE
            self._free.append(bid)

    def evict_cached(self, n: int | None = None) -> int:
        """Force-evict up to ``n`` cached blocks LRU-first (all of them
        when ``n`` is None): drop the prefix-index entries and return
        the blocks to the free list. Live owners are untouched — only
        refcount-0 indexed blocks are evictable, so this can never pull
        a block out from under a request (or a spilled request: spill
        releases every block and restores from host copies). This is
        the eviction-storm injection site and a memory-pressure valve."""
        count = 0
        while self._evictable and (n is None or count < n):
            bid, _ = self._evictable.popitem(last=False)  # LRU
            self._drop_keys(bid)
            self._state[bid] = BlockState.FREE
            self._free.append(bid)
            self.counters.evictions += 1
            count += 1
        return count

    def _drop_keys(self, bid: int) -> None:
        """Eviction half of hash-consing: forget every alias the block
        was addressable under (whole-prefix and chunk keys drop as one
        — they name the same content, so a partial drop could never be
        coherent)."""
        for key in self._keys_of.pop(bid, []):
            del self._index[key]

    # -- prefix index (hash-consing) ---------------------------------------
    def lookup(self, key: bytes) -> int | None:
        self.counters.prefix_block_lookups += 1
        bid = self._index.get(key)
        if bid is not None:
            self.counters.prefix_block_hits += 1
        return bid

    def lookup_any(self, keys) -> int | None:
        """One COUNTED lookup across alias keys naming the same content
        (chunk-boundary key first, whole-prefix key as the fallback).
        However many aliases are probed, the stats see one block-level
        lookup and at most one hit — the hit rate measures content
        reuse, not key-scheme redundancy."""
        self.counters.prefix_block_lookups += 1
        for key in keys:
            bid = self._index.get(key)
            if bid is not None:
                self.counters.prefix_block_hits += 1
                return bid
        return None

    def peek(self, key: bytes) -> int | None:
        """Side-effect-free index probe: no counters, no LRU touch. The
        replica router calls this across the whole fleet per request —
        counting those probes would drown the real hit-rate stats."""
        return self._index.get(key)

    def is_registered(self, bid: int) -> bool:
        """Is the block addressable by content (under any alias)?"""
        return bool(self._keys_of.get(bid))

    def register(self, key: bytes, bid: int) -> int:
        """Hash-cons: publish ``bid`` as THE block for ``key``. If the
        key is already taken (a concurrent request staged the same
        content), the existing block wins and ``bid`` stays a private
        unshared copy — returns the canonical id either way. A block
        may register under several keys (whole-prefix + chunk-boundary
        aliases of the same content); all of them drop together at
        eviction."""
        self._check(bid)
        if self._state[bid] is not BlockState.ACTIVE:
            raise KVPoolError(
                f"register on block {bid} in state "
                f"{self._state[bid].value!r} (must be active: blocks are "
                "published at admission, after staging completes)"
            )
        existing = self._index.get(key)
        if existing is not None:
            return existing
        self._index[key] = bid
        self._keys_of.setdefault(bid, []).append(key)
        return bid


# ---------------------------------------------------------------------------
# Device pool: the model's own cache specs at (batch=num_blocks,
# max_len=block_size), with per-leaf axes probed from spec diffs.
# ---------------------------------------------------------------------------


def _diff_axis(a, b) -> int:
    for i, (x, y) in enumerate(zip(a.shape, b.shape)):
        if x != y:
            return i
    raise ValueError(
        f"cache leaf {a.shape} has no differing axis between probes; "
        "the paged pool cannot address it"
    )


def probe_batch_axes(api, cfg, minfo, max_len: int):
    """Which axis of each cache leaf is the batch (slot/block) axis?
    Diff the spec shapes for batch=2 vs batch=3."""
    s2 = api.cache_specs(cfg, minfo, 2, max_len)
    s3 = api.cache_specs(cfg, minfo, 3, max_len)
    return jax.tree.map(_diff_axis, s2, s3, is_leaf=L.is_spec)


def probe_length_axes(api, cfg, minfo, batch: int):
    """Which axis of each cache leaf is the sequence-length axis? Diff
    the spec shapes for max_len=16 vs max_len=32. Together with the
    batch axis this fully describes how a leaf pages: pool leaves carry
    blocks on the batch axis and ``block_size`` positions on the length
    axis, whatever the family's layout (GQA 5-D KV, int8 scales, MLA
    latent)."""
    s16 = api.cache_specs(cfg, minfo, batch, 16)
    s32 = api.cache_specs(cfg, minfo, batch, 32)
    return jax.tree.map(_diff_axis, s16, s32, is_leaf=L.is_spec)


class KVPool:
    """The physical pooled cache plus generic block-granular device ops.

    ``cache`` is a normal model cache pytree whose probed batch axis has
    ``num_blocks`` entries and probed length axis ``block_size``
    positions — the scheduler hands it (plus block tables) straight to
    the model's decode/prefill steps, where attention scatters/gathers
    through the tables. The helpers here are the *generic* paths used
    off the hot loop: copy-on-write block copies and dense
    reconstruction for tests.
    """

    def __init__(self, api, cfg, minfo, *, num_blocks: int,
                 block_size: int, place=None) -> None:
        self.cfg = cfg
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.batch_axes = probe_batch_axes(api, cfg, minfo, block_size)
        self.length_axes = probe_length_axes(api, cfg, minfo, num_blocks)
        self.cache = api.init_cache(cfg, minfo, num_blocks, block_size)
        if place is not None:
            # tensor-parallel serving: the pool's KV-head axes live on
            # the mesh's "model" axis, block/position axes replicate
            self.cache = place(self.cache)

    def copy_blocks(self, dst: list[int], src: list[int]) -> None:
        """Device copy pool[src] -> pool[dst] on every leaf (the
        copy-on-write primitive). Eager jnp ops — rare path, smoke-scale
        tensors; the hot paths never copy."""
        if not dst:
            return
        d = jnp.asarray(dst, jnp.int32)
        s = jnp.asarray(src, jnp.int32)
        self.cache = jax.tree.map(
            lambda f, ax: f.at[(slice(None),) * ax + (d,)].set(
                jnp.take(f, s, axis=ax)),
            self.cache, self.batch_axes,
        )

    def gather(self, tables) -> dict:
        """Dense per-request cache reconstruction: block tables ``(B,
        nb)`` -> a cache tree shaped exactly like the slot scheduler's
        slab with ``max_len = nb * block_size`` — the bit-exactness
        bridge the segment programs decode through."""
        return gather_blocks(self.cache, self.batch_axes,
                             self.length_axes, tables)

    def read_blocks(self, bids: list[int]) -> list:
        """Device -> host copy of whole blocks, one pytree of numpy
        leaves per block (dtype-preserving, so a later ``write_blocks``
        round-trip is bit-exact). The preemption spill path — rare by
        construction, so eager per-leaf ``jnp.take`` is fine."""
        if not bids:
            return []
        b = jnp.asarray(bids, jnp.int32)
        batch = jax.tree.map(
            lambda f, ax: np.asarray(jnp.take(f, b, axis=ax)),
            self.cache, self.batch_axes,
        )
        return [
            jax.tree.map(
                lambda leaf, ax: np.take(leaf, j, axis=ax),
                batch, self.batch_axes,
            )
            for j in range(len(bids))
        ]

    def write_blocks(self, bids: list[int], payloads: list) -> None:
        """Host -> device: write spilled block payloads (as produced by
        ``read_blocks``) back into pool blocks ``bids`` — the restore
        half of preemption. Bit-exact: same dtypes, whole-block set."""
        if not bids:
            return
        b = jnp.asarray(bids, jnp.int32)
        stacked = jax.tree.map(
            lambda *leaves: np.stack(leaves), *payloads)
        self.cache = jax.tree.map(
            lambda f, s, ax: f.at[(slice(None),) * ax + (b,)].set(
                jnp.moveaxis(jnp.asarray(s), 0, ax).astype(f.dtype)),
            self.cache, stacked, self.batch_axes,
        )


def gather_blocks(cache, batch_axes, length_axes, tables):
    """Pool -> dense slab view, per leaf, jit-traceable. The paged
    segment program runs this ONCE at entry, decodes every step on the
    dense view with the slab scheduler's own (aligned/ragged) machinery,
    and ``scatter_blocks`` writes the blocks back at exit — block
    bookkeeping costs O(1) gathers per segment, not per token. With the
    paged decode kernel this pair is OFF the hot path entirely (the
    ``kernel="slab"`` reference and COW/tests keep it); the dispatch
    record below is the observable tests assert that on."""
    kops.record_dispatch("gather_blocks", "dma")
    t = jnp.asarray(tables, jnp.int32)

    def leaf(f, ba, la):
        g = jnp.take(f, t, axis=ba)              # axis ba -> (B, nb)
        # merge the (nb, block) pair back into one length axis; the
        # batch axis precedes the length axis in every family layout,
        # so the block axis sits at la + 1 after the take
        g = jnp.moveaxis(g, ba + 1, la)
        shape = list(g.shape)
        merged = shape[la] * shape[la + 1]
        return g.reshape(*shape[:la], merged, *shape[la + 2:])

    return jax.tree.map(leaf, cache, batch_axes, length_axes)


def scatter_blocks(cache, dense, batch_axes, length_axes, tables):
    """Dense slab view -> pool, the inverse of ``gather_blocks``.

    Every table entry is written back wholesale. Blocks shared between
    rows (prefix hits) or with the index receive the values they already
    hold — decode only writes positions inside each row's exclusive
    blocks (the copy-on-write invariant) — and duplicate scratch entries
    receive junk nothing reads, so the scatter is order-independent."""
    kops.record_dispatch("scatter_blocks", "dma")
    t = jnp.asarray(tables, jnp.int32)

    def leaf(f, g, ba, la):
        shape = list(g.shape)
        bs = f.shape[la]
        nb = shape[la] // bs
        g = g.reshape(*shape[:la], nb, bs, *shape[la + 1:])
        g = jnp.moveaxis(g, la, ba + 1)          # (…, B, nb, …, bs, …)
        return f.at[(slice(None),) * ba + (t,)].set(g.astype(f.dtype))

    return jax.tree.map(leaf, cache, dense, batch_axes, length_axes)


def validate_tables(tables, num_blocks: int) -> None:
    """Host-side bounds check on a block-table batch before dispatch.

    The device paths deliberately carry NO bounds machinery: the paged
    gathers declare ``mode="promise_in_bounds"`` and the paged-attention
    kernel's table-indexed DMA would read whatever pool row a corrupt
    entry names. This is the promise's enforcement point — cheap numpy
    on a (B, nb) int table, raising ``KVPoolError`` instead of letting
    a stale/sentinel entry silently alias block 0 (the old ``jnp.take``
    clipping behaviour) or a neighbour's block.
    """
    t = np.asarray(tables)
    if t.size == 0:
        return
    lo, hi = int(t.min()), int(t.max())
    if lo < 0 or hi >= num_blocks:
        raise KVPoolError(
            f"block table entry out of range: min {lo}, max {hi} for a "
            f"pool of {num_blocks} blocks — stale or corrupt table"
        )


# ---------------------------------------------------------------------------
# Request-level orchestration: tables, prefix splicing, COW.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestBlocks:
    """One request's logical->physical mapping while it lives in the
    pool: ``bids[j]`` backs positions ``[j*bs, (j+1)*bs)``."""

    bids: list[int]
    prefix_hit_blocks: int      # LEADING bids spliced from the index
    span: int                   # positions covered: len(bids) * bs
    # every spliced block index, interior holes included — a superset
    # of range(prefix_hit_blocks); staging prefills the complement
    hit_idx: tuple[int, ...] = ()

    def table_row(self, width: int) -> np.ndarray:
        row = np.full((width,), SCRATCH_BLOCK, np.int32)
        row[: len(self.bids)] = self.bids
        return row


class PagedKVManager:
    """Allocator + device pool + prefix index, with request-granular ops.

    The scheduler talks to this and to nothing lower: ``begin_request``
    (prefix splice + atomic span allocation, at staging start),
    ``publish_prompt`` (activate + hash-cons full prompt blocks, at
    admission), ``ensure_exclusive`` (copy-on-write before a write into
    a shared block), ``release_request`` (at retirement). Lazy growth
    goes through ``ensure_span`` (the scheduler's ``_grow_active``);
    preemption through ``spill_request``/``restore_request``.

    ``spare_blocks`` appends that many physical rows to the device pool
    WITHOUT registering them with the allocator: their ids are
    ``num_blocks .. num_blocks + spare_blocks - 1`` (``spare_ids``).
    They are scratch — never refcounted, never hash-consed, never
    spilled — and exist for the speculative decoder: drafted tokens
    write into a slot's private spares spliced into its verify table,
    and only ACCEPTED positions are copied into allocator-owned blocks
    (``pool.copy_blocks``). A rejected draft therefore leaves zero
    trace in ``counters`` — not as an accounting convention but because
    the allocator genuinely never saw it.
    """

    def __init__(self, api, cfg, minfo, *, num_blocks: int,
                 block_size: int, place=None,
                 spare_blocks: int = 0) -> None:
        self.block_size = int(block_size)
        self.spare_blocks = int(spare_blocks)
        self.alloc = BlockAllocator(num_blocks)
        self.pool = KVPool(api, cfg, minfo,
                           num_blocks=num_blocks + self.spare_blocks,
                           block_size=block_size, place=place)

    @property
    def spare_ids(self) -> range:
        """Physical ids of the scratch rows past the allocator's reach."""
        return range(self.alloc.num_blocks,
                     self.alloc.num_blocks + self.spare_blocks)

    @property
    def counters(self) -> PoolCounters:
        return self.alloc.counters

    def blocks_needed(self, n_positions: int) -> int:
        return -(-int(n_positions) // self.block_size)

    def _prompt_keys(self, prompt: np.ndarray,
                     n_blocks: int) -> list[tuple[bytes, bytes]]:
        """Per-block alias key pairs (chunk-boundary, whole-prefix) for
        the first ``n_blocks`` full blocks of ``prompt``. Both name the
        same content; publication registers both, probes try both."""
        cks = chunk_keys(prompt, n_blocks, self.block_size)
        return [(cks[j], prefix_key(prompt, (j + 1) * self.block_size))
                for j in range(n_blocks)]

    def _peek_block(self, keys: tuple[bytes, bytes]) -> int | None:
        for key in keys:
            bid = self.alloc.peek(key)
            if bid is not None:
                return bid
        return None

    def prefix_affinity(self, prompt: np.ndarray) -> int:
        """How many leading full ``prompt[:-1]`` blocks this pool already
        holds — the router's steering signal. Pure ``peek``: no counter
        or LRU side effects, so probing every replica per request leaves
        the per-replica prefix stats untouched."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_full = (int(prompt.size) - 1) // self.block_size
        hits = 0
        for keys in self._prompt_keys(prompt, n_full):
            if self._peek_block(keys) is None:
                break
            hits += 1
        return hits

    def chunk_affinity(self, prompt: np.ndarray) -> int:
        """Chunk-granular affinity: how many of the prompt's full
        ``prompt[:-1]`` blocks — interior chunk boundaries INCLUDED,
        not just the leading run — this pool holds. Always >=
        ``prefix_affinity``; the router steers by it so a replica whose
        leading block was evicted but whose retrieved-chunk blocks
        survive still wins the request. Pure ``peek``, like
        ``prefix_affinity``."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_full = (int(prompt.size) - 1) // self.block_size
        return sum(1 for keys in self._prompt_keys(prompt, n_full)
                   if self._peek_block(keys) is not None)

    def check_span(self, rb: RequestBlocks, end: int) -> None:
        """Host-side companion to the device write's ``mode="drop"``:
        a decode segment about to write positions up to ``end - 1``
        must stay inside the request's allocated span. The device path
        silently DROPS out-of-table writes (never corrupting a
        neighbour); this check makes the scheduling bug that would have
        produced them loud instead of a token-quality mystery."""
        if end > rb.span:
            raise KVPoolError(
                f"write frontier {end} exceeds the request's allocated "
                f"span {rb.span} ({len(rb.bids)} blocks of "
                f"{self.block_size}) — segment length outran allocation"
            )

    def begin_request(self, prompt: np.ndarray, n_positions: int
                      ) -> RequestBlocks | None:
        """Start staging: splice every full prompt[:-1] block already in
        the index (refcount bump, zero compute), then allocate fresh
        staged blocks for the rest of the request's whole KV span
        (``n_positions`` = prompt + generation - 1 write positions).

        The walk is chunk-granular and does NOT stop at the first miss:
        a block found at an interior chunk boundary (its chained key
        covers content + offset + full preceding context, so the splice
        is bit-exact by construction) is spliced even when an earlier
        block was evicted — staging then prefills only the holes. The
        stats see every full prompt block as one lookup
        (``prompt_blocks`` is the honest hit-rate denominator) and
        interior splices separately (``chunk_interior_hits``).

        Atomic: returns ``None`` without side effects when the pool
        cannot cover the remainder (the scheduler defers staging)."""
        bs = self.block_size
        need = self.blocks_needed(n_positions)
        n_full = (int(prompt.size) - 1) // bs
        n_walk = min(n_full, need)
        hits: list[tuple[int, int]] = []     # (block index j, bid)
        miss_seen = False
        leading = 0
        for j, keys in enumerate(self._prompt_keys(prompt, n_walk)):
            bid = self.alloc.lookup_any(keys)
            if bid is None:
                miss_seen = True
                continue
            hits.append((j, bid))
            if miss_seen:
                self.counters.chunk_interior_hits += 1
            else:
                leading += 1
        self.counters.prompt_blocks += n_walk
        # retain-then-check: reviving a cached hit removes it from the
        # evictable pool, so availability must be measured AFTER the
        # retains — checking can_alloc first would double-count revived
        # hits as still-evictable and let alloc() raise mid-loop.
        for _, bid in hits:
            self.alloc.retain(bid)
        fresh_needed = need - len(hits)
        fresh: list[int] = []
        try:
            if not self.alloc.can_alloc(fresh_needed):
                raise KVPoolError("pool cannot cover the span")
            for _ in range(fresh_needed):
                fresh.append(self.alloc.alloc())
        except KVPoolError:
            # atomic rollback: an alloc CAN raise past the can_alloc
            # check (injected failure) — the splice's refcount bumps and
            # any partially allocated fresh blocks must all unwind, or
            # the hits leak a reference forever
            for bid in fresh:
                self.alloc.release(bid)
            for _, bid in hits:  # revived hits re-cache
                self.alloc.release(bid)
            return None
        # weave spliced and fresh blocks into table order
        by_idx = dict(hits)
        it = iter(fresh)
        bids = [by_idx[j] if j in by_idx else next(it)
                for j in range(need)]
        return RequestBlocks(bids=bids,
                             prefix_hit_blocks=leading,
                             span=need * bs,
                             hit_idx=tuple(sorted(by_idx)))

    def ensure_span(self, rb: RequestBlocks, n_positions: int) -> bool:
        """Lazy growth: extend ``rb`` with fresh exclusive blocks until
        it covers ``n_positions`` write positions. Allocated blocks go
        straight to active (they back this request's own generated
        tokens — never published, never shared). Atomic: on exhaustion
        or injected failure the partial growth unwinds and the request
        keeps its old span — False is the scheduler's preemption cue."""
        need = self.blocks_needed(n_positions)
        if need <= len(rb.bids):
            return True
        got: list[int] = []
        try:
            for _ in range(need - len(rb.bids)):
                bid = self.alloc.alloc()
                self.alloc.activate(bid)
                got.append(bid)
        except KVPoolError:
            for bid in got:
                self.alloc.release(bid)
            return False
        rb.bids.extend(got)
        rb.span = len(rb.bids) * self.block_size
        return True

    def spill_request(self, rb: RequestBlocks, valid_end: int) -> dict:
        """Preemption: copy the blocks holding the request's first
        ``valid_end`` positions of KV to host, then release EVERY block
        the request owns. The victim keeps no pool references at all —
        shared prefix blocks drop to cached (still evictable; restore
        re-splices them if they survive, rewrites them if not), so a
        spilled request can never be the reason an eviction is unsafe.
        Returns the host payload for a ``SidebarSpillRegion`` entry."""
        n = min(self.blocks_needed(valid_end), len(rb.bids))
        blocks = self.pool.read_blocks(rb.bids[:n])
        nbytes = sum(
            leaf.nbytes for payload in blocks
            for leaf in jax.tree.leaves(payload))
        self.release_request(rb)
        return {"blocks": blocks, "n_blocks": n, "nbytes": nbytes}

    def restore_request(self, prompt: np.ndarray, payload: dict,
                        ) -> RequestBlocks | None:
        """Resume a spilled request: re-acquire one pool block per
        spilled block — splicing any full ``prompt[:-1]`` block still in
        the prefix index (bit-identical by hash-consing; prefill KV is a
        pure function of the prefix) and writing the host copy into a
        fresh block otherwise — then re-publish the full prompt blocks.
        Atomic: on failure every acquired block unwinds and the caller
        keeps the payload (the request stays spilled). The write
        frontier resumes at ``valid_end`` inside an exclusive block, so
        the re-spliced prefix blocks are never written (same structural
        invariant as admission, still enforced by ``ensure_exclusive``).
        """
        bs = self.block_size
        n = payload["n_blocks"]
        n_full = (int(prompt.size) - 1) // bs
        n_walk = min(n_full, n)
        keys = self._prompt_keys(prompt, n_walk)
        acquired: list[tuple[int, bool]] = []   # (bid, spliced?)
        try:
            for j in range(n):
                bid = self.alloc.lookup_any(keys[j]) if j < n_walk else None
                if bid is not None:
                    self.alloc.retain(bid)
                    acquired.append((bid, True))
                else:
                    acquired.append((self.alloc.alloc(), False))
        except KVPoolError:
            for bid, _ in acquired:
                self.alloc.release(bid)
            return None
        fresh = [bid for bid, spliced in acquired if not spliced]
        self.pool.write_blocks(
            fresh,
            [payload["blocks"][j] for j, (_, spliced)
             in enumerate(acquired) if not spliced])
        for bid in fresh:
            self.alloc.activate(bid)
        spliced_js = tuple(j for j, (_, spliced) in enumerate(acquired)
                           if spliced)
        leading = 0
        for j in spliced_js:
            if j != leading:
                break
            leading += 1
        rb = RequestBlocks(
            bids=[bid for bid, _ in acquired],
            prefix_hit_blocks=leading,
            span=n * bs,
            hit_idx=spliced_js,
        )
        # re-publish: restored full prompt blocks re-enter the index
        # under both key families so later requests (and a re-preempted
        # restore) splice them whichever way they probe
        for j in range(n_walk):
            bid = rb.bids[j]
            if not self.alloc.is_registered(bid):
                for key in keys[j]:
                    self.alloc.register(key, bid)
        return rb

    def publish_prompt(self, prompt: np.ndarray, rb: RequestBlocks) -> None:
        """At admission: staged blocks go active, and every full
        prompt[:-1] block is hash-consed into the index — under both its
        whole-prefix key and its chunk-boundary key — so later requests
        splice it whichever way they probe. Spliced hit blocks (possibly
        sparse under interior-hole splicing) are already active and
        registered. (Blocks covering generated positions stay private:
        their future content depends on this request's own sampling
        stream, not on any shareable prefix.)"""
        hit = set(rb.hit_idx) if rb.hit_idx else set(
            range(rb.prefix_hit_blocks))
        n_full = (int(prompt.size) - 1) // self.block_size
        n_walk = min(n_full, len(rb.bids))
        keys = self._prompt_keys(prompt, n_walk)
        for j, bid in enumerate(rb.bids):
            if j in hit:
                continue
            self.alloc.activate(bid)
            if j < n_walk:
                for key in keys[j]:
                    self.alloc.register(key, bid)

    def ensure_exclusive(self, rb: RequestBlocks, block_idx: int) -> bool:
        """Copy-on-write: if the block backing ``block_idx`` is shared
        (refcount > 1) or published (another request could splice it
        between now and the write), divert this request onto a private
        copy before it writes. Returns True when a copy happened.

        The scheduler's structural invariant (sharing covers only full
        prompt[:-1] blocks; writes start at position ``S - 1``) makes
        this a no-op on today's paths — it is the protocol's safety net,
        and the property tests exercise it directly."""
        bid = rb.bids[block_idx]
        shared = (self.alloc.refcount(bid) > 1
                  or self.alloc.is_registered(bid))
        if not shared:
            return False
        new = self.alloc.alloc()       # comes out staged
        self.pool.copy_blocks([new], [bid])
        if self.alloc.state(bid) is BlockState.ACTIVE:
            self.alloc.activate(new)   # the copy mirrors the original
        rb.bids[block_idx] = new
        self.alloc.release(bid)
        self.counters.cow_copies += 1
        return True

    def release_request(self, rb: RequestBlocks) -> None:
        """Retirement: drop this request's ownership of every block.
        Published blocks whose refcount reaches zero stay cached
        (evictable) for future prefix hits; private ones free."""
        for bid in rb.bids:
            self.alloc.release(bid)
        rb.bids = []
