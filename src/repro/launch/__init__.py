"""Substrate package."""
