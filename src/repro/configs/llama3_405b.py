"""llama3-405b [dense]: 126L d=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783]. int8 KV cache for the decode cells
(256 v5e chips cannot hold a 32k bf16 cache at batch 128)."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    activation="silu",
    rope_theta=500000.0,
    kv_cache_dtype=jnp.int8,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3-405b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=256,
        activation="silu",
        rope_theta=500000.0,
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
