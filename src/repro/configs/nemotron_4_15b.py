"""nemotron-4-15b [dense]: 32L d=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 [arXiv:2402.16819]. Squared-ReLU, non-gated MLP — the
paper's 'new activation function' scenario made concrete: the monolithic
design would need a hardware respin for ReLU^2; the sidebar design edits
one function-table row."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    activation="squared_relu",
    gated_mlp=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="nemotron-4-15b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        activation="squared_relu",
        gated_mlp=False,
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
