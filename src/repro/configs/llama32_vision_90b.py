"""llama-3.2-vision-90b [vlm]: 100L d=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, gated cross-attention image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision, scaled]. Vision frontend is a STUB:
input_specs provides patch embeddings (B, 1600, d_model). int8 KV for
decode cells (100 layers x 32k cache)."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1600,
    activation="silu",
    rope_theta=500000.0,
    kv_cache_dtype=jnp.int8,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="llama-3.2-vision-90b-smoke",
        family="vlm",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        cross_attn_every=2,
        num_image_tokens=8,
        activation="silu",
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
