"""deepseek-v3-671b [moe]: 61L d=7168 128H d_ff(expert)=2048
vocab=129280, MoE 1 shared + 256 routed top-8, MLA [arXiv:2412.19437].

MLA dims per the paper: q_lora=1536, kv_lora=512, rope_head=64,
nope_head=128, v_head=128. First 3 layers are dense (d_ff=18432).
MTP (multi-token prediction) is out of scope — noted in DESIGN.md.
The MLA compressed KV cache (576 B/token-layer vs 65 KB for GQA-bf16)
is why this arch decodes comfortably where llama3-405b needs int8 KV.
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,            # dense (first 3) layers
    vocab_size=129280,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,          # per assignment: expert hidden
    first_dense_layers=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    activation="silu",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-v3-671b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=160,
        vocab_size=512,
        num_experts=8,
        experts_per_token=2,
        num_shared_experts=1,
        moe_d_ff=64,
        first_dense_layers=1,
        use_mla=True,
        q_lora_rank=48,
        kv_lora_rank=32,
        rope_head_dim=8,
        nope_head_dim=16,
        v_head_dim=16,
        activation="silu",
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
