"""rwkv6-7b [ssm]: 32L d=4096 (attention-free) d_ff=14336 vocab=65536 —
'Finch' with data-dependent decay [arXiv:2404.05892]. The decay
w = exp(-exp(x)) is a function-table entry ('exp_decay'): the paper's
fast-evolving-function scenario in its purest form."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    activation="squared_relu",   # channel-mix
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-7b-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=128,
        vocab_size=256,
        rwkv_head_dim=16,
        activation="squared_relu",
        dtype=jnp.float32,
    )
