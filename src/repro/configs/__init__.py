"""Config registry: --arch <id> resolution for all assigned architectures."""

from __future__ import annotations

from repro.configs import (
    deepseek_7b,
    deepseek_v3_671b,
    llama3_405b,
    llama4_scout_17b,
    llama32_vision_90b,
    nemotron_4_15b,
    qwen3_14b,
    rwkv6_7b,
    whisper_medium,
    zamba2_7b,
)
from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeCell,
    TrainConfig,
    applicable_shapes,
)

_MODULES = {
    "zamba2-7b": zamba2_7b,
    "llama3-405b": llama3_405b,
    "nemotron-4-15b": nemotron_4_15b,
    "deepseek-7b": deepseek_7b,
    "qwen3-14b": qwen3_14b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "llama4-scout-17b-a16e": llama4_scout_17b,
    "rwkv6-7b": rwkv6_7b,
    "whisper-medium": whisper_medium,
    "llama-3.2-vision-90b": llama32_vision_90b,
}

ARCH_IDS = tuple(_MODULES.keys())
SHAPE_BY_NAME = {c.name: c for c in ALL_SHAPES}


def get_config(arch_id: str) -> ModelConfig:
    try:
        return _MODULES[arch_id].FULL
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}") from None


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke()


def get_shape(name: str) -> ShapeCell:
    return SHAPE_BY_NAME[name]


def all_cells() -> list[tuple[str, str]]:
    """All well-defined (arch, shape) cells — the 40-cell table minus the
    long_500k rows that pure-attention archs skip (DESIGN.md §4)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in applicable_shapes(cfg):
            out.append((arch, cell.name))
    return out
