"""Config system: model architecture + input-shape cells + smoke reduction.

Every assigned architecture gets a ``ModelConfig`` in ``configs/<id>.py``
with the exact published hyperparameters, plus a ``smoke()`` reduction of
the same family for CPU tests. Shape cells (train_4k / prefill_32k /
decode_32k / long_500k) are defined here once and apply per-arch according
to family rules (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (superset across the 10 assigned families)."""

    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # flexible functions (function-table keys) — the paper's swap points
    activation: str = "silu"
    gated_mlp: bool = True
    qk_norm: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0              # per-expert hidden (d_ff if 0)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0    # deepseek-v3: first k layers stay dense

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0            # zamba2: shared attn block period

    # RWKV6
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed frame count (1500 for whisper)

    # VLM
    cross_attn_every: int = 0      # every Nth layer is a cross-attn block
    num_image_tokens: int = 0

    # numerics / engineering
    dtype: Any = jnp.bfloat16
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    scan_layers: bool = True
    remat: str = "full"            # full | dots | none
    use_pallas: bool = False       # route hot ops through Pallas kernels
    kv_cache_dtype: Any = jnp.bfloat16  # int8 => quantized KV (big decode)
    moe_dispatch: str = "shard_map"     # shard_map | dense
    # perf levers (EXPERIMENTS.md §Perf):
    seq_shard_acts: bool = False   # shard saved layer boundaries over "model"
                                   # (sequence parallelism at checkpoints)
    tp_activations: bool = False   # weight-stationary TP: shard activation
                                   # d_model over the fsdp axes; weights are
                                   # never all-gathered (activation psums
                                   # replace FSDP weight gathers)
    cache_in_carry: bool = True    # decode cache as scan CARRY with in-place
                                   # slice updates (donation-aliasable); the
                                   # xs/ys restacking alternative doubles
                                   # peak decode memory (19.4 -> 0.9 GiB/dev
                                   # on deepseek-7b decode_32k, §Perf)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived -----------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid-with-windowing only.)"""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the assignment table."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCell("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ModelConfig) -> list[ShapeCell]:
    """Shape cells that are well-defined for this arch (DESIGN.md §4)."""
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        cells.append(LONG_500K)
    return cells


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    microbatch_per_device: int = 1   # grad-accumulation microbatch size
    moment_dtype: Any = jnp.float32  # bf16 for the largest configs
    grad_compression: str = "none"   # none | bf16 | int8_ef
