"""qwen3-14b [dense]: 40L d=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
[hf:Qwen/Qwen3-8B family] — qk-RMSNorm (an EXTRA flexible op inside
attention: the function-table entry 'rmsnorm' applied to q/k heads)."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    activation="silu",
    rope_theta=1000000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-14b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        head_dim=16,
        qk_norm=True,
        activation="silu",
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
