"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242]. Shared attn block invoked every 6 layers (weights
shared across invocations, per-invocation KV cache).
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,
    activation="silu",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-7b-smoke",
        family="hybrid",
        num_layers=7,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=8,
        attn_every=3,
        activation="silu",
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
