"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + 1 shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E]. Early-fusion multimodality is a
frontend concern; the backbone here is the text MoE decoder."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    activation="silu",
    rope_theta=500000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="llama4-scout-17b-a16e-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        experts_per_token=1,
        num_shared_experts=1,
        moe_d_ff=128,
        activation="silu",
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
