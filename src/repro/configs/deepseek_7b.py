"""deepseek-7b [dense]: 30L d=4096 32H (kv=32, MHA) d_ff=11008
vocab=102400 [arXiv:2401.02954] — llama-architecture."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    activation="silu",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-7b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=192,
        vocab_size=512,
        activation="silu",
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
