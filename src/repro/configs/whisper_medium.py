"""whisper-medium [audio]: 24 enc + 24 dec layers, d=1024 16H (kv=16)
d_ff=4096 vocab=51865 [arXiv:2212.04356]. Conv frontend is a STUB:
input_specs provides precomputed frame embeddings (B, 1500, d_model).
GELU MLPs (non-gated)."""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    encoder_layers=24,
    encoder_seq=1500,
    activation="gelu",
    gated_mlp=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-medium-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        encoder_layers=2,
        encoder_seq=24,
        activation="gelu",
        gated_mlp=False,
        dtype=jnp.float32,
        kv_cache_dtype=jnp.float32,
    )
