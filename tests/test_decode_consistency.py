"""Serving correctness: prefill+decode must equal the no-cache forward.

This is the strongest end-to-end invariant for every cache/state type
(GQA KV, MLA latent, Mamba2 state+conv, RWKV wkv+shifts, hybrid mixes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.configs.base import ShapeCell
from repro.data import pipeline
from repro.models import layers as L
from repro.models.registry import get_model

CELL = ShapeCell("smoke", seq_len=16, global_batch=2, kind="train")
MAX_LEN = 32
DECODE_STEPS = 3

TOL = {}


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    import dataclasses

    cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        # capacity drops legitimately differ between a 34-token forward
        # and a 2-token decode batch; a no-drop capacity factor isolates
        # the cache/state machinery (what this test is about).
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = pipeline.make_batch(cfg, CELL, step=0)
    tokens = batch["tokens"]
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}

    memory = None
    if cfg.family == "audio":
        from repro.models import whisper as W

        memory = W.encode(params, cfg, batch["frames"])
    if cfg.family == "vlm":
        memory = batch["image_embeds"]

    cache = api.init_cache(cfg, L.HOST, CELL.global_batch, MAX_LEN)
    logits_prefill, cache = api.prefill(params, cfg, batch, cache)

    full = api.forward(params, cfg, batch)
    tol = TOL.get(arch, 2e-3)
    np.testing.assert_allclose(
        np.asarray(logits_prefill[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=tol, atol=tol, err_msg=f"{arch}: prefill != forward",
    )

    toks = tokens
    pos = CELL.seq_len
    lgd = None
    for i in range(DECODE_STEPS):
        nxt = tokens[:, i : i + 1]
        lgd, cache = api.decode_step(
            params, cfg, nxt, cache, jnp.int32(pos), memory=memory
        )
        toks = jnp.concatenate([toks, nxt], axis=1)
        pos += 1

    ext_batch = {"tokens": toks, **extra}
    full_ext = api.forward(params, cfg, ext_batch)
    np.testing.assert_allclose(
        np.asarray(lgd[:, 0], np.float32),
        np.asarray(full_ext[:, -1], np.float32),
        rtol=tol, atol=tol,
        err_msg=f"{arch}: {DECODE_STEPS}-step decode != forward",
    )
