"""Recurrence math: chunked algorithms vs step-by-step oracles.

The chunked SSD (Mamba2) and chunked WKV (RWKV6) must match the naive
sequential recurrences exactly — for random decays, dts, and chunk sizes
that do / don't divide the sequence (hypothesis-driven).
"""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.rwkv import wkv_chunked
from repro.models.ssm import mamba2_chunked, mamba2_step


def _ssd_oracle(x, dt, a, b, c, d_skip, h0):
    """Naive per-step SSD recurrence."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    hs = np.asarray(h0).copy()
    ys = []
    for i in range(t):
        decay = np.exp(np.asarray(dt[:, i]) * np.asarray(a)[None, :])  # (B,H)
        inc = np.einsum("bh,bn,bhp->bhnp", np.asarray(dt[:, i]),
                        np.asarray(b[:, i]), np.asarray(x[:, i]))
        hs = decay[..., None, None] * hs + inc
        y = np.einsum("bn,bhnp->bhp", np.asarray(c[:, i]), hs)
        y = y + np.asarray(x[:, i]) * np.asarray(d_skip)[None, :, None]
        ys.append(y)
    return np.stack(ys, axis=1), hs


@given(
    t=st.sampled_from([8, 16, 24, 32]),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_mamba2_chunked_equals_oracle(t, chunk, seed):
    rng = np.random.default_rng(seed)
    bsz, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((bsz, t, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (bsz, t, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, (h,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, t, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bsz, t, n)), jnp.float32)
    dsk = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((bsz, h, n, p)) * 0.1, jnp.float32)

    y, hf = mamba2_chunked(x, dt, a, b, c, dsk, h0, chunk)
    y_ref, hf_ref = _ssd_oracle(x, dt, a, b, c, dsk, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), hf_ref, rtol=2e-4, atol=2e-4)


def test_mamba2_step_equals_chunked_tail():
    rng = np.random.default_rng(1)
    bsz, t, h, p, n = 1, 8, 2, 4, 3
    x = jnp.asarray(rng.standard_normal((bsz, t, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (bsz, t, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, (h,)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, t, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bsz, t, n)), jnp.float32)
    dsk = jnp.zeros((h,), jnp.float32)
    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    y_all, h_all = mamba2_chunked(x, dt, a, b, c, dsk, h0, 4)
    # replay step-by-step
    hs = h0
    for i in range(t):
        y_i, hs = mamba2_step(x[:, i], dt[:, i], a, b[:, i], c[:, i], dsk, hs)
    np.testing.assert_allclose(np.asarray(y_i), np.asarray(y_all[:, -1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(h_all),
                               rtol=2e-4, atol=2e-4)


@given(
    t=st.sampled_from([8, 16, 32]),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 10_000),
    decay_lo=st.floats(0.001, 0.5),
)
@settings(max_examples=25, deadline=None)
def test_wkv_chunked_equals_oracle(t, chunk, seed, decay_lo):
    rng = np.random.default_rng(seed)
    bsz, h, k = 2, 2, 4
    r = jnp.asarray(rng.standard_normal((bsz, t, h, k)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((bsz, t, h, k)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bsz, t, h, k)), jnp.float32)
    w = jnp.asarray(rng.uniform(decay_lo, 0.999, (bsz, t, h, k)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, k)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((bsz, h, k, k)) * 0.1, jnp.float32)

    o, sf = wkv_chunked(r, kk, v, jnp.log(w), u, s0, chunk)

    st_ = np.asarray(s0).copy()
    os = []
    for i in range(t):
        kv = np.einsum("bhk,bhv->bhkv", np.asarray(kk[:, i]), np.asarray(v[:, i]))
        o_i = np.einsum(
            "bhk,bhkv->bhv", np.asarray(r[:, i]),
            st_ + np.asarray(u)[None, :, :, None] * kv,
        )
        st_ = np.asarray(w[:, i])[..., None] * st_ + kv
        os.append(o_i)
    np.testing.assert_allclose(np.asarray(o), np.stack(os, 1),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sf), st_, rtol=3e-4, atol=3e-4)


def test_wkv_extreme_decay_stable():
    """Near-zero decay (w -> 0) must not overflow the chunked form."""
    bsz, t, h, k = 1, 16, 1, 4
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.standard_normal((bsz, t, h, k)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((bsz, t, h, k)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bsz, t, h, k)), jnp.float32)
    w = jnp.full((bsz, t, h, k), 1e-30, jnp.float32)
    u = jnp.zeros((h, k), jnp.float32)
    s0 = jnp.zeros((bsz, h, k, k), jnp.float32)
    o, sf = wkv_chunked(r, kk, v, jnp.log(w), u, s0, 8)
    assert bool(jnp.isfinite(o).all())
    assert bool(jnp.isfinite(sf).all())
