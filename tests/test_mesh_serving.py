"""Mesh-sharded serving: tensor parallelism, the comms model, the fleet.

Fast tests run on the single-device host mesh — the SAME shard_map step
programs as production, with every collective a size-1 identity, so
(1,1)-mesh serving must be BIT-exact against the solo server. The slow
subprocess test forces 8 virtual CPU devices and proves the real thing:
tp=2 paged decode token-exact on GQA / int8-KV / MLA+MoE, the analytic
per-step collective model equal to the HLO-counted bytes, and the TP
divisibility guard.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch.mesh import (
    CANONICAL_AXES,
    make_host_mesh,
    make_serving_mesh,
    mesh_info,
)
from repro.launch.router import ReplicaRouter, sum_stats
from repro.launch.scheduler import (
    PagedContinuousBatchingServer,
    SchedulerStats,
)
from repro.launch.serve import Server
from repro.models.registry import get_model


def _cfg(arch="nemotron-4-15b"):
    cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.fixture(scope="module")
def nemotron():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _traffic(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [
        (rng.randint(0, cfg.vocab_size, size=rng.randint(3, 12))
         .astype(np.int32), int(rng.randint(2, 7)))
        for _ in range(n)
    ]


# -- mesh construction -------------------------------------------------------

def test_host_mesh_axes_and_sizes():
    for multi_pod in (False, True):
        mesh = make_host_mesh(multi_pod=multi_pod)
        assert tuple(mesh.axis_names) == CANONICAL_AXES[
            3 if multi_pod else 2]
        assert all(s == 1 for s in mesh.devices.shape)
        minfo = mesh_info(mesh)
        assert minfo.size("model") == 1
        assert minfo.tp == "model"


def test_mesh_info_rejects_divergent_axis_names():
    from repro.parallel.compat import auto_mesh

    rogue = auto_mesh((1, 1), ("rows", "cols"))
    with pytest.raises(ValueError, match="canonical"):
        mesh_info(rogue)


def test_serving_mesh_rejects_bad_rank():
    with pytest.raises(ValueError, match="rank"):
        make_serving_mesh((1,))
    with pytest.raises(ValueError, match="rank"):
        make_serving_mesh((1, 1, 1, 1))


# -- host-mesh bit-exactness + cache keys ------------------------------------

def test_host_mesh_paged_serving_bit_exact(nemotron):
    """(1,1)-mesh paged serving (shard_map, size-1 collectives) produces
    EXACTLY the solo server's tokens — the identity end of the TP
    correctness bar; the slow test covers the tp=2 end."""
    cfg, params = nemotron
    solo = Server(cfg, params, max_len=48)
    srv = PagedContinuousBatchingServer(
        cfg, params, num_slots=4, max_len=48, block_size=8,
        mesh=make_host_mesh())
    reqs = _traffic(cfg, 5, seed=11)
    for prompt, gen in reqs:
        srv.submit(prompt, gen)
    done = {r.rid: r for r in srv.run()}
    assert len(done) == len(reqs)
    for rid, (prompt, gen) in enumerate(reqs):
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], done[rid].tokens,
            err_msg=f"rid {rid}: host-mesh paged != solo",
        )


def test_executable_cache_keys_carry_mesh(nemotron):
    """Satellite: every paged/stage/segment executable key ends in the
    (mesh shape, axis names) pair — meshless servers record None there,
    so a rebuilt-on-a-mesh server can never replay a stale program."""
    cfg, params = nemotron

    def serve_one(mesh):
        srv = PagedContinuousBatchingServer(
            cfg, params, num_slots=2, max_len=48, block_size=8,
            mesh=mesh)
        srv.submit(np.arange(1, 6, dtype=np.int32), 3)
        srv.run()
        return srv.executable_cache_keys()

    meshless = serve_one(None)
    meshed = serve_one(make_host_mesh())
    assert meshless and meshed
    assert all(k[-1] is None for k in meshless)
    want = ((1, 1), ("data", "model"))
    assert all(k[-1] == want for k in meshed)
    # identical traffic, disjoint key spaces
    assert not set(meshless) & set(meshed)


def test_replicated_tables_stay_valid_under_eviction(nemotron):
    """The host-side block tables are THE replicated metadata of the TP
    design (every shard receives the same (N, nb) int table). Serve
    enough shared-prefix traffic through a deliberately tiny pool to
    force evictions, and check the invariants the device path promises
    on: in-bounds tables at every dispatch (validate_tables raises
    inside run() otherwise), exact tokens, and allocator bookkeeping
    that sums back to capacity."""
    cfg, params = nemotron
    solo = Server(cfg, params, max_len=48)
    srv = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8,
        num_blocks=9, mesh=make_host_mesh())
    rng = np.random.RandomState(5)
    reqs = []
    for i in range(8):
        # unique >=1-full-block prompts: each publishes a prefix block
        # that turns cached on release, so the tiny free list runs dry
        # and later admissions must evict
        prompt = rng.randint(0, cfg.vocab_size,
                             size=int(rng.randint(9, 13))).astype(np.int32)
        reqs.append((prompt, 8))
        srv.submit(prompt, 8)
    done = {r.rid: r for r in srv.run()}
    assert len(done) == len(reqs)
    for rid, (prompt, gen) in enumerate(reqs):
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], done[rid].tokens)
    assert srv.stats.evictions > 0, "pool never came under pressure"
    alloc = srv.mgr.alloc
    assert (alloc.num_free + alloc.num_evictable + alloc.in_use
            == alloc.capacity)
    # drained fleet: no slot still points at real blocks
    assert (srv._tables == 0).all()


# -- replica router ----------------------------------------------------------

def _fleet(cfg, params, n, policy, **kw):
    reps = [
        PagedContinuousBatchingServer(cfg, params, num_slots=2,
                                      max_len=64, block_size=8)
        for _ in range(n)
    ]
    return ReplicaRouter(reps, policy=policy, **kw)


def _prefix_waves(cfg, n_fams=4, waves=3, per_wave=8, seed=7):
    rng = np.random.RandomState(seed)
    fams = [rng.randint(0, cfg.vocab_size, size=24).astype(np.int32)
            for _ in range(n_fams)]
    out = []
    for _ in range(waves):
        wave = []
        for i in range(per_wave):
            tail = rng.randint(0, cfg.vocab_size,
                               size=rng.randint(2, 6)).astype(np.int32)
            wave.append((np.concatenate([fams[i % n_fams], tail]),
                         int(rng.randint(2, 5))))
        out.append(wave)
    return out


def test_router_prefix_affinity_beats_random(nemotron):
    """Shared-prefix waves over 4 replicas: after the seeding wave the
    prefix policy concentrates each family on the replica holding its
    blocks, so the fleet prefix hit rate must beat random spray (and
    affinity routing must actually fire — not win vacuously)."""
    cfg, params = nemotron
    waves = _prefix_waves(cfg)
    rates = {}
    for policy in ("prefix", "random"):
        fleet = _fleet(cfg, params, 4, policy, seed=3)
        fids = []
        for wave in waves:
            fids += [fleet.submit(p, g) for p, g in wave]
            fleet.run()
        assert fleet.load == 0
        rates[policy] = fleet.stats.prefix_hit_rate
        if policy == "prefix":
            assert fleet.stats.affinity_routed > 0
            assert fleet.stats.random_routed == 0
        else:
            assert fleet.stats.random_routed == len(fids)
        # fleet rids are unique and dense
        assert sorted(fids) == list(range(len(fids)))
    assert rates["prefix"] > rates["random"], rates


def test_router_tokens_match_solo_and_stats_roll_up(nemotron):
    cfg, params = nemotron
    solo = Server(cfg, params, max_len=64)
    fleet = _fleet(cfg, params, 2, "prefix")
    reqs = _traffic(cfg, 6, seed=2)
    fids = [fleet.submit(p, g) for p, g in reqs]
    done = {r.rid: r for r in fleet.run()}
    assert sorted(done) == sorted(fids)
    for fid, (prompt, gen) in zip(fids, reqs):
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], done[fid].tokens,
            err_msg=f"fleet fid {fid} != solo",
        )
    totals = fleet.stats.totals
    assert totals.admitted == len(reqs)
    assert totals.segments == sum(r.stats.segments
                                  for r in fleet.replicas)
    assert fleet.stats.requests == len(reqs)


def test_sum_stats_adds_every_counter_field():
    a = SchedulerStats(compiles=1, hits=2, admitted=3, evictions=4)
    b = SchedulerStats(compiles=10, hits=20, admitted=30, evictions=40)
    a.record_ttft(0, 0.1)
    a.record_ttft(1, 0.2)
    b.record_ttft(1, 0.3)
    b.record_itl(0, 0.05)
    s = sum_stats([a, b])
    for f in dataclasses.fields(SchedulerStats):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, dict):
            # latency-sample dicts pool (concatenate) per priority —
            # fleet tails come from the pooled samples, not a sum
            merged = {k: va.get(k, []) + vb.get(k, [])
                      for k in set(va) | set(vb)}
            assert getattr(s, f.name) == merged
        else:
            assert getattr(s, f.name) == va + vb


def test_router_rejects_bad_policy_and_empty_fleet(nemotron):
    cfg, params = nemotron
    with pytest.raises(ValueError, match="policy"):
        _fleet(cfg, params, 1, "round-robin")
    with pytest.raises(ValueError, match="replica"):
        ReplicaRouter([])


def test_prefix_affinity_probe_is_side_effect_free(nemotron):
    cfg, params = nemotron
    srv = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=64, block_size=8)
    prompt = np.arange(1, 20, dtype=np.int32)
    srv.submit(prompt, 4)
    srv.run()
    before = dataclasses.replace(srv.mgr.counters)
    hits = srv.mgr.prefix_affinity(prompt)
    assert hits == (prompt.size - 1) // 8
    assert srv.mgr.counters == before, "peek must not move counters"


# -- the comms model (identity end) ------------------------------------------

def test_tp_step_collectives_zero_at_tp1():
    from repro.launch.roofline import tp_step_collectives

    model = tp_step_collectives(_cfg(), batch=4, tp=1)
    assert all(v == 0.0 for v in model.values())


def test_tp_spec_host_mesh_places_everything(nemotron):
    cfg, params = nemotron
    srv = Server(cfg, params, max_len=32, mesh=make_host_mesh())
    assert srv.tp is not None
    assert srv.tp.size == 1
    assert srv.tp.mesh_key == ((1, 1), ("data", "model"))
    assert srv.tp.cfg_local.num_heads == cfg.num_heads


# -- the real thing: 8 virtual devices, tp=2 ---------------------------------

@pytest.mark.slow
def test_tp2_serving_subprocess():
    """8 forced CPU devices, (4,2) mesh: paged-kernel serving at tp=2 is
    token-exact vs solo for GQA, int8-KV and MLA+MoE (Pallas interpret
    kernels on for the non-quantized families); the analytic collective
    model equals the loop-aware HLO count for step and scanned segment;
    indivisible head counts are rejected."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs as cfglib
from repro.launch import hlo_analysis, roofline
from repro.launch.mesh import make_serving_mesh
from repro.launch.scheduler import PagedContinuousBatchingServer
from repro.launch.serve import Server, make_decode_scan, make_tp_spec
from repro.models.registry import get_model

mesh = make_serving_mesh((4, 2))

def smoke(arch):
    if arch == "nemotron-int8":
        cfg = dataclasses.replace(
            cfglib.get_smoke_config("nemotron-4-15b"),
            kv_cache_dtype=jnp.int8)
    else:
        cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    return cfg

for arch in ("nemotron-4-15b", "nemotron-int8", "deepseek-v3-671b"):
    cfg = smoke(arch)
    if arch != "nemotron-int8":     # int8 KV takes the ref path anyway
        cfg = dataclasses.replace(cfg, use_pallas=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    solo = Server(cfg, params, max_len=48)
    srv = PagedContinuousBatchingServer(
        cfg, params, num_slots=4, max_len=48, block_size=8, mesh=mesh)
    assert srv.tp is not None and srv.tp.size == 2
    rng = np.random.RandomState(3)
    reqs = [(rng.randint(0, cfg.vocab_size, size=rng.randint(3, 12))
             .astype(np.int32), int(rng.randint(2, 7)))
            for _ in range(5)]
    for p, g in reqs:
        srv.submit(p, g)
    done = {r.rid: r for r in srv.run()}
    for rid, (p, g) in enumerate(reqs):
        ref = solo.generate(jnp.asarray(p)[None, :], g, decode="loop")
        got = np.asarray(done[rid].tokens)
        want = np.asarray(ref.tokens)[0, p.size:]
        assert got.tolist() == want.tolist(), (arch, rid, got, want)
    print(arch, "tp2 token-exact")

# comms model == HLO (single step and 6-step scanned segment)
cfg = smoke("nemotron-4-15b")
api = get_model(cfg)
srv = Server(cfg, api.init(jax.random.PRNGKey(1), cfg), max_len=32,
             mesh=mesh)
B = 4
cache = srv.tp.place_cache(api.init_cache(cfg, srv.minfo, B, 32))
toks = jnp.zeros((B, 1), jnp.int32)
for steps in (1, 6):
    scan = make_decode_scan(cfg, api, srv.minfo, mesh, steps, tp=srv.tp)
    comp = jax.jit(scan).lower(
        srv.params, toks, cache, jnp.int32(3), None, None).compile()
    costs = hlo_analysis.analyze_hlo(comp.as_text())
    model = roofline.tp_step_collectives(cfg, batch=B, tp=2, steps=steps)
    assert costs.unknown_trip_loops == 0
    for kind, want in model.items():
        got = costs.coll_bytes.get(kind, 0.0)
        assert got == want, (steps, kind, got, want)
    print("comms model == HLO for", steps, "step(s)")

# divisibility guard
bad = dataclasses.replace(cfg, num_heads=3, num_kv_heads=3, head_dim=8)
try:
    make_tp_spec(bad, get_model(bad), mesh)
except ValueError as e:
    assert "divide" in str(e) or "model" in str(e), e
    print("divisibility guard OK")
else:
    raise AssertionError("indivisible heads were accepted")
print("SUBPROCESS OK")
"""
    res = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"},
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SUBPROCESS OK" in res.stdout
