"""SidebarBuffer protocol model: ownership, placement, capacity."""

import numpy as np
import pytest

from repro.core import DEFAULT_TABLE, Owner, SidebarBuffer, SidebarCall
from repro.core.sidebar import SidebarProtocolError, required_capacity


def test_placement_and_rw():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 256)
    arr = np.arange(64, dtype=np.float32)
    sb.write(Owner.ACCELERATOR, "a", arr)
    out = sb.read(Owner.ACCELERATOR, "a")
    np.testing.assert_array_equal(out, arr)


def test_wrong_owner_raises():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 256)
    with pytest.raises(SidebarProtocolError, match="owned by accelerator"):
        sb.write(Owner.HOST, "a", np.zeros(4, np.float32))


def test_ownership_transfer_counts_handshakes():
    sb = SidebarBuffer(4096)
    sb.pass_ownership(Owner.HOST)
    sb.pass_ownership(Owner.ACCELERATOR)
    assert sb.stats.handshakes == 2
    with pytest.raises(SidebarProtocolError):
        sb.pass_ownership(Owner.ACCELERATOR)  # already owner


def test_capacity_overflow():
    sb = SidebarBuffer(1024)
    with pytest.raises(SidebarProtocolError, match="overflow"):
        sb.allocate("big", 2048)


def test_write_exceeding_region():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    with pytest.raises(SidebarProtocolError, match="exceeds region"):
        sb.write(Owner.ACCELERATOR, "a", np.zeros(64, np.float32))  # 256 B


def test_read_before_write():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    with pytest.raises(SidebarProtocolError, match="never written"):
        sb.read(Owner.ACCELERATOR, "a")


def test_full_invocation_cycle():
    sb = SidebarBuffer(required_capacity((16,), 4, copies=2))
    sb.allocate("in", 64)
    sb.allocate("out", 64)
    x = np.linspace(-1, 1, 16).astype(np.float32)
    sb.write(Owner.ACCELERATOR, "in", x)
    sb.invoke_host(
        SidebarCall("relu", ("in",), ("out",), 16), DEFAULT_TABLE
    )
    out = sb.read(Owner.ACCELERATOR, "out")
    np.testing.assert_allclose(out, np.maximum(x, 0))
    assert sb.owner is Owner.ACCELERATOR
    assert sb.stats.host_invocations == 1


def test_free_all_resets_intermediates_only():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    sb.free_all()
    sb.allocate("a", 64)  # re-placeable after task end
    assert sb.utilization() > 0


# ---------------------------------------------------------------------------
# Spill region: the sidebar ownership discipline, host-side.
# ---------------------------------------------------------------------------

def test_spill_region_lifecycle_and_accounting():
    from repro.core.sidebar import SidebarSpillRegion

    r = SidebarSpillRegion()
    r.stage(7)
    assert 7 in r and len(r) == 1
    r.commit(7, {"blocks": [1, 2]}, 128)
    assert r.in_use_bytes == 128 and r.peak_bytes == 128
    assert r.spills == 1
    assert r.fetch(7) == {"blocks": [1, 2]}         # non-consuming
    assert r.fetch(7)["blocks"] == [1, 2]
    assert r.restores == 2
    r.release(7)
    assert 7 not in r and r.in_use_bytes == 0
    assert r.peak_bytes == 128                      # high-water sticks


def test_spill_region_rejects_out_of_order_transitions():
    from repro.core.sidebar import SidebarProtocolError, SidebarSpillRegion

    r = SidebarSpillRegion()
    with pytest.raises(SidebarProtocolError, match="commit"):
        r.commit(1, None, 0)                        # commit before stage
    with pytest.raises(SidebarProtocolError, match="fetch"):
        r.stage(1) or r.fetch(1)                    # fetch uncommitted
    with pytest.raises(SidebarProtocolError, match="already"):
        r.stage(1)                                  # double stage
    r.commit(1, "x", 4)
    with pytest.raises(SidebarProtocolError, match="commit"):
        r.commit(1, "y", 4)                         # double commit
    r.release(1)
    with pytest.raises(SidebarProtocolError, match="release"):
        r.release(1)                                # double release


def test_spill_region_capacity_bound():
    from repro.core.sidebar import SidebarProtocolError, SidebarSpillRegion

    r = SidebarSpillRegion(capacity_bytes=100)
    r.stage(1)
    r.commit(1, "a", 80)
    r.stage(2)
    with pytest.raises(SidebarProtocolError, match="capacity"):
        r.commit(2, "b", 40)
    r.release(1)
    r.commit(2, "b", 40)                            # fits after release
    assert r.in_use_bytes == 40
