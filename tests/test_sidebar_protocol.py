"""SidebarBuffer protocol model: ownership, placement, capacity."""

import numpy as np
import pytest

from repro.core import DEFAULT_TABLE, Owner, SidebarBuffer, SidebarCall
from repro.core.sidebar import SidebarProtocolError, required_capacity


def test_placement_and_rw():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 256)
    arr = np.arange(64, dtype=np.float32)
    sb.write(Owner.ACCELERATOR, "a", arr)
    out = sb.read(Owner.ACCELERATOR, "a")
    np.testing.assert_array_equal(out, arr)


def test_wrong_owner_raises():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 256)
    with pytest.raises(SidebarProtocolError, match="owned by accelerator"):
        sb.write(Owner.HOST, "a", np.zeros(4, np.float32))


def test_ownership_transfer_counts_handshakes():
    sb = SidebarBuffer(4096)
    sb.pass_ownership(Owner.HOST)
    sb.pass_ownership(Owner.ACCELERATOR)
    assert sb.stats.handshakes == 2
    with pytest.raises(SidebarProtocolError):
        sb.pass_ownership(Owner.ACCELERATOR)  # already owner


def test_capacity_overflow():
    sb = SidebarBuffer(1024)
    with pytest.raises(SidebarProtocolError, match="overflow"):
        sb.allocate("big", 2048)


def test_write_exceeding_region():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    with pytest.raises(SidebarProtocolError, match="exceeds region"):
        sb.write(Owner.ACCELERATOR, "a", np.zeros(64, np.float32))  # 256 B


def test_read_before_write():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    with pytest.raises(SidebarProtocolError, match="never written"):
        sb.read(Owner.ACCELERATOR, "a")


def test_full_invocation_cycle():
    sb = SidebarBuffer(required_capacity((16,), 4, copies=2))
    sb.allocate("in", 64)
    sb.allocate("out", 64)
    x = np.linspace(-1, 1, 16).astype(np.float32)
    sb.write(Owner.ACCELERATOR, "in", x)
    sb.invoke_host(
        SidebarCall("relu", ("in",), ("out",), 16), DEFAULT_TABLE
    )
    out = sb.read(Owner.ACCELERATOR, "out")
    np.testing.assert_allclose(out, np.maximum(x, 0))
    assert sb.owner is Owner.ACCELERATOR
    assert sb.stats.host_invocations == 1


def test_free_all_resets_intermediates_only():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    sb.free_all()
    sb.allocate("a", 64)  # re-placeable after task end
    assert sb.utilization() > 0
