"""Paged scheduler: bit-exact tokens through the block pool.

The strongest invariant, extended from the slab scheduler's: serving
through the paged KV pool — prefix-cache hits, chunked prefill-ahead,
copy-on-write, admission fused into the segment program — produces, per
request, EXACTLY the tokens a solo ``Server.generate`` (and therefore
the PR-4 slab scheduler, which is tested against the same reference)
produces. Paging is a memory-layout choice, never a numerics choice —
on the GQA, int8-KV, and MLA+MoE cache families alike.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch.sampling import SamplingParams
from repro.launch.scheduler import (
    ContinuousBatchingServer,
    PagedContinuousBatchingServer,
    SchedulerStats,
)
from repro.launch.serve import Server
from repro.models.registry import get_model

ARCHS = ["nemotron-4-15b", "nemotron-int8", "deepseek-v3-671b"]


def _cfg(arch: str):
    if arch == "nemotron-int8":
        cfg = dataclasses.replace(
            cfglib.get_smoke_config("nemotron-4-15b"),
            kv_cache_dtype=jnp.int8,
        )
    else:
        cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        # no-drop capacity: chunk boundaries (like bucket padding) must
        # not change expert routing — see the scheduler docstring
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.fixture(scope="module")
def served():
    out = {}
    for arch in ARCHS:
        cfg = _cfg(arch)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        out[arch] = (cfg, params, Server(cfg, params, max_len=48))
    return out


def _traffic(cfg, n, seed=0, max_prompt=14):
    rng = np.random.RandomState(seed)
    return [
        (rng.randint(0, cfg.vocab_size, size=rng.randint(2, max_prompt))
         .astype(np.int32), int(rng.randint(1, 9)))
        for _ in range(n)
    ]


def _check_exact(solo, done, reqs, arch=""):
    for r in done:
        prompt, gen = reqs[r.rid]
        assert r.generated == gen
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], r.tokens,
            err_msg=f"{arch} rid {r.rid}: paged != solo decode",
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_matches_solo_decode(arch, served):
    """Mixed lengths, more requests than slots, chunked prefill-ahead
    smaller than prompts — every family decodes the solo tokens."""
    cfg, params, solo = served[arch]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=3, max_len=48, block_size=8,
        prefill_chunk=8, segment=4)
    reqs = _traffic(cfg, 7, seed=3)
    rids = [sched.submit(p, g) for p, g in reqs]
    done = sched.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    _check_exact(solo, done, reqs, arch)
    assert all(s.free for s in sched.slots)
    assert sched.stats.stage_chunks > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefix_cache_hits_are_token_exact(arch, served):
    """Shared-prefix traffic: the second wave splices cached blocks
    (prefix_block_hits > 0) and still produces solo-exact tokens —
    including a request whose prompt extends a cached prefix."""
    cfg, params, solo = served[arch]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=4,
        prefill_chunk=4, segment=4)
    rng = np.random.RandomState(11)
    system = rng.randint(0, cfg.vocab_size, size=9).astype(np.int32)
    reqs = []
    for i in range(4):
        tail = rng.randint(0, cfg.vocab_size, size=3 + i).astype(np.int32)
        reqs.append((np.concatenate([system, tail]), 4))
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    _check_exact(solo, done, reqs, arch)
    assert sched.stats.prefix_block_hits > 0
    assert 0 < sched.stats.prefix_hit_rate <= 1
    # retired requests' published blocks stay cached: a fresh identical
    # prompt hits without any staging compute for the shared blocks
    hits0 = sched.stats.prefix_block_hits
    sched.submit(reqs[0][0], 4)
    (r,) = sched.run()
    _check_exact(solo, [r], {r.rid: reqs[0]}, arch)
    assert sched.stats.prefix_block_hits > hits0


def test_edge_prompts_single_token_and_block_boundary(served):
    """S=1 (no staging at all — straight to the fused correction step)
    and a prompt whose last token sits exactly on a block boundary."""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8, segment=4)
    single = np.asarray([7], np.int32)
    exact = np.arange(1, 10, dtype=np.int32)     # S-1 == block_size
    sched.submit(single, 6)
    sched.submit(exact, 6)
    done = sched.run()
    for r, p in zip(done, (single, exact)):
        ref = solo.generate(jnp.asarray(p)[None, :], 6, decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, p.size:], r.tokens)


def test_paged_matches_slab_scheduler_tokens(served):
    """Same traffic through the slab scheduler and the paged scheduler:
    identical tokens, request for request."""
    cfg, params, _ = served["nemotron-4-15b"]
    reqs = _traffic(cfg, 6, seed=7)
    slab = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                    buckets=(8,), segment=4)
    paged = PagedContinuousBatchingServer(cfg, params, num_slots=2,
                                          max_len=48, block_size=8,
                                          segment=4)
    for p, g in reqs:
        slab.submit(p, g)
        paged.submit(p, g)
    a, b = slab.run(), paged.run()
    assert len(a) == len(b) == 6
    for ra, rb in zip(a, b):
        assert ra.rid == rb.rid
        np.testing.assert_array_equal(ra.tokens, rb.tokens)


@pytest.mark.parametrize("arch", ["nemotron-4-15b", "deepseek-v3-671b"])
def test_sampled_paged_decode_matches_solo(arch, served):
    """Sampled requests (mixed with greedy neighbours) keep their exact
    position-keyed streams through staging, splicing, and the fused
    admission step."""
    cfg, params, solo = served[arch]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8, segment=3)
    sp = SamplingParams(temperature=0.8, top_k=40, seed=13)
    rng = np.random.RandomState(5)
    reqs = [(rng.randint(0, cfg.vocab_size, size=n).astype(np.int32), 5)
            for n in (3, 9, 6)]
    sched.submit(reqs[0][0], 5, sample=sp)
    sched.submit(reqs[1][0], 5)
    sched.submit(reqs[2][0], 5, sample=sp)
    done = sched.run()
    for r in done:
        prompt, gen = reqs[r.rid]
        sample = sp if r.rid in (0, 2) else None
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop", sample=sample)
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], r.tokens)


def test_admission_is_fused_into_segment(served):
    """One dispatch per scheduler iteration: the executable cache holds
    ONLY staging and fused-segment programs — no separate admission/
    prefill program ever compiles (the slab scheduler compiles both)."""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8, segment=4)
    reqs = _traffic(cfg, 5, seed=9)
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    _check_exact(solo, done, reqs)
    kinds = {k[0] for k in sched.executable_cache_keys()}
    assert kinds <= {"stage", "pseg"}, kinds
    admitting = [k for k in sched.executable_cache_keys()
                 if k[0] == "pseg" and k[5] > 0]
    assert admitting, "no segment program carried fused admissions"


def test_repeat_traffic_never_recompiles(served):
    cfg, params, _ = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8, segment=4)
    wave = _traffic(cfg, 4, seed=5)
    for p, g in wave:
        sched.submit(p, g)
    sched.run()
    compiles = sched.stats.compiles
    keys = sched.executable_cache_keys()
    for p, g in wave:
        sched.submit(p, g)
    sched.run()
    assert sched.stats.compiles == compiles
    assert sched.executable_cache_keys() == keys


def test_pool_pressure_stalls_then_recovers(served):
    """A pool too small for every live request: pressure is recorded
    (a staging stall, an unstaged entry, or a preemption — allocation
    is lazy now, so full spans materialize segment by segment and the
    squeeze can land on any of the three), requests drain in waves as
    blocks free, tokens stay exact, and nothing leaks."""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=32, block_size=8,
        num_blocks=7, segment=4)     # 6 allocatable = 2 full spans
    rng = np.random.RandomState(21)
    reqs = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 12)
            for _ in range(5)]       # 3 blocks each, fully grown
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == 5
    _check_exact(solo, done, reqs)
    assert (sched.stats.stage_stalls + sched.stats.unstaged
            + sched.stats.preemptions) > 0
    assert sched.mgr.alloc.in_use == 0          # nothing leaked
    assert sched.mgr.alloc.num_free + sched.mgr.alloc.num_evictable \
        == sched.mgr.alloc.capacity


def test_oversized_request_rejected_up_front(served):
    cfg, params, _ = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=1, max_len=32, block_size=8, num_blocks=3)
    with pytest.raises(ValueError, match="blocks"):
        sched.submit(np.arange(1, 20, dtype=np.int32), 10)
    with pytest.raises(ValueError, match="multiple"):
        PagedContinuousBatchingServer(cfg, params, num_slots=1,
                                      max_len=30, block_size=8)


def test_scheduler_stats_typed_and_printable(served):
    """The satellite: stats are a typed dataclass with the dict-style
    compat shim, derived rates, and a printable summary."""
    cfg, params, _ = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8, segment=4)
    assert isinstance(sched.stats, SchedulerStats)
    for p, g in _traffic(cfg, 4, seed=2):
        sched.submit(p, g)
    sched.run()
    assert sched.stats["compiles"] == sched.stats.compiles  # shim
    assert sched.stats.pool_blocks == sched.mgr.alloc.capacity
    assert 0 <= sched.stats.pool_occupancy <= 1
    assert 0 <= sched.stats.exec_hit_rate <= 1
    text = sched.stats.summary()
    assert "kv pool" in text and "prefix hit rate" in text
    # the slab scheduler shares the same stats type, pool fields dormant
    slab = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48)
    assert isinstance(slab.stats, SchedulerStats)
    assert slab.stats.pool_blocks == 0
    assert "kv pool" not in slab.stats.summary()
