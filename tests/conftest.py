import os
import sys

# Tests run on the single real CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process). Keep compilation deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running subprocess tests (deselect with -m 'not slow')",
    )
