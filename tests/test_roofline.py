"""Roofline extraction: scan-once verification + loop-aware HLO analysis."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
from repro.launch.flops import (
    analytic_fwd_flops,
    analytic_step_flops,
    scan_correction,
)
from repro import configs as cfglib


def test_cost_analysis_counts_while_body_once():
    """The XLA behaviour §Roofline corrects for — pinned by this test."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    fl = xla_cost_analysis(c)["flops"]
    one = 2 * 64 * 64 * 64
    assert fl == pytest.approx(one, rel=0.05), (
        "XLA now trip-counts while loops — drop the scan corrections!"
    )


def test_loop_aware_analysis_recovers_trip_counts():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    costs = analyze_hlo(compiled.as_text())
    assert costs.unknown_trip_loops == 0
    assert any(t == 10 for _, t in costs.loops)
    one = 2 * 64 * 64 * 64
    assert costs.dot_flops == pytest.approx(10 * one, rel=0.05)


def test_loop_aware_nested_scans():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    costs = analyze_hlo(compiled.as_text())
    one = 2 * 32 * 32 * 32
    assert costs.dot_flops == pytest.approx(15 * one, rel=0.05)


@pytest.mark.slow
def test_collective_bytes_counted():
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.launch.hlo_analysis import analyze_hlo
from repro.parallel.compat import auto_mesh
mesh = auto_mesh((8,), ("model",))
def f(x, w):
    return jnp.sum(x @ w)
xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                 NamedSharding(mesh, P("model", None)))).lower(xs, ws).compile()
costs = analyze_hlo(c.as_text())
assert costs.coll_bytes_total > 0, costs.coll_bytes
print("OK", costs.coll_bytes_total)
"""
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout


def test_analytic_flops_matches_6nd_for_dense():
    """At short seq the exact model must approach 2·N·D fwd (embeddings
    excluded from N, attention small)."""
    from repro.launch.roofline import count_params
    from repro.models import layers as L
    from repro.models.registry import get_model

    cfg = cfglib.get_config("deepseek-7b")
    api = get_model(cfg)
    total, emb, _ = count_params(api.param_specs(cfg, L.HOST))
    n = total - emb
    tokens = 256 * 4096
    fwd = analytic_fwd_flops(cfg, tokens, batch=256)
    # subtract the unembed term the 2ND convention excludes
    from repro.models.layers import padded_vocab
    fwd_no_unembed = fwd - 2.0 * tokens * cfg.d_model * padded_vocab(cfg.vocab_size)
    ratio = fwd_no_unembed / (2.0 * n * tokens)
    assert 0.95 < ratio < 1.25, ratio  # attention adds ~7% at 4k


def test_scan_correction_shapes():
    for arch in cfglib.ARCH_IDS:
        cfg = cfglib.get_config(arch)
        cell = cfglib.get_shape("train_4k")
        k = scan_correction(cfg, cell, n_micro=16)
        assert k >= 16, (arch, k)
        k1 = scan_correction(cfg, cfglib.get_shape("decode_32k"), 1)
        assert k1 >= 1


def test_analytic_step_flops_positive_all_cells():
    for arch, shape in cfglib.all_cells():
        cfg = cfglib.get_config(arch)
        cell = cfglib.get_shape(shape)
        f = analytic_step_flops(cfg, cell)
        assert f > 0, (arch, shape)
        if cell.kind == "train":
            assert f > analytic_step_flops(
                cfg, cfglib.get_shape("prefill_32k")
            ) * 0.5  # train >> one fwd at comparable token counts
