"""Fault tolerance: watchdog behaviour + restartable trainer."""

import numpy as np
import pytest

from repro import configs as cfglib
from repro.configs.base import ShapeCell, TrainConfig
from repro.ft.watchdog import StragglerWatchdog, Verdict
from repro.launch.train import Trainer

CELL = ShapeCell("smoke", seq_len=16, global_batch=4, kind="train")


# --------------------------- watchdog ---------------------------------------

def test_watchdog_quiet_on_steady_steps():
    wd = StragglerWatchdog(min_samples=4)
    for _ in range(50):
        assert wd.observe(0.10) is Verdict.OK
    assert wd.history == []


def test_watchdog_flags_stragglers_and_escalates():
    wd = StragglerWatchdog(min_samples=4, warn_after=2, evict_after=4)
    for _ in range(16):
        wd.observe(0.10)
    verdicts = [wd.observe(1.0) for _ in range(4)]
    assert verdicts[0] is Verdict.OK      # single event: log only
    assert verdicts[1] is Verdict.WARN
    assert verdicts[3] is Verdict.EVICT
    assert len(wd.history) == 4


def test_watchdog_straggler_not_poisoning_baseline():
    wd = StragglerWatchdog(min_samples=4)
    for _ in range(16):
        wd.observe(0.10)
    wd.observe(10.0)  # huge outlier
    assert abs(wd.median_step_s - 0.10) < 1e-9  # baseline unchanged


def test_watchdog_tolerates_jitter():
    wd = StragglerWatchdog(min_samples=8)
    rng = np.random.default_rng(0)
    for _ in range(100):
        v = wd.observe(0.1 + rng.normal(0, 0.004))
        assert v is Verdict.OK


# --------------------------- trainer ----------------------------------------

@pytest.fixture
def tiny():
    cfg = cfglib.get_smoke_config("deepseek-7b")
    tcfg = TrainConfig(microbatch_per_device=4, warmup_steps=2,
                       learning_rate=1e-3)
    return cfg, tcfg


def test_trainer_runs_and_checkpoints(tmp_path, tiny):
    cfg, tcfg = tiny
    tr = Trainer(cfg, tcfg, CELL, ckpt_dir=str(tmp_path), ckpt_every=2)
    rep = tr.run(4)
    assert rep.steps_run == 4
    assert tr.ckpt.latest_step() == 4
    assert np.isfinite(rep.final_loss)


def test_trainer_resume_is_bitwise_deterministic(tmp_path, tiny):
    """Kill after step 3, resume, finish at 6 == uninterrupted 6-step run.
    This is the checkpoint/restart contract at the heart of FT."""
    cfg, tcfg = tiny
    a = Trainer(cfg, tcfg, CELL, ckpt_dir=str(tmp_path / "a"), ckpt_every=3)
    rep_a = a.run(3)          # 'preemption' right after a checkpoint
    assert a.ckpt.latest_step() == 3
    a2 = Trainer(cfg, tcfg, CELL, ckpt_dir=str(tmp_path / "a"), ckpt_every=3)
    rep_resumed = a2.run(6)
    assert rep_resumed.resumed_from == 3

    b = Trainer(cfg, tcfg, CELL, ckpt_dir=str(tmp_path / "b"), ckpt_every=100)
    rep_b = b.run(6)

    np.testing.assert_allclose(rep_resumed.losses, rep_b.losses[3:],
                               rtol=1e-6, atol=1e-6)


def test_trainer_eviction_hook_fires(tmp_path, tiny):
    cfg, tcfg = tiny
    evicted = []
    wd = StragglerWatchdog(min_samples=2, warn_after=1, evict_after=2)
    tr = Trainer(
        cfg, tcfg, CELL, ckpt_dir=str(tmp_path), ckpt_every=100,
        watchdog=wd, on_evict=lambda: evicted.append(True),
    )
    # steps 0-5 normal, 6+ straggling badly (simulated slow host)
    times = lambda step: 0.1 if step < 6 else 5.0
    rep = tr.run(9, inject_step_times=times)
    assert rep.straggler_events >= 2
    assert rep.evictions >= 1 and evicted
    # eviction checkpointed synchronously
    assert tr.ckpt.latest_step() is not None


def test_trainer_data_stream_restart_alignment(tiny):
    """Data pipeline is step-keyed: the resumed stream must serve the
    same batches the original run would have seen."""
    from repro.data import pipeline

    cfg, _ = tiny
    b1 = pipeline.make_batch(cfg, CELL, step=7)
    b2 = pipeline.make_batch(cfg, CELL, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipeline.make_batch(cfg, CELL, step=8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


# ---------------------------------------------------------------------------
# Serving-side segment watchdog (non-fatal straggler events).
# ---------------------------------------------------------------------------

def test_segment_watchdog_quiet_on_steady_segments():
    from repro.ft.watchdog import SegmentWatchdog

    w = SegmentWatchdog(min_samples=4)
    assert not any(w.observe(0.1 + 0.001 * i) for i in range(50))
    assert w.events == []
    assert abs(w.median_segment_s - 0.12) < 0.02


def test_segment_watchdog_records_stall_and_keeps_baseline():
    from repro.ft.watchdog import SegmentWatchdog

    w = SegmentWatchdog(k=8.0, min_samples=4)
    for _ in range(8):
        w.observe(0.1)
    assert w.observe(2.0)                  # 20x median: event
    ev = w.events[-1]
    assert ev.seconds == 2.0 and abs(ev.median - 0.1) < 1e-9
    assert ev.threshold == 8.0 * ev.median
    # the stall is EXCLUDED from the baseline: the next normal segment
    # is judged against the same median, and a second stall still trips
    assert abs(w.median_segment_s - 0.1) < 1e-9
    assert not w.observe(0.1)
    assert w.observe(2.0)
    assert len(w.events) == 2


def test_segment_watchdog_warms_up_before_judging():
    from repro.ft.watchdog import SegmentWatchdog

    w = SegmentWatchdog(min_samples=8)
    # huge variance during warm-up: never an event
    for t in (0.001, 5.0, 0.001, 5.0, 0.001):
        assert not w.observe(t)
    with pytest.raises(ValueError, match="k must be"):
        SegmentWatchdog(k=1.0)


def test_segment_watchdog_wired_into_drain_loop():
    """An injected slow segment (fake timer) during a real paged drain
    lands in ``SchedulerStats.watchdog_events`` — and changes nothing
    about the tokens (non-fatal by design)."""
    import jax

    from repro.launch.scheduler import PagedContinuousBatchingServer
    from repro.models.registry import get_model as _gm

    cfg = cfglib.get_smoke_config("nemotron-4-15b")
    api = _gm(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    from repro.ft.watchdog import SegmentWatchdog

    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=32, block_size=8, segment=2)
    sched.watchdog = SegmentWatchdog(k=8.0, min_samples=2)
    # fake timer: each call pair brackets one segment dispatch; the
    # third segment "takes" ~1000x the baseline wall time
    ticks = [0]

    def timer():
        ticks[0] += 1
        return 1000.0 * ticks[0] if ticks[0] == 6 else float(ticks[0])

    sched._timer = timer
    rng = np.random.RandomState(0)
    for _ in range(6):
        sched.submit(
            rng.randint(0, cfg.vocab_size, size=5).astype(np.int32), 8)
    done = sched.run()
    assert len(done) == 6
    assert sched.stats.watchdog_events >= 1
    assert len(sched.watchdog.events) == sched.stats.watchdog_events
