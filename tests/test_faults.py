"""Fault injection: the robustness claims, made to fail on demand.

The ``FaultInjector`` is consulted at named sites (alloc, evict_storm,
stage_stall, dispatch:i) and is a pure function of (seed, consultation
order) — so a faulted serving run REPLAYS exactly, and the property
sweep can assert the strong invariants under many seeded interleavings:
every request still drains, tokens stay bit-exact against the unfaulted
solo reference, no pool block leaks, no spill-region entry survives,
and the fleet quarantines a flapping replica instead of wedging on it.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch.faults import FaultInjector, FaultRecord
from repro.launch.router import ReplicaRouter
from repro.launch.scheduler import PagedContinuousBatchingServer
from repro.launch.serve import Server
from repro.models.registry import get_model


def _cfg(arch="nemotron-4-15b"):
    cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.fixture(scope="module")
def nemotron():
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params, Server(cfg, params, max_len=48)


def _traffic(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [
        (rng.randint(0, cfg.vocab_size, size=rng.randint(3, 10))
         .astype(np.int32), int(rng.randint(2, 8)))
        for _ in range(n)
    ]


def _check_exact(solo, done, reqs):
    for r in done:
        prompt, gen = reqs[r.rid]
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], r.tokens)


def _assert_quiescent(sched):
    assert sched.mgr.alloc.in_use == 0
    assert (sched.mgr.alloc.num_free + sched.mgr.alloc.num_evictable
            == sched.mgr.alloc.capacity)
    assert len(sched.spill) == 0 and sched.spill.in_use_bytes == 0


# ---------------------------------------------------------------------------
# The injector itself.
# ---------------------------------------------------------------------------

def test_injector_is_deterministic_per_seed():
    logs = []
    for _ in range(2):
        fi = FaultInjector(7, rates={"alloc": 0.3, "dispatch": 0.2})
        for i in range(200):
            fi.fire("alloc")
            fi.fire(f"dispatch:{i % 3}")
        logs.append(list(fi.log))
    assert logs[0] == logs[1] and len(logs[0]) > 0
    other = FaultInjector(8, rates={"alloc": 0.3, "dispatch": 0.2})
    for i in range(200):
        other.fire("alloc")
        other.fire(f"dispatch:{i % 3}")
    assert other.log != logs[0]          # the seed matters


def test_injector_script_fires_exact_calls():
    fi = FaultInjector(0, script={"alloc": [2, 5]})
    hits = [fi.fire("alloc") for _ in range(6)]
    assert hits == [False, True, False, False, True, False]
    assert fi.log == [FaultRecord("alloc", 2), FaultRecord("alloc", 5)]
    assert fi.total_injected == 2


def test_injector_base_site_rate_covers_indexed_sites():
    fi = FaultInjector(0, rates={"dispatch": 1.0})
    assert fi.fire("dispatch:0") and fi.fire("dispatch:3")
    assert not fi.fire("alloc")          # unconfigured site never fires
    specific = FaultInjector(0, rates={"dispatch:1": 1.0})
    assert specific.fire("dispatch:1")
    assert not specific.fire("dispatch:0")   # exact key wins over base


def test_injector_max_per_site_bounds_storms():
    fi = FaultInjector(0, rates={"alloc": 1.0}, max_per_site=3)
    hits = sum(fi.fire("alloc") for _ in range(50))
    assert hits == 3
    # scripted fires are exempt from the budget (pinpoint tests)
    fi2 = FaultInjector(0, script={"alloc": [1]}, max_per_site=0)
    assert fi2.fire("alloc")


# ---------------------------------------------------------------------------
# Seeded end-to-end: faulted runs drain bit-exact with zero leaks.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_faulted_drain_is_bitexact_and_leak_free(seed, nemotron):
    """Allocation failures, eviction storms and staging stalls all
    land mid-run (tight pool so the alloc site is consulted under real
    pressure too) — and the OUTPUT cannot tell: every request drains
    with solo-exact tokens, the pool returns to empty, the spill
    region holds nothing."""
    cfg, params, solo = nemotron
    faults = FaultInjector(seed, rates={
        "alloc": 0.10, "evict_storm": 0.15, "stage_stall": 0.15,
    }, max_per_site=8)
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8,
        num_blocks=8, segment=4, faults=faults)
    reqs = _traffic(cfg, 6, seed=seed + 10)
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == len(reqs)
    assert faults.total_injected > 0, "no fault ever fired — dead test"
    _check_exact(solo, done, reqs)
    _assert_quiescent(sched)


def test_faulted_run_replays_exactly(nemotron):
    """Same seed, same traffic -> same fault log and same finish order:
    the injector consults at deterministic points, so a faulted failure
    reproduces instead of flaking."""
    cfg, params, _ = nemotron
    runs = []
    for _ in range(2):
        faults = FaultInjector(3, rates={
            "alloc": 0.2, "stage_stall": 0.2}, max_per_site=6)
        sched = PagedContinuousBatchingServer(
            cfg, params, num_slots=2, max_len=48, block_size=8,
            num_blocks=8, segment=4, faults=faults)
        reqs = _traffic(cfg, 5, seed=42)
        for p, g in reqs:
            sched.submit(p, g)
        order = []
        while sched._has_work():
            order.extend(r.rid for r in sched.step(draining=True))
        runs.append((list(faults.log), order))
    assert runs[0] == runs[1]


def test_scripted_alloc_failure_rolls_back_staging(nemotron):
    """Pinpoint: fail the very first allocation — the request's staging
    attempt unwinds atomically (a stall, not a crash) and the next
    boundary stages it successfully."""
    cfg, params, solo = nemotron
    faults = FaultInjector(0, script={"alloc": [1]})
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8,
        segment=4, faults=faults)
    reqs = _traffic(cfg, 3, seed=1)
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == 3
    assert sched.stats.stage_stalls >= 1
    _check_exact(solo, done, reqs)
    _assert_quiescent(sched)


# ---------------------------------------------------------------------------
# Fleet: dispatch faults, quarantine with exponential backoff, stealing.
# ---------------------------------------------------------------------------

def _fleet(cfg, params, n, **kw):
    reps = [
        PagedContinuousBatchingServer(
            cfg, params, num_slots=2, max_len=48, block_size=8,
            segment=4, num_blocks=kw.pop("num_blocks", None) or None)
        for _ in range(n)
    ]
    return ReplicaRouter(reps, **kw)


def test_dispatch_faults_quarantine_with_backoff(nemotron):
    """Three consecutive dispatch errors quarantine replica 0; its
    queued work survives untouched and finishes once the backoff
    expires. A second burst during the reprobe doubles the backoff."""
    cfg, params, solo = nemotron
    faults = FaultInjector(0, script={"dispatch:0": [1, 2, 3, 4]})
    fleet = _fleet(cfg, params, 2, faults=faults, quarantine_after=3,
                   backoff_steps=2)
    reqs = _traffic(cfg, 4, seed=2)
    fids = [fleet.submit(p, g) for p, g in reqs]
    done = {r.rid: r for r in fleet.run()}
    assert sorted(done) == sorted(fids)
    assert fleet.stats.dispatch_errors == 4
    assert fleet.stats.quarantine_events >= 2     # entered, then doubled
    h = fleet._health[0]
    assert h.backoff >= 0                          # reset after clean step
    assert h.consecutive_errors == 0
    assert fleet.quarantined == []                 # healthy at the end
    for fid, (prompt, gen) in zip(fids, reqs):
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], done[fid].tokens)
    assert fleet.load == 0


def test_healthy_fleet_never_quarantines(nemotron):
    cfg, params, _ = nemotron
    fleet = _fleet(cfg, params, 2)
    reqs = _traffic(cfg, 4, seed=3)
    fids = [fleet.submit(p, g) for p, g in reqs]
    done = fleet.run()
    assert len(done) == len(fids)
    assert fleet.stats.dispatch_errors == 0
    assert fleet.stats.quarantine_events == 0
    assert fleet.stats.stolen == 0                 # ample pools: no spills


def test_work_stealing_moves_spilled_requests(nemotron):
    """Same-prefix traffic concentrates on one replica (that is the
    affinity policy working); when its tight pool preempts, the router
    migrates the spilled victim to the idle sibling — the fleet drains
    with every token solo-exact and the steal recorded."""
    cfg, params, solo = nemotron
    reps = [
        PagedContinuousBatchingServer(
            cfg, params, num_slots=2, max_len=48, block_size=8,
            num_blocks=6, segment=4)               # 5 allocatable: tight
        for _ in range(2)
    ]
    fleet = ReplicaRouter(reps)
    rng = np.random.RandomState(4)
    head = rng.randint(0, cfg.vocab_size, size=6).astype(np.int32)
    reqs = [(head.copy(), 18) for _ in range(3)]   # 3 blocks grown, shared prefix
    fids = [fleet.submit(p, g) for p, g in reqs]
    done = {r.rid: r for r in fleet.run()}
    assert sorted(done) == sorted(fids)
    assert fleet.stats.totals.preemptions > 0, "pool never preempted"
    assert fleet.stats.stolen > 0, "no spill migrated"
    for fid, (prompt, gen) in zip(fids, reqs):
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], done[fid].tokens,
            err_msg=f"fid {fid} (possibly migrated) != solo")
    assert fleet.load == 0
    for rep in reps:
        _assert_quiescent(rep)


def test_fleet_cancel_by_fleet_rid(nemotron):
    cfg, params, solo = nemotron
    fleet = _fleet(cfg, params, 2)
    reqs = _traffic(cfg, 4, seed=5)
    fids = [fleet.submit(p, g) for p, g in reqs]
    assert fleet.cancel(fids[1])
    assert not fleet.cancel(fids[1])               # already gone
    assert not fleet.cancel(999)
    done = {r.rid for r in fleet.run()}
    assert done == set(fids) - {fids[1]}
    assert fleet.stats.totals.cancelled == 1


# ---------------------------------------------------------------------------
# Property sweep: random faulted interleavings keep every invariant.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [11, 12])
def test_random_faulted_interleavings(seed, nemotron):
    """Random traffic, random cancels, random fault rates, step-wise
    drain — after the dust settles: finished == submitted - cancelled,
    pool empty, spill region empty, and every surviving request's
    tokens solo-exact. The scheduler-level analogue of the kvpool
    state-machine interleaving test."""
    cfg, params, solo = nemotron
    rng = np.random.RandomState(seed)
    faults = FaultInjector(seed, rates={
        "alloc": 0.08, "evict_storm": 0.1, "stage_stall": 0.1,
    }, max_per_site=6)
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8,
        num_blocks=8, segment=4, faults=faults)
    reqs = _traffic(cfg, 8, seed=seed)
    submitted, cancelled, finished = [], set(), []
    for p, g in reqs:
        submitted.append(sched.submit(p, g))
        if rng.rand() < 0.5:
            finished.extend(sched.step())
        if rng.rand() < 0.25 and submitted:
            victim = submitted[int(rng.randint(len(submitted)))]
            if victim not in cancelled and sched.cancel(victim):
                cancelled.add(victim)
    while sched._has_work():
        finished.extend(sched.step(draining=True))
    assert {r.rid for r in finished} == set(submitted) - cancelled
    _check_exact(solo, finished, reqs)
    _assert_quiescent(sched)
