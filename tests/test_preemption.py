"""Preemption, priority scheduling, and spill/restore: bit-exact under
overload.

The tentpole invariant extends the paged scheduler's: a request that is
preempted mid-generation — its KV spilled to the host-side sidebar
region, its blocks released, later restored and resumed — produces
EXACTLY the tokens an unpreempted solo decode produces, greedy and
sampled alike, on the GQA, int8-KV, and MLA+MoE cache families. Spill
is a full copy + full release (a spilled request pins zero pool
memory), restore re-splices what the prefix index still holds and
rewrites the rest, and the position-keyed PRNG makes a sampled stream a
pure function of (seed, position) — restart-safe by construction.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.core.sidebar import SidebarSpillRegion
from repro.launch.sampling import SamplingParams
from repro.launch.scheduler import (
    ContinuousBatchingServer,
    PagedContinuousBatchingServer,
)
from repro.launch.serve import Server
from repro.models.registry import get_model

ARCHS = ["nemotron-4-15b", "nemotron-int8", "deepseek-v3-671b"]


def _cfg(arch: str):
    if arch == "nemotron-int8":
        cfg = dataclasses.replace(
            cfglib.get_smoke_config("nemotron-4-15b"),
            kv_cache_dtype=jnp.int8,
        )
    else:
        cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.fixture(scope="module")
def served():
    out = {}
    for arch in ARCHS:
        cfg = _cfg(arch)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        out[arch] = (cfg, params, Server(cfg, params, max_len=48))
    return out


def _check_exact(solo, done, reqs, samples=None, arch=""):
    for r in done:
        prompt, gen = reqs[r.rid]
        sample = None if samples is None else samples.get(r.rid)
        assert r.generated == gen
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop", sample=sample)
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], r.tokens,
            err_msg=f"{arch} rid {r.rid}: preempted != solo decode",
        )


def _assert_quiescent(sched):
    assert sched.mgr.alloc.in_use == 0
    assert (sched.mgr.alloc.num_free + sched.mgr.alloc.num_evictable
            == sched.mgr.alloc.capacity)
    assert len(sched.spill) == 0
    assert sched.spill.in_use_bytes == 0


def _tight_server(cfg, params, **kw):
    """A pool sized so two fully grown requests cannot coexist: lazy
    growth hits the wall mid-generation and the worse-scored request
    self-spills (no strictly worse victim exists) — deterministic
    preemption without any fault injection."""
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("block_size", 8)
    kw.setdefault("num_blocks", 6)      # 5 allocatable < 2 * 3-block spans
    kw.setdefault("segment", 4)
    return PagedContinuousBatchingServer(cfg, params, **kw)


def _tight_traffic(cfg, n=2, seed=3):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 18)
            for _ in range(n)]          # span 23 pos -> 3 blocks each


# ---------------------------------------------------------------------------
# Tentpole: preempt -> spill -> restore is invisible in the tokens.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_preempt_restore_bitexact_greedy(arch, served):
    cfg, params, solo = served[arch]
    sched = _tight_server(cfg, params)
    reqs = _tight_traffic(cfg)
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == len(reqs)
    assert sched.stats.preemptions > 0, "pool was not tight enough"
    assert sched.stats.restores > 0
    _check_exact(solo, done, reqs, arch=arch)
    _assert_quiescent(sched)


@pytest.mark.parametrize("arch", ARCHS)
def test_preempt_restore_bitexact_sampled(arch, served):
    """The position-keyed PRNG makes the sampled stream restart-safe:
    the restored request re-derives exactly the draws it would have
    made uninterrupted."""
    cfg, params, solo = served[arch]
    sched = _tight_server(cfg, params)
    reqs = _tight_traffic(cfg)
    sp = SamplingParams(temperature=0.8, top_k=40, seed=13)
    samples = {0: None, 1: sp}          # the later (victim) one samples
    for rid, (p, g) in enumerate(reqs):
        sched.submit(p, g, sample=samples[rid])
    done = sched.run()
    assert len(done) == len(reqs)
    assert sched.stats.preemptions > 0
    _check_exact(solo, done, reqs, samples=samples, arch=arch)
    _assert_quiescent(sched)


def test_lazy_growth_allocates_segment_by_segment(served):
    """Staging takes only the prompt's blocks; the full span shows up
    segment by segment — the whole point of lazy allocation (eager
    reservation is what made overload admission all-or-nothing)."""
    cfg, params, _ = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=1, max_len=48, block_size=8, segment=4)
    prompt = np.arange(1, 7, dtype=np.int32)        # S=6
    sched.submit(prompt, 20)                        # span 25 -> 4 blocks
    full = sched.mgr.blocks_needed(prompt.size + 20 - 1)
    sched.step()
    rb = sched._slot_rb[0]
    assert rb is not None
    grown_early = len(rb.bids)
    assert grown_early < full, (
        f"first segment already owns the full span "
        f"({grown_early}/{full} blocks) — allocation is not lazy")
    sched.run()
    assert sched.stats.preemptions == 0             # growth never failed


def test_spill_region_accounting(served):
    cfg, params, _ = served["nemotron-4-15b"]
    region = SidebarSpillRegion()
    sched = _tight_server(cfg, params, spill_region=region)
    for p, g in _tight_traffic(cfg):
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == 2
    assert region.spills == sched.stats.preemptions > 0
    assert region.restores > 0
    assert region.peak_bytes > 0
    assert region.in_use_bytes == 0 and len(region) == 0


def test_eviction_storm_while_spilled_never_breaks_restore(served):
    """The satellite: force-evict EVERY cached block while a request
    sits spilled — restore must rewrite from host copies instead of
    splicing, bit-exactly. (Spill releases all refcounts precisely so
    no eviction can ever be unsafe for a spilled request.)"""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = _tight_server(cfg, params)
    reqs = _tight_traffic(cfg)
    for p, g in reqs:
        sched.submit(p, g)
    done = []
    stormed = False
    while sched._has_work():
        done.extend(sched.step(draining=True))
        if sched._spilled and not stormed:
            stormed = True
            sched.mgr.alloc.evict_cached()          # flush the index
            assert sched.mgr.alloc.num_evictable == 0
    assert stormed, "no spill happened — pool was not tight enough"
    assert len(done) == len(reqs)
    assert sched.stats.restores > 0
    _check_exact(solo, done, reqs)
    _assert_quiescent(sched)


# ---------------------------------------------------------------------------
# Priority classes + EDF admission.
# ---------------------------------------------------------------------------

def test_priority_jumps_the_queue(served):
    """With one slot and a low-priority backlog, a late high-priority
    arrival is staged and admitted ahead of every queued request (but
    behind the one already decoding — admission preempts the QUEUE, the
    pool reclaims slots only under memory pressure). FIFO scheduling on
    the identical traffic keeps arrival order — the bench's baseline."""
    cfg, params, solo = served["nemotron-4-15b"]
    rng = np.random.RandomState(11)
    reqs = [(rng.randint(0, cfg.vocab_size, size=5).astype(np.int32), 4)
            for _ in range(4)]
    orders = {}
    for mode in ("edf", "fifo"):
        sched = PagedContinuousBatchingServer(
            cfg, params, num_slots=1, max_len=48, block_size=8,
            segment=4, scheduling=mode)
        for rid, (p, g) in enumerate(reqs):
            sched.submit(p, g, priority=(1 if rid == 3 else 0))
        order = []
        while sched._has_work():
            order.extend(r.rid for r in sched.step(draining=True))
        orders[mode] = order
        _check_exact(solo, [r for r in sched.finished], reqs)
    assert orders["fifo"] == [0, 1, 2, 3]
    assert orders["edf"].index(3) < orders["edf"].index(1)
    assert orders["edf"].index(3) < orders["edf"].index(2)


def test_edf_orders_by_deadline_inside_a_class(served):
    cfg, params, _ = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=1, max_len=48, block_size=8, segment=4)
    t = [0.0]
    sched._clock = lambda: t[0]                     # injectable clock
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(3)]
    sched.submit(prompts[0], 3)                     # no target: best-effort
    sched.submit(prompts[1], 3, ttft_target=100.0)
    sched.submit(prompts[2], 3, ttft_target=1.0)    # tightest deadline
    order = []
    while sched._has_work():
        order.extend(r.rid for r in sched.step(draining=True))
    assert order == [2, 1, 0]
    # per-class latency stats were recorded for the one class in play
    assert len(sched.stats.ttft_s[0]) == 3
    assert sched.stats.ttft_tail(q=95) >= 0.0
    assert len(sched.stats.itl_s[0]) == 3


def test_scheduling_mode_validated(served):
    cfg, params, _ = served["nemotron-4-15b"]
    with pytest.raises(ValueError, match="scheduling"):
        PagedContinuousBatchingServer(
            cfg, params, num_slots=1, max_len=48, block_size=8,
            scheduling="lifo")


# ---------------------------------------------------------------------------
# Satellite: cancel() on both servers.
# ---------------------------------------------------------------------------

def test_cancel_on_slab_server(served):
    cfg, params, solo = served["nemotron-4-15b"]
    sched = ContinuousBatchingServer(cfg, params, num_slots=2,
                                     max_len=48, segment=4)
    rng = np.random.RandomState(9)
    reqs = [(rng.randint(0, cfg.vocab_size, size=5).astype(np.int32), 8)
            for _ in range(4)]
    for p, g in reqs:
        sched.submit(p, g)
    sched.step()                        # rids 0,1 active; 2,3 pending
    assert sched.cancel(2)              # pending
    assert sched.cancel(0)              # active mid-generation
    assert not sched.cancel(2)          # already gone
    assert not sched.cancel(99)         # never existed
    done = sched.run()
    assert sorted(r.rid for r in done) == [1, 3]
    assert sched.stats.cancelled == 2
    _check_exact(solo, done, reqs)      # survivors unperturbed


def test_cancel_on_paged_server_everywhere(served):
    """Cancel a request in every pool-holding state — active, staged,
    spilled — and the pool drains to zero with the survivors exact."""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = _tight_server(cfg, params, num_blocks=6)
    reqs = _tight_traffic(cfg, n=3)
    for p, g in reqs:
        sched.submit(p, g)
    # run until someone spills, then cancel the spilled request
    while not sched._spilled and sched._has_work():
        sched.step(draining=True)
    assert sched._spilled, "expected a spill under this pool"
    spilled_rid = sched._spilled[0].req.rid
    assert sched.cancel(spilled_rid)
    assert spilled_rid not in sched.spill
    # cancel an active one too (if any survive this boundary)
    active = [s.rid for s in sched.slots if not s.free]
    cancelled = {spilled_rid}
    if active:
        assert sched.cancel(active[0])
        cancelled.add(active[0])
    done = sched.run()
    assert {r.rid for r in done} == set(range(3)) - cancelled
    assert sched.stats.cancelled == len(cancelled)
    _check_exact(solo, done, reqs)
    _assert_quiescent(sched)


# ---------------------------------------------------------------------------
# Default traffic is untouched by the machinery (regression guard).
# ---------------------------------------------------------------------------

def test_default_traffic_sees_no_preemption(served):
    """An amply provisioned pool never preempts, never spills, and EDF
    with no priorities or deadlines is exactly FIFO — the overload
    machinery is invisible until overload."""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=8, segment=4)
    rng = np.random.RandomState(17)
    reqs = [(rng.randint(0, cfg.vocab_size,
                         size=rng.randint(2, 12)).astype(np.int32),
             int(rng.randint(1, 9))) for _ in range(5)]
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    assert len(done) == 5
    st = sched.stats
    assert (st.preemptions, st.restores, st.unstaged, st.cancelled,
            st.spilled_blocks, st.restored_blocks) == (0,) * 6
    assert len(sched.spill) == 0
    _check_exact(solo, done, reqs)
