"""SIDEBAR_PIPELINED: protocol, numerics, and overlap accounting.

Three layers of coverage for the double-buffered engine path:

  (a) mode-equivalence over random alternating ``LayerGraph``s — all four
      execution modes agree numerically, and the two sidebar variants are
      *bit-identical* (same eager op sequence, tiles split/concatenated
      losslessly). Hypothesis-driven when available, seeded-random always.
  (b) the per-region ownership + ping-pong protocol: every illegal
      transition raises ``SidebarProtocolError``; the legal concurrent
      access (accelerator fills one half while the host owns the other)
      does not. Free-list recycling reuses placements.
  (c) overlap accounting on hand-computed graphs: stall/overlap cycle
      counts, handshake and invocation counts, and exact agreement
      between ``engine.account`` and the counters ``engine.run`` collects.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DEFAULT_TABLE,
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    Owner,
    PingPongPair,
    SidebarBuffer,
    SidebarProtocolError,
    StaticOp,
    account,
    estimate,
    flexible_runs,
    pipeline_schedule,
    run,
)
from repro.core.energy import VPU_RATE_DIV
from repro.models import lenet

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAS_HYPOTHESIS = True
except ImportError:  # property tests skip, seeded-random ones still run
    HAS_HYPOTHESIS = False

ALL_MODES = list(ExecutionMode)
SIDEBAR_MODES = (ExecutionMode.SIDEBAR, ExecutionMode.SIDEBAR_PIPELINED)
ACTS = ["relu", "tanh", "sigmoid", "softplus", "gelu"]


def _mm(w, x):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def _random_graph(rng: np.random.Generator):
    """Random alternating static/flexible graph + matching params/input."""
    b = int(rng.integers(1, 5)) * 2
    dims = [int(rng.integers(1, 9)) * 4]
    ops = []
    params = {}
    n_ops = int(rng.integers(2, 7))
    for i in range(n_ops):
        if rng.random() < 0.5:
            d_in, d_out = dims[-1], int(rng.integers(1, 9)) * 4
            name = f"w{i}"
            ops.append(
                StaticOp(name, _mm, (b, d_out), flops=2 * b * d_in * d_out,
                         weight_bytes=d_in * d_out * 4)
            )
            params[name] = np.asarray(
                rng.normal(size=(d_in, d_out)) * 0.1, np.float32
            )
            dims.append(d_out)
        else:
            act = ACTS[int(rng.integers(0, len(ACTS)))]
            ops.append(FlexibleOp(act, (b, dims[-1])))
    graph = LayerGraph("rand", tuple(ops), (b, dims[0]))
    x = np.asarray(rng.normal(size=(b, dims[0])) * 0.5, np.float32)
    return graph, params, jnp.asarray(x)


def _check_mode_equivalence(graph, params, x):
    outs = {
        m: np.asarray(run(graph, params, x, m, DEFAULT_TABLE).output)
        for m in ALL_MODES
    }
    ref = outs[ExecutionMode.MONOLITHIC]
    for m, o in outs.items():
        np.testing.assert_allclose(o, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=str(m))
    # the two sidebar variants run the identical eager op sequence —
    # the ping-pong tile split must be lossless, i.e. bit-identical
    np.testing.assert_array_equal(
        outs[ExecutionMode.SIDEBAR], outs[ExecutionMode.SIDEBAR_PIPELINED]
    )


# ---------------------------------------------------------------------------
# (a) mode equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_random_graphs_mode_equivalent_seeded(seed):
    graph, params, x = _random_graph(np.random.default_rng(seed))
    _check_mode_equivalence(graph, params, x)


if HAS_HYPOTHESIS:

    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_random_graphs_mode_equivalent_property(seed):
        graph, params, x = _random_graph(np.random.default_rng(seed))
        _check_mode_equivalence(graph, params, x)

    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pipelined_never_stalls_more_property(seed):
        graph, _, _ = _random_graph(np.random.default_rng(seed))
        a_serial = account(graph, ExecutionMode.SIDEBAR, DEFAULT_TABLE)
        a_pipe = account(graph, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE)
        assert a_pipe.stall_cycles <= a_serial.stall_cycles
        assert a_pipe.stall_cycles + a_pipe.overlap_cycles == a_pipe.host_busy_cycles
        assert a_serial.host_busy_cycles == a_pipe.host_busy_cycles


def test_lenet_pipelined_matches_forward():
    lenet.register_pooling(DEFAULT_TABLE)
    params = lenet.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 32, 32), jnp.float32)
    graph = lenet.to_layer_graphs(batch=8, activation="relu")[0]
    out = run(graph, lenet.engine_params(params), x,
              ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE).output
    ref = lenet.forward(params, x, DEFAULT_TABLE.lookup("relu"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# (b) per-region ownership + ping-pong protocol
# ---------------------------------------------------------------------------


def test_concurrent_halves_are_legal():
    """The accelerator may fill one half while the host owns the other —
    the whole point of per-region ownership."""
    sb = SidebarBuffer(8192)
    pair = PingPongPair(sb, "op", 256, 256)
    h0 = pair.acquire(0)
    sb.write(Owner.ACCELERATOR, h0.operand.name, np.zeros(16, np.float32))
    pair.to_host(h0)
    # host owns h0; accelerator can still fill h1
    h1 = pair.acquire(1)
    sb.write(Owner.ACCELERATOR, h1.operand.name, np.ones(16, np.float32))
    # ...but not touch h0
    with pytest.raises(SidebarProtocolError, match="owned by host"):
        sb.write(Owner.ACCELERATOR, h0.operand.name, np.ones(4, np.float32))
    # and the host cannot reach h1
    with pytest.raises(SidebarProtocolError, match="owned by accelerator"):
        sb.read(Owner.HOST, h1.operand.name)


def test_pingpong_reuse_before_release_raises():
    sb = SidebarBuffer(8192)
    pair = PingPongPair(sb, "op", 256, 256)
    pair.acquire(0)
    with pytest.raises(SidebarProtocolError, match="reused before release"):
        pair.acquire(2)  # tile 2 maps back onto the un-released ping half


def test_pingpong_state_machine_enforced():
    sb = SidebarBuffer(8192)
    pair = PingPongPair(sb, "op", 256, 256)
    h0 = pair.acquire(0)
    with pytest.raises(SidebarProtocolError, match="returned in state"):
        pair.to_accelerator(h0)          # never invoked
    with pytest.raises(SidebarProtocolError, match="released in state"):
        pair.release(h0)                 # result never returned
    pair.to_host(h0)
    with pytest.raises(SidebarProtocolError, match="invoked in state"):
        pair.to_host(h0)                 # double invoke
    pair.to_accelerator(h0)
    with pytest.raises(SidebarProtocolError, match="freed mid-flight"):
        pair.free()                      # h0 returned but not released
    pair.release(h0)


def test_pass_region_already_owned_raises():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    with pytest.raises(SidebarProtocolError, match="already with"):
        sb.pass_region("a", Owner.ACCELERATOR)


def test_free_list_recycles_placements():
    sb = SidebarBuffer(4096)
    r1 = sb.allocate("a", 200)
    sb.free("a")
    r2 = sb.allocate("b", 100)            # reuses the freed span
    assert r2.offset == r1.offset
    r3 = sb.allocate("c", 100)            # fits in the remainder of it
    assert r3.offset < r1.offset + 256
    # a long alternating sequence must not grow past capacity
    for i in range(64):
        sb.allocate(f"t{i}", 1024)
        sb.free(f"t{i}")
    assert sb.utilization() <= 1.0


def test_region_owner_introspection():
    sb = SidebarBuffer(4096)
    sb.allocate("a", 64)
    sb.allocate("b", 64)
    assert sb.region_owner("a") is Owner.ACCELERATOR
    sb.pass_region("a", Owner.HOST)
    assert sb.region_owner("a") is Owner.HOST
    assert sb.region_owner("b") is Owner.ACCELERATOR
    assert sb.stats.handshakes == 1


# ---------------------------------------------------------------------------
# (c) overlap accounting
# ---------------------------------------------------------------------------


def _three_op_graph(b=2, d=8, act="relu", f1=1000, f2=2000):
    return LayerGraph(
        "tiny",
        ops=(
            StaticOp("w1", _mm, (b, d), flops=f1, weight_bytes=0),
            FlexibleOp(act, (b, d)),
            StaticOp("w2", _mm, (b, d), flops=f2, weight_bytes=0),
        ),
        in_shape=(b, d),
    )


def test_hand_computed_stage_timing():
    g = _three_op_graph(b=2, d=8)          # operand 16 elements, relu cost 1
    (stage,) = pipeline_schedule(g, DEFAULT_TABLE)
    H = int(16 * 1 * VPU_RATE_DIV)          # 256 host cycles
    assert stage.host_cycles == H
    assert stage.producer_cycles == 1000
    assert stage.consumer_cycles == 2000
    assert stage.tiles == 2
    # both halves (128 each) hide fully behind the adjacent statics
    assert stage.overlap_cycles == min(H // 2, 500) + min(H // 2, 1000) == H
    assert stage.stall_cycles == 0

    a_serial = account(g, ExecutionMode.SIDEBAR, DEFAULT_TABLE)
    a_pipe = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE)
    assert a_serial.stall_cycles == H and a_serial.overlap_cycles == 0
    assert a_pipe.stall_cycles == 0 and a_pipe.overlap_cycles == H
    assert a_serial.handshakes == 2 and a_pipe.handshakes == 4
    assert a_serial.host_invocations == 1 and a_pipe.host_invocations == 2


def test_trailing_flexible_overlaps_producer_only():
    g = LayerGraph(
        "tail",
        ops=(
            StaticOp("w1", _mm, (2, 8), flops=60, weight_bytes=0),
            FlexibleOp("relu", (2, 8)),     # H = 256, producer only
        ),
        in_shape=(2, 8),
    )
    (stage,) = pipeline_schedule(g, DEFAULT_TABLE)
    assert stage.overlap_cycles == min(128, 30) + 0 == 30
    assert stage.stall_cycles == 256 - 30


def test_unsplittable_operand_degrades_to_serial():
    g = _three_op_graph(b=1)                # leading axis 1: no tile split
    (stage,) = pipeline_schedule(g, DEFAULT_TABLE)
    assert stage.tiles == 1
    assert stage.overlap_cycles == 0
    a = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE)
    assert a.stall_cycles == a.host_busy_cycles
    assert a.handshakes == 2 and a.host_invocations == 1


@pytest.mark.parametrize("mode", SIDEBAR_MODES)
def test_run_counters_match_account(mode):
    lenet.register_pooling(DEFAULT_TABLE)
    params = lenet.engine_params(lenet.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 32, 32), jnp.float32)
    g = lenet.to_layer_graphs(batch=8, activation="relu")[0]
    res = run(g, params, x, mode, DEFAULT_TABLE)
    acct = account(g, mode, DEFAULT_TABLE)
    st = res.sidebar.stats
    assert st.stall_cycles == acct.stall_cycles
    assert st.overlap_cycles == acct.overlap_cycles
    assert st.host_busy_cycles == acct.host_busy_cycles
    assert st.acc_busy_cycles == acct.acc_busy_cycles == g.static_flops
    assert st.handshakes == acct.handshakes
    assert st.host_invocations == acct.host_invocations


@pytest.mark.parametrize("workload", ["lenet", "mlp"])
@pytest.mark.parametrize("act", ["relu", "softplus"])
def test_pipelined_strictly_fewer_stalls_and_faster(workload, act):
    """Acceptance: on graphs with >= 2 flexible ops the pipelined mode
    stalls strictly less and the model estimates strictly lower latency."""
    if workload == "lenet":
        lenet.register_pooling(DEFAULT_TABLE)
        g = lenet.to_layer_graphs(batch=256, activation=act)[0]
    else:
        b, d, f = 64, 128, 512
        g = LayerGraph(
            "mlp2",
            ops=(
                StaticOp("w1", _mm, (b, f), flops=2 * b * d * f,
                         weight_bytes=d * f * 4),
                FlexibleOp(act, (b, f)),
                StaticOp("w2", _mm, (b, d), flops=2 * b * f * d,
                         weight_bytes=f * d * 4),
                FlexibleOp(act, (b, d)),
                StaticOp("w3", _mm, (b, d), flops=2 * b * d * d,
                         weight_bytes=d * d * 4),
            ),
            in_shape=(b, d),
        )
    assert len(g.flexible_ops()) >= 2
    a_serial = account(g, ExecutionMode.SIDEBAR, DEFAULT_TABLE)
    a_pipe = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE)
    assert a_pipe.stall_cycles < a_serial.stall_cycles
    e_serial = estimate(a_serial)
    e_pipe = estimate(a_pipe)
    assert e_pipe.latency_s < e_serial.latency_s
    assert e_pipe.edp < e_serial.edp
    # same compute; data movement can only shrink (fusing a run of
    # consecutive flexible ops keeps its intermediates in host registers)
    assert a_pipe.sidebar_bytes <= a_serial.sidebar_bytes
    if not any(
        len(r) > 1 for r in flexible_runs(g)
    ):  # no fused runs -> identical crossings
        assert a_pipe.sidebar_bytes == a_serial.sidebar_bytes
    assert a_pipe.flex_vpu_ops == a_serial.flex_vpu_ops
    assert a_pipe.mxu_flops == a_serial.mxu_flops


@pytest.mark.parametrize("depth", [2, 3, 4])
def test_pipelined_kernel_matches_serial_kernel(depth):
    """The TPU realization: T-deep VMEM ring == single-scratch kernel."""
    from repro.kernels import ops as kops

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (16, 128), jnp.float32) * 0.1
    w1 = jax.random.normal(k2, (128, 256), jnp.float32) * 0.05
    w2 = jax.random.normal(k3, (256, 128), jnp.float32) * 0.05
    for act in ("relu", "softplus"):
        serial = kops.sidebar_mlp(x, w1, w2, act, use_kernel=True,
                                  interpret=True, pipelined=False)
        pipe = kops.sidebar_mlp(x, w1, w2, act, use_kernel=True,
                                interpret=True, pipelined=True, depth=depth)
        # same f-block accumulation order at every depth -> bit-identical
        np.testing.assert_array_equal(np.asarray(pipe), np.asarray(serial),
                                      err_msg=f"{act}@T={depth}")


def test_ops_execution_mode_ambient_switch():
    from repro.kernels import ops as kops

    assert kops.current_execution_mode() is ExecutionMode.SIDEBAR
    with kops.execution_mode(ExecutionMode.SIDEBAR_PIPELINED):
        assert (kops.current_execution_mode()
                is ExecutionMode.SIDEBAR_PIPELINED)
    assert kops.current_execution_mode() is ExecutionMode.SIDEBAR


def test_ops_execution_plan_carries_depth():
    from repro.core.modes import LayerPlan
    from repro.kernels import ops as kops

    with kops.execution_plan(
        LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=4)
    ):
        assert kops.current_plan().depth == 4
        assert (kops.current_execution_mode()
                is ExecutionMode.SIDEBAR_PIPELINED)
        with kops.execution_mode(ExecutionMode.SIDEBAR):
            assert kops.current_execution_mode() is ExecutionMode.SIDEBAR
        assert kops.current_plan().depth == 4
    assert kops.current_execution_mode() is ExecutionMode.SIDEBAR


# ---------------------------------------------------------------------------
# (d) T-deep rings and host-op fusion
# ---------------------------------------------------------------------------


def _uneven_graph(b=64, d=128, f=1024, d2=8, act="relu"):
    """Producer matmul dwarfs the consumer: the regime where going past
    double buffering keeps paying (the consumer's donation saturates)."""
    return LayerGraph(
        "uneven",
        ops=(
            StaticOp("w1", _mm, (b, f), flops=2 * b * d * f,
                     weight_bytes=d * f * 4),
            FlexibleOp(act, (b, f)),
            StaticOp("w2", _mm, (b, d2), flops=2 * b * f * d2,
                     weight_bytes=f * d2 * 4),
        ),
        in_shape=(b, d),
    )


def test_stall_monotone_in_depth_and_t4_beats_t2():
    """Acceptance: modeled stall is monotonically non-increasing in T and
    depth 4 strictly beats depth 2 on the uneven-cost graph. softplus's
    host cost keeps the producer donation chunk-limited past T=2."""
    g = _uneven_graph(act="softplus")
    stalls = {}
    for t in (1, 2, 3, 4, 8):
        a = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
                    depth=t)
        stalls[t] = a.stall_cycles
        assert a.stall_cycles + a.overlap_cycles == a.host_busy_cycles
    assert all(stalls[a] >= stalls[b] for a, b in
               zip((1, 2, 3, 4), (2, 3, 4, 8)))
    assert stalls[4] < stalls[2]
    e2 = estimate(account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
                          depth=2))
    e4 = estimate(account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
                          depth=4))
    assert e4.latency_s < e2.latency_s


@pytest.mark.parametrize("depth", [1, 2, 3, 4])
def test_run_counters_match_account_at_every_depth(depth):
    """Acceptance: run() and account() agree on every overlap counter for
    T in {1, 2, 3, 4}, on a graph with uneven producer/consumer cost."""
    rng = np.random.default_rng(7)
    g = _uneven_graph(b=6, d=8, f=12, d2=4)
    params = {
        "w1": np.asarray(rng.normal(size=(8, 12)) * 0.1, np.float32),
        "w2": np.asarray(rng.normal(size=(12, 4)) * 0.1, np.float32),
    }
    x = jnp.asarray(rng.normal(size=(6, 8)).astype(np.float32))
    res = run(g, params, x, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
              depth=depth)
    acct = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
                   depth=depth)
    st = res.sidebar.stats
    assert st.stall_cycles == acct.stall_cycles
    assert st.overlap_cycles == acct.overlap_cycles
    assert st.host_busy_cycles == acct.host_busy_cycles
    assert st.acc_busy_cycles == acct.acc_busy_cycles
    assert st.handshakes == acct.handshakes
    assert st.host_invocations == acct.host_invocations
    # numerics are depth-invariant and bit-identical to the serial mode
    ref = run(g, params, x, ExecutionMode.SIDEBAR, DEFAULT_TABLE)
    np.testing.assert_array_equal(np.asarray(res.output),
                                  np.asarray(ref.output))


def _fused_graph(b=8, d=16):
    return LayerGraph(
        "fused",
        ops=(
            StaticOp("w1", _mm, (b, d), flops=4000, weight_bytes=0),
            FlexibleOp("softplus", (b, d)),
            FlexibleOp("relu", (b, d)),      # consecutive: fuses
            StaticOp("w2", _mm, (b, d), flops=6000, weight_bytes=0),
        ),
        in_shape=(b, d),
    )


def test_fused_run_shares_one_invocation_per_tile():
    g = _fused_graph()
    stages = pipeline_schedule(g, DEFAULT_TABLE, depth=2)
    assert len(stages) == 1
    (stage,) = stages
    assert stage.indices == (1, 2) and stage.functions == ("softplus", "relu")
    assert stage.producer_cycles == 4000 and stage.consumer_cycles == 6000
    a_f = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE)
    a_nf = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
                   fuse=False)
    # one ownership round-trip per tile for the whole run, and the
    # inter-op intermediate never re-crosses the sidebar
    assert a_f.host_invocations == 2 and a_nf.host_invocations == 4
    assert a_f.handshakes == 4 and a_nf.handshakes == 8
    assert a_f.sidebar_bytes == a_nf.sidebar_bytes // 2
    # identical compute either way
    assert a_f.flex_vpu_ops == a_nf.flex_vpu_ops
    assert a_f.host_busy_cycles == a_nf.host_busy_cycles


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_fused_run_numerics_and_counters(depth):
    rng = np.random.default_rng(3)
    g = _fused_graph()
    params = {
        "w1": np.asarray(rng.normal(size=(16, 16)) * 0.2, np.float32),
        "w2": np.asarray(rng.normal(size=(16, 16)) * 0.2, np.float32),
    }
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    res = run(g, params, x, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
              depth=depth)
    ref = run(g, params, x, ExecutionMode.MONOLITHIC, DEFAULT_TABLE)
    np.testing.assert_allclose(np.asarray(res.output),
                               np.asarray(ref.output), rtol=1e-5, atol=1e-6)
    acct = account(g, ExecutionMode.SIDEBAR_PIPELINED, DEFAULT_TABLE,
                   depth=depth)
    st = res.sidebar.stats
    assert st.host_invocations == acct.host_invocations
    assert st.handshakes == acct.handshakes
    assert st.stall_cycles == acct.stall_cycles
    assert st.overlap_cycles == acct.overlap_cycles


def test_run_accepts_layer_plan():
    from repro.core import LayerPlan

    rng = np.random.default_rng(5)
    g = _fused_graph()
    params = {
        "w1": np.asarray(rng.normal(size=(16, 16)) * 0.2, np.float32),
        "w2": np.asarray(rng.normal(size=(16, 16)) * 0.2, np.float32),
    }
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    plan = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=3)
    res = run(g, params, x, plan, DEFAULT_TABLE)
    ref = run(g, params, x, ExecutionMode.SIDEBAR, DEFAULT_TABLE)
    np.testing.assert_array_equal(np.asarray(res.output),
                                  np.asarray(ref.output))
    assert res.accounting.host_invocations == 3  # 3 tiles x 1 fused stage
