"""Host function table semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FunctionTable, make_default_table
from repro.core.constants import FLEXIBLE_OP_COST


def test_paper_table1_present():
    t = make_default_table()
    for name in ("heaviside", "tanh", "sigmoid", "relu", "leaky_relu",
                 "elu", "softplus"):
        assert name in t, f"paper Table 1 activation {name} missing"


def test_register_duplicate_requires_overwrite():
    t = FunctionTable()
    t.register("f", lambda x: x)
    with pytest.raises(ValueError, match="already registered"):
        t.register("f", lambda x: x)
    t.register("f", lambda x: x + 1, overwrite=True)  # the upgrade path


def test_version_bumps_on_mutation():
    t = FunctionTable()
    v0 = t.version
    t.register("f", lambda x: x)
    assert t.version == v0 + 1
    t.unregister("f")
    assert t.version == v0 + 2


def test_unknown_lookup_message():
    t = make_default_table()
    with pytest.raises(KeyError, match="not in the function table"):
        t.lookup("mystery_activation_2030")


def test_costs_encode_relu_softplus_asymmetry():
    t = make_default_table()
    assert t.cost("softplus") > 5 * t.cost("relu")
    assert t.cost("relu") == FLEXIBLE_OP_COST["relu"]


def test_numerics_match_closed_forms():
    t = make_default_table()
    x = jnp.linspace(-4, 4, 33, dtype=jnp.float32)
    np.testing.assert_allclose(
        t.lookup("softplus")(x), np.log1p(np.exp(np.asarray(x))), rtol=1e-5
    )
    np.testing.assert_allclose(
        t.lookup("squared_relu")(x), np.maximum(np.asarray(x), 0) ** 2, rtol=1e-6
    )
    np.testing.assert_allclose(
        t.lookup("exp_decay")(x), np.exp(-np.exp(np.asarray(x))), rtol=1e-5
    )
