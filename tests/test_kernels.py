"""Per-kernel shape/dtype sweeps against the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (kernel body executed in
Python on CPU) and must match ref.py to tight tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.function_table import make_default_table
from repro.kernels import ops, ref
from repro.kernels.sidebar_mlp import choose_tiles
from repro.core import constants

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=0.1):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


MLP_SHAPES = [(8, 128, 128), (16, 128, 256), (32, 256, 512), (64, 384, 128)]
ACTS = ["relu", "softplus", "silu", "gelu", "squared_relu", "tanh"]


@pytest.mark.parametrize("shape", MLP_SHAPES)
@pytest.mark.parametrize("act", ["relu", "softplus", "silu"])
def test_sidebar_mlp_sweep(shape, act):
    m, d, f = shape
    x, w1, w2 = _arr((m, d)), _arr((d, f), scale=0.05), _arr((f, d), scale=0.05)
    got = ops.sidebar_mlp(x, w1, w2, act, interpret=True, use_kernel=True)
    want = ref.sidebar_mlp_ref(x, w1, w2, act)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sidebar_mlp_dtypes(dtype):
    x, w1, w2 = _arr((16, 128), dtype), _arr((128, 256), dtype, 0.05), \
        _arr((256, 128), dtype, 0.05)
    got = ops.sidebar_mlp(x, w1, w2, "relu", interpret=True, use_kernel=True)
    want = ref.sidebar_mlp_ref(x, w1, w2, "relu")
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_sidebar_mlp_function_table_swap():
    """New activation = one table row, same kernel source (paper claim)."""
    table = make_default_table()
    x, w1, w2 = _arr((16, 128)), _arr((128, 256), scale=0.05), \
        _arr((256, 128), scale=0.05)
    table.register("mish", lambda v: v * jnp.tanh(jnp.logaddexp(v, 0.0)))
    got = ops.sidebar_mlp(x, w1, w2, "mish", table=table, interpret=True,
                          use_kernel=True)
    want = ref.sidebar_mlp_ref(x, w1, w2, "mish", table)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_choose_tiles_respects_vmem():
    for d in (512, 1024, 4096, 8192, 16384):
        bm, bf = choose_tiles(1024, d, 4 * d, itemsize=2)
        ws = (2 * bm * d + 2 * d * bf * 2 + 4 * bm * bf + 4 * bm * d)
        assert ws <= constants.VMEM_BYTES_PER_CHIP // 4  # comfortable


@pytest.mark.parametrize("shape", [(32, 128, 128), (64, 256, 384),
                                   (128, 512, 128)])
@pytest.mark.parametrize("act", ["identity", "gelu"])
def test_sidebar_matmul_sweep(shape, act):
    m, k, n = shape
    a, b = _arr((m, k)), _arr((k, n))
    got = ops.sidebar_matmul(a, b, act, interpret=True, use_kernel=True)
    want = ref.sidebar_matmul_ref(a, b, act)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("act", ACTS)
def test_host_activation_sweep(act):
    x = _arr((64, 512), scale=1.0)
    got = ops.host_activation(x, act, interpret=True, use_kernel=True)
    want = ref.activation_ref(x, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_host_activation_rowwise_softmax():
    x = _arr((32, 384), scale=1.0)
    got = ops.host_activation(x, "softmax", interpret=True, use_kernel=True)
    want = ref.activation_ref(x, "softmax")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, rtol=1e-5)


FLASH_CASES = [
    # (B, Hq, Hkv, S, T, Dh, causal)
    (2, 4, 4, 128, 128, 64, True),
    (1, 8, 2, 128, 128, 64, True),     # GQA
    (2, 4, 2, 128, 256, 32, True),     # decode-style offset
    (1, 4, 4, 128, 128, 128, False),   # non-causal (cross-attn)
    (1, 2, 1, 256, 256, 64, True),     # multiple q blocks
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_sweep(case):
    b, hq, hkv, s, t, dh, causal = case
    q = _arr((b, hq, s, dh), scale=0.3)
    k = _arr((b, hkv, t, dh), scale=0.3)
    v = _arr((b, hkv, t, dh), scale=0.3)
    got = ops.flash_attention(q, k, v, causal=causal, interpret=True,
                              use_kernel=True, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    q = _arr((1, 4, 128, 64), jnp.bfloat16, 0.3)
    k = _arr((1, 4, 128, 64), jnp.bfloat16, 0.3)
    v = _arr((1, 4, 128, 64), jnp.bfloat16, 0.3)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True,
                              use_kernel=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_flash_rejects_bad_gqa():
    q = _arr((1, 3, 128, 64))
    k = _arr((1, 2, 128, 64))
    with pytest.raises(ValueError, match="GQA"):
        ops.flash_attention(q, k, k, interpret=True, use_kernel=True)


GATED_SHAPES = [(8, 128, 128), (16, 128, 256), (32, 256, 512)]


@pytest.mark.parametrize("shape", GATED_SHAPES)
@pytest.mark.parametrize("act", ["silu", "gelu", "relu"])
def test_sidebar_gated_mlp_sweep(shape, act):
    m, d, f = shape
    x = _arr((m, d))
    wg, wu = _arr((d, f), scale=0.05), _arr((d, f), scale=0.05)
    wd = _arr((f, d), scale=0.05)
    got = ops.sidebar_gated_mlp(x, wg, wu, wd, act, interpret=True,
                                use_kernel=True)
    want = ref.sidebar_gated_mlp_ref(x, wg, wu, wd, act)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_sidebar_gated_mlp_bf16():
    x = _arr((16, 128), jnp.bfloat16)
    wg, wu = _arr((128, 256), jnp.bfloat16, 0.05), _arr((128, 256), jnp.bfloat16, 0.05)
    wd = _arr((256, 128), jnp.bfloat16, 0.05)
    got = ops.sidebar_gated_mlp(x, wg, wu, wd, "silu", interpret=True,
                                use_kernel=True)
    want = ref.sidebar_gated_mlp_ref(x, wg, wu, wd, "silu")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_sidebar_gated_mlp_table_swap():
    from repro.core.function_table import make_default_table
    table = make_default_table()
    table.register("swish2", lambda v: v * jax.nn.sigmoid(2.0 * v))
    x, wg = _arr((16, 128)), _arr((128, 256), scale=0.05)
    wu, wd = _arr((128, 256), scale=0.05), _arr((256, 128), scale=0.05)
    got = ops.sidebar_gated_mlp(x, wg, wu, wd, "swish2", table=table,
                                interpret=True, use_kernel=True)
    want = ref.sidebar_gated_mlp_ref(x, wg, wu, wd, "swish2", table)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
