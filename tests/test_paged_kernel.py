"""Paged-attention decode kernel: in-place pool decode, no slab copies.

PR-6's contract, layer by layer:

  * op level — the Pallas kernels (interpret mode on CPU) match the jnp
    references to fp32 tolerance on GQA fp32, GQA int8-KV (in-kernel
    dequant), and MLA absorbed decode, over ragged lengths, duplicate
    table entries, and scratch-padded tails.
  * scheduler level — ``kernel="paged"`` serves bit-exact tokens vs
    solo decode on every cache family while issuing ZERO pool-wide
    ``gather_blocks`` / ``scatter_blocks`` dispatches (the trace-time
    dispatch records are the observable); ``kernel="slab"`` keeps the
    gather/scatter reference segment, also bit-exact.
  * safety rails — out-of-table writes hit the drop sentinel instead of
    clamping onto a neighbour's last block; corrupt tables and
    span-overrunning segments raise ``KVPoolError`` host-side before
    any device dispatch could silently alias block 0.

Bit-exactness note: the jnp reference path (default config on CPU)
mirrors the slab attention op-for-op, so token equality is exact. The
Pallas kernels use an online softmax — the ``use_pallas`` end-to-end
smoke asserts drain/shape/dispatch, never exact tokens.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.core.modes import ExecutionMode, ExecutionPlan, LayerPlan
from repro.kernels import ops as kops
from repro.kernels import paged_attention as pa
from repro.launch import kvpool as kvp
from repro.launch.scheduler import PagedContinuousBatchingServer
from repro.launch.serve import Server
from repro.models import attention as attn
from repro.models.registry import get_model

ARCHS = ["nemotron-4-15b", "nemotron-int8", "deepseek-v3-671b"]


def _cfg(arch: str):
    if arch == "nemotron-int8":
        cfg = dataclasses.replace(
            cfglib.get_smoke_config("nemotron-4-15b"),
            kv_cache_dtype=jnp.int8,
        )
    else:
        cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.fixture(scope="module")
def served():
    out = {}
    for arch in ARCHS:
        cfg = _cfg(arch)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        out[arch] = (cfg, params, Server(cfg, params, max_len=48))
    return out


def _traffic(cfg, n, seed=0, max_prompt=14, max_gen=8):
    rng = np.random.RandomState(seed)
    return [
        (rng.randint(0, cfg.vocab_size, size=rng.randint(2, max_prompt))
         .astype(np.int32), int(rng.randint(1, max_gen + 1)))
        for _ in range(n)
    ]


def _check_exact(solo, done, reqs, arch=""):
    for r in done:
        prompt, gen = reqs[r.rid]
        assert r.generated == gen
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop")
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], r.tokens,
            err_msg=f"{arch} rid {r.rid}: paged kernel != solo decode",
        )


# ---------------------------------------------------------------------------
# Op level: Pallas kernel (interpret) vs jnp reference
# ---------------------------------------------------------------------------


def _pool_problem(seed=0, *, quantized=False):
    """Random pool + tables with duplicate entries, a scratch-padded
    tail row, and ragged lengths (one mid-block, one block-aligned, one
    spanning the whole table)."""
    rng = np.random.RandomState(seed)
    P, Hkv, bs, Dh, B, nb, group = 9, 2, 8, 16, 3, 4, 4
    q = jnp.asarray(rng.randn(B, Hkv * group, Dh).astype(np.float32))
    tables = rng.randint(1, P, size=(B, nb)).astype(np.int32)
    tables[0, 1:] = kvp.SCRATCH_BLOCK          # short row, unused tail
    tables[1, 2] = tables[1, 1]                # duplicate (prefix-share)
    lengths = jnp.asarray(np.array([5, bs * 2, bs * nb], np.int32))
    if quantized:
        k = jnp.asarray(rng.randint(-127, 128, (P, Hkv, bs, Dh))
                        .astype(np.int8))
        v = jnp.asarray(rng.randint(-127, 128, (P, Hkv, bs, Dh))
                        .astype(np.int8))
        ks = jnp.asarray((rng.rand(P, Hkv, bs).astype(np.float32) + .5)
                         / 127)
        vs = jnp.asarray((rng.rand(P, Hkv, bs).astype(np.float32) + .5)
                         / 127)
    else:
        k = jnp.asarray(rng.randn(P, Hkv, bs, Dh).astype(np.float32))
        v = jnp.asarray(rng.randn(P, Hkv, bs, Dh).astype(np.float32))
        ks = vs = None
    return q, k, v, ks, vs, jnp.asarray(tables), lengths, Dh ** -0.5


@pytest.mark.parametrize("quantized", [False, True],
                         ids=["fp32", "int8"])
def test_gqa_kernel_matches_reference(quantized):
    q, k, v, ks, vs, tables, lengths, scale = _pool_problem(
        seed=1, quantized=quantized)
    ref = pa.paged_gqa_reference(q, k, v, tables, lengths, scale=scale,
                                 k_scale=ks, v_scale=vs)
    out = pa.paged_gqa_kernel(q, k, v, tables, lengths, scale=scale,
                              k_scale=ks, v_scale=vs, interpret=True)
    assert out.dtype == q.dtype and out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_mla_kernel_matches_reference():
    rng = np.random.RandomState(2)
    P, bs, B, nb, h, kvr, rope = 7, 8, 3, 4, 4, 32, 8
    ckv = jnp.asarray(rng.randn(P, bs, kvr).astype(np.float32))
    krope = jnp.asarray(rng.randn(P, bs, rope).astype(np.float32))
    ql = jnp.asarray(rng.randn(B, h, kvr).astype(np.float32))
    qr = jnp.asarray(rng.randn(B, h, rope).astype(np.float32))
    tables = rng.randint(1, P, size=(B, nb)).astype(np.int32)
    tables[2, 2:] = kvp.SCRATCH_BLOCK
    tables = jnp.asarray(tables)
    lengths = jnp.asarray(np.array([3, bs * nb, bs + 1], np.int32))
    scale = (kvr + rope) ** -0.5
    ref = pa.paged_mla_reference(ql, qr, ckv, krope, tables, lengths,
                                 scale=scale)
    out = pa.paged_mla_kernel(ql, qr, ckv, krope, tables, lengths,
                              scale=scale, interpret=True)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ops_dispatch_kernel_vs_reference_agree():
    """The ops-layer dispatch itself: forcing the kernel and forcing
    the reference agree, and each records its variant."""
    q, k, v, ks, vs, tables, lengths, scale = _pool_problem(seed=3)
    recs = []
    with kops.record_dispatches(recs):
        ref = kops.paged_attention_gqa(q, k, v, tables, lengths,
                                       scale=scale, use_kernel=False)
        out = kops.paged_attention_gqa(q, k, v, tables, lengths,
                                       scale=scale, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert [(r.op, r.variant, r.used_kernel) for r in recs] == [
        ("paged_attention", "ref", False),
        ("paged_attention", "paged", True),
    ]


# ---------------------------------------------------------------------------
# Scheduler level: in-place decode, zero slab copies, exact tokens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_kernel_exact_and_no_slab_copies(arch, served):
    """The tentpole invariant: ``kernel="paged"`` decodes bit-exact vs
    solo on every cache family — prefix hits, ragged positions, fused
    admissions included — and its trace records show ZERO pool-wide
    gather/scatter, only table-walking paged attention."""
    cfg, params, solo = served[arch]
    recs = []
    with kops.record_dispatches(recs):
        sched = PagedContinuousBatchingServer(
            cfg, params, num_slots=3, max_len=48, block_size=8,
            prefill_chunk=8, segment=4, kernel="paged")
        reqs = _traffic(cfg, 6, seed=3)
        rids = [sched.submit(p, g) for p, g in reqs]
        done = sched.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    _check_exact(solo, done, reqs, arch)
    ops_seen = {r.op for r in recs}
    assert "gather_blocks" not in ops_seen, ops_seen
    assert "scatter_blocks" not in ops_seen, ops_seen
    paged = [r for r in recs if r.op == "paged_attention"]
    assert paged, "paged segment never traced table-walking attention"
    # default config off-TPU routes to the jnp reference (exactness)
    assert all(r.variant == "ref" and not r.used_kernel for r in paged)
    # the executable cache keys carry the kernel choice + table width
    psegs = [k for k in sched.executable_cache_keys() if k[0] == "pseg"]
    assert psegs and all(k[6] == "paged" for k in psegs)
    assert all(1 <= k[7] <= sched.blocks_per_table for k in psegs)


def test_slab_kernel_keeps_gather_scatter_and_matches_paged(served):
    """``kernel="slab"`` preserves the reference segment — gathers and
    scatters recorded, tokens identical to the paged kernel's."""
    cfg, params, solo = served["nemotron-4-15b"]
    reqs = _traffic(cfg, 5, seed=7)

    def run(kernel):
        recs = []
        with kops.record_dispatches(recs):
            sched = PagedContinuousBatchingServer(
                cfg, params, num_slots=2, max_len=48, block_size=8,
                segment=4, kernel=kernel)
            for p, g in reqs:
                sched.submit(p, g)
            done = sched.run()
        return done, {r.op for r in recs}

    slab_done, slab_ops = run("slab")
    paged_done, _ = run("paged")
    assert "gather_blocks" in slab_ops and "scatter_blocks" in slab_ops
    assert "paged_attention" not in slab_ops
    _check_exact(solo, slab_done, reqs)
    for ra, rb in zip(slab_done, paged_done):
        assert ra.rid == rb.rid
        np.testing.assert_array_equal(ra.tokens, rb.tokens)


def test_unused_tail_table_entries_are_inert(served):
    """Short requests against a long max_len: most of every table row
    is scratch padding and the sliced segment width stays tiny — the
    dead entries never perturb tokens."""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=2, max_len=48, block_size=4, segment=4,
        kernel="paged")
    reqs = [(np.asarray([5, 3], np.int32), 3),
            (np.asarray([9], np.int32), 4)]
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    _check_exact(solo, done, reqs)
    widths = {k[7] for k in sched.executable_cache_keys()
              if k[0] == "pseg"}
    assert widths and max(widths) < sched.blocks_per_table


def test_kernel_kwarg_validated(served):
    cfg, params, _ = served["nemotron-4-15b"]
    with pytest.raises(ValueError, match="kernel"):
        PagedContinuousBatchingServer(cfg, params, num_slots=1,
                                      max_len=32, block_size=8,
                                      kernel="dense")


# ---------------------------------------------------------------------------
# Safety rails: drop sentinel + host-side validation
# ---------------------------------------------------------------------------


def test_write_index_drops_out_of_table_positions():
    """Positions past the table map to the one-past-the-pool sentinel,
    so a ``mode="drop"`` scatter discards them — the old clamp aimed
    them at the row's LAST real block (cross-request corruption when
    the row was fully allocated)."""
    bs, nb, num_blocks = 4, 2, 6
    tables = jnp.asarray([[3, 5]], np.int32)
    pos = jnp.asarray([bs * nb - 1], jnp.int32)       # last in-table
    pb, off = attn._paged_write_index(tables, pos, 1, bs, num_blocks)
    assert int(pb[0]) == 5 and int(off[0]) == bs - 1
    pos = jnp.asarray([bs * nb], jnp.int32)           # first past it
    pb, off = attn._paged_write_index(tables, pos, 1, bs, num_blocks)
    assert int(pb[0]) == num_blocks                   # drop sentinel
    pool = jnp.zeros((num_blocks, bs))
    written = pool.at[pb, off].set(1.0, mode="drop")
    assert not np.asarray(written).any()              # pool untouched
    # a prefill chunk straddling the edge keeps its in-table writes
    pb, off = attn._paged_write_index(
        tables, jnp.int32(bs * nb - 2), 4, bs, num_blocks)
    assert np.asarray(pb)[0].tolist() == [5, 5, num_blocks, num_blocks]


def test_validate_tables_rejects_out_of_pool_entries():
    good = np.asarray([[0, 2, 1]], np.int32)
    kvp.validate_tables(good, num_blocks=3)
    for bad in ([[0, 3, 1]], [[0, -1, 1]]):
        with pytest.raises(kvp.KVPoolError, match="table"):
            kvp.validate_tables(np.asarray(bad, np.int32), num_blocks=3)


def test_check_span_rejects_frontier_overrun(served):
    cfg, params, _ = served["nemotron-4-15b"]
    sched = PagedContinuousBatchingServer(
        cfg, params, num_slots=1, max_len=32, block_size=8)
    rb = sched.mgr.begin_request(np.asarray([1, 2, 3], np.int32), 10)
    sched.mgr.check_span(rb, 10)                      # frontier == span ok
    with pytest.raises(kvp.KVPoolError, match="span"):
        sched.mgr.check_span(rb, 17)
    sched.mgr.release_request(rb)


# ---------------------------------------------------------------------------
# Pallas end-to-end (interpret) + per-layer plan dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["nemotron-4-15b", "deepseek-v3-671b"])
def test_pallas_paged_kernel_serves_end_to_end(arch, served):
    """``use_pallas=True`` routes segment decode through the Pallas
    kernel (interpret mode off TPU) inside the scan-compiled segment:
    the server drains, per-request token counts are right, and the
    trace records confirm the kernel path ran. (No exact-token check:
    online softmax is tolerance-level, not bitwise.)"""
    cfg, params, _ = served[arch]
    cfg = dataclasses.replace(cfg, use_pallas=True)
    recs = []
    with kops.record_dispatches(recs):
        sched = PagedContinuousBatchingServer(
            cfg, params, num_slots=2, max_len=48, block_size=8,
            segment=4, kernel="paged")
        reqs = _traffic(cfg, 4, seed=11, max_gen=5)
        for p, g in reqs:
            sched.submit(p, g)
        done = sched.run()
    assert len(done) == len(reqs)
    for r in done:
        assert r.generated == reqs[r.rid][1]
        assert r.tokens.shape == (reqs[r.rid][1],)
    paged = [r for r in recs if r.op == "paged_attention"]
    assert paged and all(r.variant == "paged" and r.used_kernel
                         for r in paged)
    assert not {"gather_blocks", "scatter_blocks"} & {r.op for r in recs}


def test_flexible_dma_layer_takes_gather_route(served):
    """Per-layer plan dispatch reaches the paged op: a FLEXIBLE_DMA
    layer takes the dense-gather route (variant "dma"), sidebar layers
    the reference — and tokens stay exact vs solo under the same
    plan."""
    cfg, params, _ = served["nemotron-4-15b"]
    plan = ExecutionPlan(
        default=LayerPlan(ExecutionMode.SIDEBAR, 2),
        layers={1: LayerPlan(ExecutionMode.FLEXIBLE_DMA, 2)},
    )
    solo = Server(cfg, params, max_len=48, plan=plan)
    recs = []
    with kops.record_dispatches(recs):
        sched = PagedContinuousBatchingServer(
            cfg, params, num_slots=2, max_len=48, block_size=8,
            segment=4, plan=plan, kernel="paged")
        reqs = _traffic(cfg, 4, seed=13)
        for p, g in reqs:
            sched.submit(p, g)
        done = sched.run()
    _check_exact(solo, done, reqs)
    by_layer = {}
    for r in recs:
        if r.op == "paged_attention":
            by_layer.setdefault(r.layer, set()).add(r.variant)
    assert by_layer.get(1) == {"dma"}
    assert all(v == {"ref"} for k, v in by_layer.items() if k != 1)
    assert not {"gather_blocks", "scatter_blocks"} & {r.op for r in recs}
