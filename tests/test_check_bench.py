"""The bench schema gate (benchmarks/check_bench.py) and the committed
trajectory artifact it gates: the committed BENCH_serving.json must
itself satisfy the schema CI enforces on freshly generated benches."""

import json
import pathlib
import subprocess
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.check_bench import check  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parents[1]
BENCH = REPO / "BENCH_serving.json"


def _rows():
    return json.loads(BENCH.read_text())


def test_committed_bench_passes_schema():
    assert check(_rows()) == []


def test_committed_bench_records_the_pr4_acceptance_numbers():
    by_name = {r["name"]: r["derived"] for r in _rows()}
    speedup = next(v for n, v in by_name.items()
                   if n.endswith("scan_over_loop_speedup"))
    assert speedup > 1.0
    # the vmap-tax acceptance: continuous >= static at smoke scale, and
    # the measured crossover mix is recorded (> 0 = some mix wins)
    ratio = next(v for n, v in by_name.items()
                 if n.endswith("continuous_over_static"))
    assert ratio >= 1.0
    crossover = next(v for n, v in by_name.items()
                     if n.endswith("continuous_crossover_mix"))
    assert crossover > 0


def test_committed_bench_records_the_pr5_acceptance_numbers():
    by_name = {r["name"]: r["derived"] for r in _rows()}
    hit = next(v for n, v in by_name.items()
               if n.endswith("paged/prefix_hit_rate"))
    assert 0 < hit <= 1
    ratio = next(v for n, v in by_name.items()
                 if n.endswith("paged_over_sync_admission"))
    assert ratio >= 1.0


def test_committed_bench_records_the_pr6_acceptance_numbers():
    by_name = {r["name"]: r["derived"] for r in _rows()}
    ratio = next(v for n, v in by_name.items()
                 if n.endswith("paged_kernel_over_slab"))
    assert ratio >= 1.0


def test_committed_bench_records_the_pr7_acceptance_numbers():
    by_name = {r["name"]: r["derived"] for r in _rows()}
    match = next(v for n, v in by_name.items()
                 if n.endswith("tp_tokens_match"))
    assert match == 1
    affinity = next(v for n, v in by_name.items()
                    if n.endswith("router_affinity_over_random"))
    assert affinity >= 1.0
    hit = next(v for n, v in by_name.items()
               if n.endswith("fleet_prefix_hit_rate"))
    assert 0 < hit <= 1
    for suffix in ("tp2/tok_s", "tp_solo/tok_s"):
        v = next(v for n, v in by_name.items() if n.endswith(suffix))
        assert v > 0


def test_committed_bench_records_the_pr8_acceptance_numbers():
    by_name = {r["name"]: r["derived"] for r in _rows()}
    goodput = next(v for n, v in by_name.items()
                   if n.endswith("goodput_2x_over_fifo"))
    assert goodput >= 1.0
    bitexact = next(v for n, v in by_name.items()
                    if n.endswith("preempt_bitexact"))
    assert bitexact == 1
    preempts = next(v for n, v in by_name.items()
                    if n.endswith("overload/preemptions"))
    assert preempts > 0          # the overload run actually preempted
    # the SLO acceptance: high-priority p95 TTFT under 2x load stays
    # within 2x of the unloaded fleet's p95 (ratio row <= 1.0)
    ttft = next(v for n, v in by_name.items()
                if n.endswith("high_ttft_edf_over_2x_unloaded"))
    assert ttft <= 1.0


def test_committed_bench_records_the_pr9_acceptance_numbers():
    by_name = {r["name"]: r["derived"] for r in _rows()}
    match = next(v for n, v in by_name.items()
                 if n.endswith("spec_tokens_match"))
    assert match == 1            # speculation invisible in the stream
    accept = next(v for n, v in by_name.items()
                  if n.endswith("spec/acceptance_rate"))
    assert 0 <= accept <= 1
    # recorded, never gated: the oracle draft IS the target on this
    # host, so the ratio measures dispatch count minus doubled compute
    ratio = next(v for n, v in by_name.items()
                 if n.endswith("spec_over_plain"))
    assert ratio > 0


def test_committed_bench_records_the_pr10_acceptance_numbers():
    by_name = {r["name"]: r["derived"] for r in _rows()}
    hit = next(v for n, v in by_name.items()
               if n.endswith("rag_chunk_hit_rate"))
    assert 0 < hit <= 1             # chunk-addressed KV blocks reused
    ratio = next(v for n, v in by_name.items()
                 if n.endswith("rag_overlap_over_serial"))
    assert ratio >= 1.0             # hiding retrieval pays for itself
    ofrac = next(v for n, v in by_name.items()
                 if n.endswith("rag/overlap_frac"))
    assert 0 < ofrac <= 1           # most waves collected post-dispatch
    for suffix in ("rag/tok_s", "rag_serial/tok_s"):
        v = next(v for n, v in by_name.items() if n.endswith(suffix))
        assert v > 0


def test_zero_rag_chunk_hit_rate_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("rag_chunk_hit_rate"):
            r["derived"] = 0.0
    assert any("chunk blocks stopped being spliced" in e
               for e in check(rows))


def test_regressed_rag_overlap_ratio_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("rag_overlap_over_serial"):
            r["derived"] = 0.8
    assert any("retrieval I/O worker" in e for e in check(rows))


def test_zero_rag_overlap_frac_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("rag/overlap_frac"):
            r["derived"] = 0.0
    assert any("serial path" in e for e in check(rows))


def test_spec_token_mismatch_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("spec_tokens_match"):
            r["derived"] = 0.0
    assert any("accept/rollback" in e for e in check(rows))


def test_regressed_goodput_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("goodput_2x_over_fifo"):
            r["derived"] = 0.8
    assert any("jumping the backlog" in e for e in check(rows))


def test_inexact_preemption_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("preempt_bitexact"):
            r["derived"] = 0.0
    assert any("lossless" in e for e in check(rows))


def test_tp_token_mismatch_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("tp_tokens_match"):
            r["derived"] = 0.0
    assert any("pure parallelization" in e for e in check(rows))


def test_regressed_router_affinity_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("router_affinity_over_random"):
            r["derived"] = 0.7
    assert any("steering" in e for e in check(rows))


def test_regressed_paged_kernel_ratio_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("paged_kernel_over_slab"):
            r["derived"] = 0.8
    assert any("pool round-trip" in e for e in check(rows))


def test_zero_prefix_hit_rate_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("paged/prefix_hit_rate"):
            r["derived"] = 0.0
    assert any("prefix cache" in e for e in check(rows))


def test_regressed_paged_ratio_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("paged_over_sync_admission"):
            r["derived"] = 0.8
    assert any("synchronous admission" in e for e in check(rows))


def test_missing_required_row_is_flagged():
    rows = [r for r in _rows()
            if not r["name"].endswith("scan_over_loop_speedup")]
    errors = check(rows)
    assert any("scan_over_loop_speedup is absent" in e for e in errors)


def test_regressed_speedup_is_flagged():
    rows = _rows()
    for r in rows:
        if r["name"].endswith("scan_over_loop_speedup"):
            r["derived"] = 0.9
    assert any("per-token host round-trip" in e for e in check(rows))


@pytest.mark.parametrize("bad", [None, float("nan"), -5.0, 0])
def test_non_positive_tok_s_is_flagged(bad):
    rows = _rows()
    for r in rows:
        if r["name"].endswith("continuous/tok_s"):
            r["derived"] = bad
    errors = check(rows)
    assert any("finite positive" in e for e in errors)


def test_empty_or_malformed_inputs():
    assert check([]) != []
    assert check([{"name": "x"}]) != []


def test_cli_exit_codes(tmp_path):
    ok = subprocess.run(
        [sys.executable, "benchmarks/check_bench.py", str(BENCH)],
        cwd=REPO, capture_output=True, text=True,
    )
    assert ok.returncode == 0, ok.stderr
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{"section": "serving", "name": "x",
                                "us_per_call": 0, "derived": 0}]))
    fail = subprocess.run(
        [sys.executable, "benchmarks/check_bench.py", str(bad)],
        cwd=REPO, capture_output=True, text=True,
    )
    assert fail.returncode == 1
    assert "absent" in fail.stderr
    missing = subprocess.run(
        [sys.executable, "benchmarks/check_bench.py",
         str(tmp_path / "nope.json")],
        cwd=REPO, capture_output=True, text=True,
    )
    assert missing.returncode == 1
