"""Engine behaviour: the paper's three designs over one task."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExecutionMode,
    FlexibleOp,
    LayerGraph,
    StaticOp,
    account,
    build_monolithic,
    estimate,
    make_default_table,
    normalized_edp,
    run,
    segment_static_chains,
)


def _mm(w, x):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


@pytest.fixture
def mlp_graph():
    b, d, f = 8, 64, 256
    return LayerGraph(
        name="mlp",
        ops=(
            StaticOp("w1", _mm, (b, f), flops=2 * b * d * f, weight_bytes=d * f * 4),
            FlexibleOp("softplus", (b, f)),
            StaticOp("w2", _mm, (b, d), flops=2 * b * f * d, weight_bytes=f * d * 4),
        ),
        in_shape=(b, d),
    )


@pytest.fixture
def mlp_params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {
        "w1": jax.random.normal(k1, (64, 256), jnp.float32) * 0.05,
        "w2": jax.random.normal(k2, (256, 64), jnp.float32) * 0.05,
    }


def test_modes_numerically_identical(mlp_graph, mlp_params):
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 64), jnp.float32)
    outs = {
        m: np.asarray(run(mlp_graph, mlp_params, x, m).output)
        for m in ExecutionMode
    }
    ref = outs[ExecutionMode.MONOLITHIC]
    for m, o in outs.items():
        np.testing.assert_allclose(o, ref, rtol=1e-5, atol=1e-6, err_msg=str(m))


def test_launch_counts(mlp_graph, mlp_params):
    x = jnp.zeros((8, 64), jnp.float32)
    assert run(mlp_graph, mlp_params, x, ExecutionMode.MONOLITHIC).launches == 1
    assert run(mlp_graph, mlp_params, x, ExecutionMode.FLEXIBLE_DMA).launches == 2
    assert run(mlp_graph, mlp_params, x, ExecutionMode.SIDEBAR).launches == 1


def test_segmentation(mlp_graph):
    chains = segment_static_chains(mlp_graph)
    assert len(chains) == 2  # [w1, softplus], [w2]


def test_accounting_modes_differ_only_in_movement(mlp_graph):
    a_mono = account(mlp_graph, ExecutionMode.MONOLITHIC)
    a_dma = account(mlp_graph, ExecutionMode.FLEXIBLE_DMA)
    a_sb = account(mlp_graph, ExecutionMode.SIDEBAR)
    # same static work everywhere
    assert a_mono.mxu_flops == a_dma.mxu_flops == a_sb.mxu_flops
    assert a_mono.hbm_weight_bytes == a_dma.hbm_weight_bytes == a_sb.hbm_weight_bytes
    # only flexible-DMA round-trips intermediates through HBM
    assert a_dma.hbm_intermediate_bytes > 0
    assert a_mono.hbm_intermediate_bytes == a_sb.hbm_intermediate_bytes == 0
    # only the sidebar uses sidebar traffic + handshakes
    assert a_sb.sidebar_bytes > 0 and a_sb.handshakes == 2
    assert a_dma.sidebar_bytes == 0


def test_paper_ordering_edp(mlp_graph):
    ests = {
        m.value: estimate(account(mlp_graph, m)) for m in ExecutionMode
    }
    norm = normalized_edp(ests)
    # Figure 8: flexible-DMA much worse; sidebar close to monolithic
    assert norm["flexible_dma"] > 1.3
    assert 1.0 <= norm["sidebar"] < 1.3
    assert norm["sidebar"] < norm["flexible_dma"]


def test_monolithic_is_frozen_at_build(mlp_graph, mlp_params):
    """The paper's central claim about fixed-function hardware: changing
    the algorithm after 'tape-out' does not change the monolithic design,
    but the sidebar design picks it up via the function table."""
    table = make_default_table()
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 64), jnp.float32)
    mono = build_monolithic(mlp_graph, table)
    before = np.asarray(mono(mlp_params, x))

    # the field discovers a better activation: hot-swap softplus
    table.register("softplus", lambda v: jnp.maximum(v, 0.0), overwrite=True)

    after = np.asarray(mono(mlp_params, x))
    np.testing.assert_array_equal(before, after)  # frozen silicon

    sidebar_out = np.asarray(
        run(mlp_graph, mlp_params, x, ExecutionMode.SIDEBAR, table).output
    )
    assert not np.allclose(sidebar_out, before)  # flexible design updated


def test_sidebar_stats_collected(mlp_graph, mlp_params):
    x = jnp.ones((8, 64), jnp.float32)
    res = run(mlp_graph, mlp_params, x, ExecutionMode.SIDEBAR)
    st = res.sidebar.stats
    assert st.host_invocations == 1
    assert st.handshakes == 2
    assert st.bytes_written_acc == 8 * 256 * 4
    assert st.bytes_read_host == 8 * 256 * 4
