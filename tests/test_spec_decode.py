"""Speculative decoding: bit-exact acceleration through the paged pool.

The contract under test is stronger than "speculative decoding works":
the OUTPUT stream is token-identical to plain (non-speculative) decode
no matter what the draft model proposes — greedy and sampled alike, on
the GQA, int8-KV, and MLA+MoE cache families — because the verifier
samples every position with the same position-keyed PRNG plain decode
uses, and a draft is accepted exactly when it guessed that token. The
pool-side contract is just as sharp: drafted positions live in spare
scratch rows outside the allocator, so a rejected draft allocates
nothing and copies nothing (allocator counters match plain decode
exactly), while every step still emits at least one token (the target's
own correction rides along for free).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.kernels import ops as kops
from repro.launch.sampling import SamplingParams
from repro.launch.scheduler import PagedContinuousBatchingServer
from repro.launch.serve import Server
from repro.launch.spec import SpecConfig, accepted_prefix
from repro.models.registry import get_model

ARCHS = ["nemotron-4-15b", "nemotron-int8", "deepseek-v3-671b"]


def _cfg(arch: str):
    if arch == "nemotron-int8":
        cfg = dataclasses.replace(
            cfglib.get_smoke_config("nemotron-4-15b"),
            kv_cache_dtype=jnp.int8,
        )
    else:
        cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        # no-drop capacity: co-verified positions share expert capacity
        # (same caveat as chunked prefill — see the scheduler docstring)
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.fixture(scope="module")
def served():
    out = {}
    for arch in ARCHS:
        cfg = _cfg(arch)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        out[arch] = (cfg, params, Server(cfg, params, max_len=48))
    return out


def _traffic(cfg, n, seed=0, max_prompt=14):
    rng = np.random.RandomState(seed)
    return [
        (rng.randint(0, cfg.vocab_size, size=rng.randint(2, max_prompt))
         .astype(np.int32), int(rng.randint(1, 9)))
        for _ in range(n)
    ]


def _server(cfg, params, spec, **kw):
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_len", 48)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("segment", 4)
    return PagedContinuousBatchingServer(cfg, params, spec=spec, **kw)


def _oracle(cfg, params, k=3):
    """The target drafts for itself: greedy acceptance is exactly 1.0,
    so oracle runs exercise the maximal accept/commit path."""
    return SpecConfig(draft_cfg=cfg, draft_params=params, k=k)


def _check_exact(solo, done, reqs, samples=None, arch=""):
    for r in done:
        prompt, gen = reqs[r.rid]
        sample = None if samples is None else samples.get(r.rid)
        assert r.generated == gen
        ref = solo.generate(jnp.asarray(prompt)[None, :], gen,
                            decode="loop", sample=sample)
        np.testing.assert_array_equal(
            np.asarray(ref.tokens)[0, prompt.size:], r.tokens,
            err_msg=f"{arch} rid {r.rid}: speculative != solo decode",
        )


# ---------------------------------------------------------------------------
# bit-exactness across cache families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_spec_greedy_matches_solo_decode(arch, served):
    """Greedy speculative decode emits EXACTLY the solo-decode tokens on
    every cache family — the tier-1 acceptance gate."""
    cfg, params, solo = served[arch]
    sched = _server(cfg, params, _oracle(cfg, params))
    reqs = _traffic(cfg, 7, seed=3)
    rids = [sched.submit(p, g) for p, g in reqs]
    done = sched.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    _check_exact(solo, done, reqs, arch=arch)
    assert sched.stats.spec_steps > 0
    assert sched.mgr.alloc.in_use == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_spec_sampled_stream_matches(arch, served):
    """Mixed greedy/sampled traffic: the position-keyed PRNG makes the
    whole emitted stream (not just accepted prefixes) identical to the
    non-speculative stream — acceptance means "the draft guessed the
    sampled token", so rejects re-derive it from the target."""
    cfg, params, solo = served[arch]
    sched = _server(cfg, params, _oracle(cfg, params))
    reqs = _traffic(cfg, 6, seed=5)
    samples = {}
    for i, (p, g) in enumerate(reqs):
        sp = SamplingParams(temperature=0.9, seed=i) if i % 2 else None
        rid = sched.submit(p, g, sample=sp)
        samples[rid] = sp
    done = sched.run()
    _check_exact(solo, done, reqs, samples, arch)


def test_oracle_draft_accepts_everything(served):
    """Greedy oracle drafting (draft == target) must be fully accepted:
    the draft's dense-slab argmax equals the verifier's paged argmax at
    every position — the slab == paged bit-exactness invariant seen
    through the acceptance counter."""
    cfg, params, _ = served["nemotron-4-15b"]
    sched = _server(cfg, params, _oracle(cfg, params))
    for p, g in _traffic(cfg, 5, seed=7):
        sched.submit(p, g)
    sched.run()
    assert sched.stats.spec_drafted > 0
    assert sched.stats.spec_accepted == sched.stats.spec_drafted
    assert sched.stats.spec_acceptance_rate == 1.0


# ---------------------------------------------------------------------------
# rejection: no pool footprint, guaranteed progress
# ---------------------------------------------------------------------------

def test_rejected_drafts_never_touch_the_pool(served):
    """A worthless draft (same arch, random weights) is rejected nearly
    always — yet the stream stays bit-exact, every request completes
    (>= 1 token per step: the target's correction), and the allocator
    records EXACTLY the plain-decode block traffic: zero extra allocs,
    zero scratch->pool commit copies for rejected spans."""
    cfg, params, solo = served["nemotron-4-15b"]
    api = get_model(cfg)
    bad = SpecConfig(draft_cfg=cfg,
                     draft_params=api.init(jax.random.PRNGKey(7), cfg),
                     k=3)
    reqs = _traffic(cfg, 5, seed=9)

    plain = _server(cfg, params, None)
    for p, g in reqs:
        plain.submit(p, g)
    plain.run()

    sched = _server(cfg, params, bad)
    rec: list = []
    for p, g in reqs:
        sched.submit(p, g)
    with kops.record_dispatches(rec):
        done = sched.run()
    _check_exact(solo, done, reqs)
    # low acceptance (random draft), but never a correctness event
    assert sched.stats.spec_acceptance_rate < 0.5
    # the allocator never saw the drafts: identical counters to plain
    assert sched.mgr.counters.allocs == plain.mgr.counters.allocs
    copies = [d for d in rec if d.op == "spec_commit_copy"]
    assert sched.stats.spec_commit_copies == 0
    assert copies == []


def test_full_rejection_steps_make_progress(served):
    """Even a step whose every draft is rejected emits one token; the
    per-step emit is bounded by [1, k+1], so total steps never exceed
    the requested generation length."""
    cfg, params, _ = served["nemotron-4-15b"]
    api = get_model(cfg)
    bad = SpecConfig(draft_cfg=cfg,
                     draft_params=api.init(jax.random.PRNGKey(7), cfg),
                     k=3)
    sched = _server(cfg, params, bad, num_slots=1)
    sched.submit(np.arange(1, 8, dtype=np.int32), 6)
    (r,) = sched.run()
    assert r.generated == 6
    # with one slot, each spec step advances the lone row by >= 1
    assert sched.stats.spec_steps <= 6
    assert sched.stats.decode_steps == 6


def test_accepted_prefix_is_a_prefix():
    """A draft matching AFTER a miss is meaningless (the target's logits
    there were conditioned on the rejected token) — only the prefix
    counts."""
    assert accepted_prefix(np.array([1, 2, 3]), np.array([1, 2, 3, 9])) == 3
    assert accepted_prefix(np.array([1, 5, 3]), np.array([1, 2, 3, 9])) == 1
    assert accepted_prefix(np.array([4, 2, 3]), np.array([1, 2, 3, 9])) == 0
    assert accepted_prefix(np.array([], np.int32), np.array([7])) == 0


# ---------------------------------------------------------------------------
# degeneration, validation
# ---------------------------------------------------------------------------

def test_spec_k0_degenerates_to_plain_decode(served):
    """k=0 disables speculation entirely: identical tokens AND identical
    executables — no draft or verify program is ever built."""
    cfg, params, solo = served["nemotron-4-15b"]
    reqs = _traffic(cfg, 5, seed=13)
    sched = _server(cfg, params,
                    SpecConfig(draft_cfg=cfg, draft_params=params, k=0))
    for p, g in reqs:
        sched.submit(p, g)
    done = sched.run()
    _check_exact(solo, done, reqs)
    kinds = {k[0] for k in sched.executable_cache_keys()}
    assert "draft" not in kinds and "specv" not in kinds
    assert sched.stats.spec_steps == 0


def test_spec_config_validation(served):
    cfg, params, _ = served["nemotron-4-15b"]
    with pytest.raises(ValueError, match="k must be >= 0"):
        SpecConfig(draft_cfg=cfg, draft_params=params, k=-1)
    small_vocab = dataclasses.replace(cfg, vocab_size=cfg.vocab_size // 2)
    with pytest.raises(ValueError, match="vocab_size"):
        _server(cfg, params,
                SpecConfig(draft_cfg=small_vocab, draft_params=params, k=2))


# ---------------------------------------------------------------------------
# interaction with preemption and the prefix cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["nemotron-4-15b", "deepseek-v3-671b"])
def test_spec_with_preemption_bitexact(arch, served):
    """A deliberately tiny pool under priority traffic: speculative rows
    get spilled mid-stream (sometimes between draft and commit — the
    round is discarded and redone after restore), and the drained
    streams still match solo decode token for token."""
    cfg, params, solo = served[arch]
    # 5 allocatable blocks < 2 * 3-block grown spans: lazy growth hits
    # the wall mid-generation (test_preemption's _tight_server shape)
    sched = _server(cfg, params, _oracle(cfg, params), num_slots=2,
                    num_blocks=6, scheduling="edf")
    reqs = {}
    rng = np.random.RandomState(21)
    for i in range(2):
        p = rng.randint(0, cfg.vocab_size, size=6).astype(np.int32)
        reqs[sched.submit(p, 18, priority=0)] = (p, 18)
    sched.step()  # backlog mid-flight ...
    p = rng.randint(0, cfg.vocab_size, size=12).astype(np.int32)
    reqs[sched.submit(p, 6, priority=1, ttft_target=30.0)] = (p, 6)
    done = sched.run()
    assert len(done) == 3
    _check_exact(solo, done, reqs, arch=arch)
    assert sched.stats.preemptions > 0, "tiny pool never preempted"
    assert sched.stats.restores > 0
    assert sched.mgr.alloc.in_use == 0
    assert len(sched.spill) == 0


def test_spec_with_prefix_cache_hits(served):
    """Shared-prefix waves through the speculative path: spliced prefix
    blocks + scratch-verified drafts still produce solo-exact tokens,
    and the prefix index actually hit."""
    cfg, params, solo = served["nemotron-4-15b"]
    sched = _server(cfg, params, _oracle(cfg, params), num_slots=2,
                    block_size=4, prefill_chunk=4)
    rng = np.random.RandomState(17)
    system = rng.randint(0, cfg.vocab_size, size=9).astype(np.int32)
    reqs = {}
    for i in range(4):
        tail = rng.randint(0, cfg.vocab_size, size=3 + i).astype(np.int32)
        p = np.concatenate([system, tail])
        reqs[sched.submit(p, 4)] = (p, 4)
    done = sched.run()
    _check_exact(solo, done, reqs)
    assert sched.stats.prefix_block_hits > 0
