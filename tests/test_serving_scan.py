"""Scan-compiled decode + per-layer plan dispatch (PR 3 tentpole).

Invariants:
  * ``Server.generate(decode="scan")`` — N tokens in one compiled
    program — is token-for-token identical to the PR-2 per-token Python
    loop, on an attention-cache arch and a recurrent-state arch.
  * A heterogeneous ``ExecutionPlan`` reaches the kernels: two layers
    planned at different ring depths trace two different kernel
    variants (observed via the ``kernels.ops`` dispatch recorder).
  * ``host_activation`` prechecks tileability (no exception control
    flow): ineligible shapes route to the oracle without the kernel
    ever being entered.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.core.modes import ExecutionMode, ExecutionPlan, LayerPlan
from repro.kernels import activations
from repro.kernels import ops as kops
from repro.launch.serve import Server
from repro.models.registry import get_model


def _server(arch, max_len=48, **kw):
    cfg = cfglib.get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, Server(cfg, params, max_len=max_len, **kw)


# two cache disciplines: position-masked KV (pooled+reused buffers) and
# recurrent state (fresh per generate)
@pytest.mark.parametrize("arch", ["nemotron-4-15b", "rwkv6-7b"])
def test_scan_decode_matches_loop(arch):
    cfg, server = _server(arch)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size, dtype=jnp.int32
    )
    loop = server.generate(prompts, 12, decode="loop")
    scan = server.generate(prompts, 12, decode="scan")
    np.testing.assert_array_equal(
        np.asarray(loop.tokens), np.asarray(scan.tokens),
        err_msg=f"{arch}: scan decode diverged from the loop",
    )
    assert scan.generated == 12 and scan.prompt_len == 8
    # the scan executable is cached per (step count, mesh): a second
    # call with the same (batch, gen) must reuse it (meshless => None)
    assert set(server._decode_scans) == {(11, None)}
    server.generate(prompts, 12, decode="scan")
    assert set(server._decode_scans) == {(11, None)}


def test_scan_decode_single_token_and_cache_pool():
    cfg, server = _server("nemotron-4-15b")
    prompts = jnp.zeros((2, 4), jnp.int32)
    out = server.generate(prompts, 1)
    assert out.tokens.shape == (2, 5)
    # KV-masked family: the cache buffer is pooled across generate calls
    assert 2 in server._cache_pool
    before = jax.tree.leaves(server._cache_pool[2])[0].shape
    server.generate(prompts, 3)
    assert jax.tree.leaves(server._cache_pool[2])[0].shape == before


def _plan_cfg():
    cfg = cfglib.get_smoke_config("nemotron-4-15b")
    # tileable sidebar-kernel shapes + pallas routing; plain (non-gated)
    # MLP is the kernel the per-layer plan dispatches between variants of
    return dataclasses.replace(cfg, d_model=128, d_ff=128, num_heads=2,
                               num_kv_heads=2, use_pallas=True)


def test_per_layer_plan_traces_both_kernel_variants():
    """The acceptance probe: two layers planned at different depths must
    trace two different kernel variants in one Server."""
    cfg = _plan_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    plan = ExecutionPlan(
        default=LayerPlan(ExecutionMode.SIDEBAR),
        layers={0: LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=2),
                1: LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=3)},
    )
    server = Server(cfg, params, max_len=24, plan=plan)
    # heterogeneous plan => the layer stack unrolls (one trace per layer)
    assert server.cfg.scan_layers is False
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (8, 8), 0, cfg.vocab_size, dtype=jnp.int32
    )
    rec = []
    with kops.record_dispatches(rec):
        out = server.generate(prompts, 4)
    assert out.tokens.shape == (8, 12)
    mlp = {(d.layer, d.variant, d.depth)
           for d in rec if d.op == "sidebar_mlp" and d.used_kernel}
    assert (0, "pipelined", 2) in mlp, mlp
    assert (1, "pipelined", 3) in mlp, mlp
    # and nothing ran at a depth the plan didn't ask for
    assert {d for (_, _, d) in mlp} == {2, 3}


def test_per_layer_plan_matches_uniform_tokens():
    """Kernel-variant dispatch is a schedule choice: per-layer depths
    must not change the generated tokens."""
    cfg = _plan_cfg()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (8, 8), 0, cfg.vocab_size, dtype=jnp.int32
    )
    uniform = Server(cfg, params, max_len=24,
                     plan=LayerPlan(ExecutionMode.SIDEBAR))
    per_layer = Server(cfg, params, max_len=24, plan=ExecutionPlan(
        default=LayerPlan(ExecutionMode.SIDEBAR),
        layers={0: LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=2),
                1: LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=3)},
    ))
    a = uniform.generate(prompts, 5).tokens
    b = per_layer.generate(prompts, 5).tokens
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uniform_execution_plan_keeps_scanned_stack():
    cfg = cfglib.get_smoke_config("nemotron-4-15b")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    plan = ExecutionPlan.uniform("sidebar_pipelined", depth=2)
    server = Server(cfg, params, max_len=24, plan=plan)
    assert server.cfg.scan_layers is True


def test_heterogeneous_plan_rejected_for_non_unrollable_family():
    """Families outside the generic transformer's dense/moe stacks trace
    one variant; a per-layer plan there must fail loudly, not silently
    serve the default (regression: silent no-op on rwkv/vlm)."""
    cfg = cfglib.get_smoke_config("rwkv6-7b")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    plan = ExecutionPlan(
        default=LayerPlan(ExecutionMode.SIDEBAR),
        layers={0: LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=4)},
    )
    with pytest.raises(ValueError, match="heterogeneous"):
        Server(cfg, params, max_len=24, plan=plan)
    # a uniform ExecutionPlan stays fine for the same family
    Server(cfg, params, max_len=24,
           plan=ExecutionPlan.uniform("sidebar_pipelined", depth=2))


def test_server_rejects_non_sidebar_default():
    cfg = cfglib.get_smoke_config("nemotron-4-15b")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="SIDEBAR"):
        Server(cfg, params, plan=ExecutionMode.MONOLITHIC)


def test_execution_plan_by_index_and_uniformity():
    d2 = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=2)
    d4 = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=4)
    plan = ExecutionPlan.by_index([d2, d4, d2])
    assert plan.default == d2          # modal choice
    assert plan.for_layer(1) == d4
    assert plan.for_layer("1") == d4   # str/int keys resolve alike
    assert plan.for_layer(None) == d2
    assert not plan.is_uniform
    assert ExecutionPlan.by_index([d2, d2]).is_uniform
    # hashable fingerprint for executable caches
    assert plan.cache_key() == ExecutionPlan.by_index([d2, d4, d2]).cache_key()
    assert plan.cache_key() != ExecutionPlan.by_index([d2, d2]).cache_key()


def test_layer_scope_resolves_ambient_plan():
    d2 = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=2)
    d8 = LayerPlan(ExecutionMode.SIDEBAR_PIPELINED, depth=8)
    plan = ExecutionPlan(default=d2, layers={3: d8})
    with kops.execution_plan(plan):
        assert kops.current_plan() == d2
        with kops.layer_scope(3):
            assert kops.current_plan() == d8
            with kops.layer_scope(0):
                assert kops.current_plan() == d2
            assert kops.current_plan() == d8
        assert kops.current_plan() == d2
        assert kops.current_full_plan() is plan


def test_host_activation_prechecks_instead_of_catching(monkeypatch):
    """Untileable shapes route to the oracle WITHOUT entering the kernel
    (the old code caught the kernel's ValueError)."""
    from repro.core import constants
    from repro.kernels import ops as O

    big_n = constants.VMEM_BYTES_PER_CHIP // 32 + 128  # rowwise VMEM bust
    assert not activations.tileable((4, big_n), "softmax")
    assert activations.tileable((32, 256), "softmax")

    def boom(*a, **k):  # the kernel must never be entered
        raise AssertionError("kernel entered for ineligible shape")

    monkeypatch.setattr(O, "_activation_kernel", boom)
    x = jnp.ones((4, big_n), jnp.float32)
    y = O.host_activation(x, "softmax", interpret=True)
    np.testing.assert_allclose(np.asarray(y), 1.0 / big_n, rtol=1e-6)
    monkeypatch.undo()
    # explicit use_kernel=True on an untileable shape now fails loudly
    # (the old try/except silently routed it to the oracle)
    with pytest.raises(ValueError):
        O.host_activation(x, "softmax", use_kernel=True, interpret=True)


def test_host_activation_kernel_still_used_when_eligible():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 256), jnp.float32)
    got = kops.host_activation(x, "softmax", interpret=True)
    want = jax.nn.softmax(x, axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# -- chunked prefill (PR 5) ---------------------------------------------------

@pytest.mark.parametrize("arch", ["nemotron-4-15b", "deepseek-v3-671b"])
def test_chunked_prefill_matches_whole_prompt(arch):
    """prefill_chunk splits the prompt's KV build into bounded chunks
    written at their true offsets — token-for-token identical to the
    one-shot prefill on GQA and MLA+MoE caches, at chunk sizes that do
    and don't divide the prompt length."""
    cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_len=48)
    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (2, 11), 0, cfg.vocab_size, dtype=jnp.int32
    )
    ref = server.generate(prompts, 6, decode="loop")
    for chunk in (4, 5, 11, 64):
        got = server.generate(prompts, 6, decode="loop",
                              prefill_chunk=chunk)
        np.testing.assert_array_equal(
            np.asarray(ref.tokens), np.asarray(got.tokens),
            err_msg=f"{arch}: chunk={chunk} diverged",
        )
    # scan decode composes with chunked prefill too
    got = server.generate(prompts, 6, decode="scan", prefill_chunk=4)
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(got.tokens))


def test_chunked_prefill_rejected_where_unsupported():
    cfg, server = _server("rwkv6-7b")
    prompts = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(ValueError, match="chunked prefill"):
        server.generate(prompts, 4, prefill_chunk=4)
    cfg, server = _server("nemotron-4-15b")
    with pytest.raises(ValueError, match="prefill_chunk"):
        server.generate(prompts, 4, prefill_chunk=0)
