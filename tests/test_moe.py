"""MoE dispatch: routing invariants + shard_map/local equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.function_table import DEFAULT_TABLE
from repro.models import layers as L
from repro.models import moe as M


def _cfg(**kw):
    base = dict(
        arch_id="m", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64, num_experts=8,
        experts_per_token=2, moe_d_ff=48, dtype=jnp.float32,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_router_weights_normalized():
    cfg = _cfg()
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    wr = jax.random.normal(jax.random.PRNGKey(1), (32, 8)) * 0.1
    w, ids = M._route(x, wr, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < 8 and int(ids.min()) >= 0


def test_capacity_formula():
    cfg = _cfg(capacity_factor=1.25)
    # 256 tokens * 2 / 8 experts * 1.25 = 80
    assert M._capacity(256, cfg) == 80
    assert M._capacity(2, cfg) == 2      # never exceeds tokens
    assert M._capacity(64, cfg) % 8 == 0  # lane-aligned


def test_single_expert_identity_equivalence():
    """With 1 expert and top-1 routing + huge capacity, MoE == dense MLP."""
    cfg = _cfg(num_experts=1, experts_per_token=1, capacity_factor=8.0)
    specs = M.moe_param_specs(cfg, L.HOST)
    params = L.materialize(jax.random.PRNGKey(0), specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    y = M.moe(params, cfg, x, table=DEFAULT_TABLE, minfo=L.HOST, mesh=None)

    act = DEFAULT_TABLE.lookup("silu")
    x2 = x.reshape(16, 32)
    g = act(x2 @ params["w_gate"][0])
    u = x2 @ params["w_up"][0]
    want = ((g * u) @ params["w_down"][0]).reshape(2, 8, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_shared_expert_always_on():
    cfg = _cfg(num_shared_experts=1)
    specs = M.moe_param_specs(cfg, L.HOST)
    params = L.materialize(jax.random.PRNGKey(0), specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32)) * 0.5
    y_full = M.moe(params, cfg, x, table=DEFAULT_TABLE, minfo=L.HOST, mesh=None)
    # zero the routed experts: output must reduce to the shared expert path
    params_zero = dict(params)
    for k in ("w_gate", "w_up", "w_down"):
        params_zero[k] = jnp.zeros_like(params[k])
    y_shared = M.moe(params_zero, cfg, x, table=DEFAULT_TABLE, minfo=L.HOST,
                     mesh=None)
    assert not np.allclose(np.asarray(y_shared), 0.0)
    assert not np.allclose(np.asarray(y_full), np.asarray(y_shared))


def test_shard_map_matches_local_on_unit_mesh():
    """shard_map dispatch on a (1,1) mesh must equal the local path."""
    cfg = _cfg()
    specs = M.moe_param_specs(cfg, L.HOST)
    params = L.materialize(jax.random.PRNGKey(0), specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5

    y_local = M.moe(params, cfg, x, table=DEFAULT_TABLE, minfo=L.HOST,
                    mesh=None)
    from repro.parallel.compat import auto_mesh

    mesh = auto_mesh((1, 1), ("data", "model"))
    minfo = L.MeshInfo.from_axes(("data", "model"))
    with mesh:
        y_sm = M.moe(params, cfg, x, table=DEFAULT_TABLE, minfo=minfo,
                     mesh=mesh)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_sm),
                               rtol=2e-4, atol=2e-4)


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(num_shared_experts=1, capacity_factor=4.0)
    specs = M.moe_param_specs(cfg, L.HOST)
    params = L.materialize(jax.random.PRNGKey(0), specs)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5

    def loss(p):
        y = M.moe(p, cfg, x, table=DEFAULT_TABLE, minfo=L.HOST, mesh=None)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0, "router got no gradient"
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["shared"]["w_up"]).sum()) > 0
