"""Sampled decoding determinism (PR 4).

The position-keyed PRNG rule (``launch.sampling``): the token at
sequence index p is keyed by (request base key, p) — never by batch
composition, slot index, segment length, or decode style. Invariants:

  * temperature 0 is BIT-identical to greedy (scan and loop), on the
    dense/GQA, int8-KV, and MLA+MoE cache families;
  * scan and loop decode produce identical sampled streams;
  * same seed => same tokens; different seed => different tokens;
  * a scheduler request matches solo ``Server.generate`` row 0, even
    sharing a segment batch with greedy neighbours;
  * a scheduler restarted mid-stream (resubmit prompt + tokens-so-far,
    same seed) continues the exact stream;
  * top-k=1 collapses to greedy at any temperature (support masking).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.launch.sampling import SamplingParams
from repro.launch.scheduler import ContinuousBatchingServer
from repro.launch.serve import Server
from repro.models.registry import get_model


def _cfg(arch: str):
    if arch == "nemotron-int8":
        cfg = dataclasses.replace(
            cfglib.get_smoke_config("nemotron-4-15b"),
            kv_cache_dtype=jnp.int8,
        )
    else:
        cfg = cfglib.get_smoke_config(arch)
    if cfg.num_experts:
        # no-drop capacity: co-batched rows share expert capacity in the
        # batched segment/prefill (see scheduler docstring); the tests
        # here are about sampling, not capacity-drop semantics
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    return cfg


# dense/GQA, quantized-KV, and MLA+MoE cache families
ARCHS = ["nemotron-4-15b", "nemotron-int8", "deepseek-v3-671b"]


@pytest.fixture(scope="module")
def served():
    out = {}
    for arch in ARCHS:
        cfg = _cfg(arch)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        out[arch] = (cfg, params, Server(cfg, params, max_len=48))
    return out


def _prompts(cfg, b=2, s=6):
    return jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size, dtype=jnp.int32)


SP = SamplingParams(temperature=0.9, top_k=50, top_p=0.95, seed=11)


@pytest.mark.parametrize("arch", ARCHS)
def test_temperature_zero_is_greedy(served, arch):
    cfg, _, server = served[arch]
    prompts = _prompts(cfg)
    t0 = SamplingParams(temperature=0.0, seed=3)
    greedy = np.asarray(server.generate(prompts, 8).tokens)
    for decode in ("scan", "loop"):
        got = np.asarray(
            server.generate(prompts, 8, decode=decode, sample=t0).tokens)
        np.testing.assert_array_equal(
            greedy, got, err_msg=f"{arch}/{decode}: temp=0 != greedy")


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_matches_loop_sampled(served, arch):
    cfg, _, server = served[arch]
    prompts = _prompts(cfg)
    scan = np.asarray(
        server.generate(prompts, 10, decode="scan", sample=SP).tokens)
    loop = np.asarray(
        server.generate(prompts, 10, decode="loop", sample=SP).tokens)
    np.testing.assert_array_equal(
        scan, loop, err_msg=f"{arch}: sampled scan != loop")


def test_seed_determinism(served):
    cfg, _, server = served["nemotron-4-15b"]
    prompts = _prompts(cfg)
    a = np.asarray(server.generate(prompts, 10, sample=SP).tokens)
    b = np.asarray(server.generate(prompts, 10, sample=SP).tokens)
    np.testing.assert_array_equal(a, b, err_msg="same seed diverged")
    other = dataclasses.replace(SP, seed=SP.seed + 1)
    c = np.asarray(server.generate(prompts, 10, sample=other).tokens)
    assert not (a == c).all(), "different seeds produced identical streams"
    # sampling actually samples: the stream differs from greedy
    g = np.asarray(server.generate(prompts, 10).tokens)
    assert not (a == g).all(), "sampled stream == greedy (suspicious)"


def test_batch_rows_get_independent_streams(served):
    """Two identical prompts in one batch must not sample identical
    continuations (per-row base key = fold(seed, row))."""
    cfg, _, server = served["nemotron-4-15b"]
    row = _prompts(cfg, b=1)
    prompts = jnp.concatenate([row, row], axis=0)
    hot = SamplingParams(temperature=1.5, seed=0)
    toks = np.asarray(server.generate(prompts, 12, sample=hot).tokens)
    assert not (toks[0] == toks[1]).all(), "rows shared a PRNG stream"


def test_top_k_one_is_greedy_at_any_temperature(served):
    cfg, _, server = served["nemotron-4-15b"]
    prompts = _prompts(cfg)
    greedy = np.asarray(server.generate(prompts, 8).tokens)
    k1 = SamplingParams(temperature=5.0, top_k=1, seed=9)
    got = np.asarray(server.generate(prompts, 8, sample=k1).tokens)
    np.testing.assert_array_equal(greedy, got)


@pytest.mark.parametrize("arch", ARCHS)
def test_scheduler_sampled_matches_solo(served, arch):
    """A sampled scheduler request == solo generate row 0 with the same
    seed — through bucketed batched admission, mixed greedy/sampled
    segment batches, and slot churn."""
    cfg, params, server = served[arch]
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 3)]
    sched = ContinuousBatchingServer(cfg, params, num_slots=2, max_len=48,
                                     buckets=(8,), segment=4)
    for i, p in enumerate(prompts):
        sched.submit(p, 8, sample=SP if i % 2 == 0 else None)
    done = sched.run()
    for i, (r, p) in enumerate(zip(done, prompts)):
        sample = SP if i % 2 == 0 else None
        ref = np.asarray(server.generate(
            jnp.asarray(p)[None, :], 8, decode="loop", sample=sample,
        ).tokens)[0, p.size:]
        np.testing.assert_array_equal(
            r.tokens, ref,
            err_msg=f"{arch} rid {r.rid}: scheduler != solo "
                    f"({'sampled' if sample else 'greedy'})")


def test_scheduler_restart_mid_stream_preserves_stream(served):
    """Kill the scheduler mid-request, resubmit prompt + tokens-so-far
    with the same seed: the continuation is the exact stream an
    uninterrupted run produces (keys depend only on (seed, position))."""
    cfg, params, server = served["nemotron-4-15b"]
    prompt = np.asarray(_prompts(cfg, b=1))[0]
    full = np.asarray(server.generate(
        jnp.asarray(prompt)[None, :], 10, sample=SP).tokens)[0, prompt.size:]

    s1 = ContinuousBatchingServer(cfg, params, num_slots=1, max_len=48,
                                  buckets=(8,), segment=3)
    s1.submit(prompt, 10, sample=SP)
    s1.step()
    part = s1.slot_tokens(0)
    assert 0 < part.size < 10
    np.testing.assert_array_equal(part, full[:part.size])

    s2 = ContinuousBatchingServer(cfg, params, num_slots=1, max_len=48,
                                  buckets=(8,), segment=3)
    s2.submit(np.concatenate([prompt, part]), 10 - part.size, sample=SP)
    (rest,) = s2.run()
    np.testing.assert_array_equal(
        np.concatenate([part, rest.tokens]), full,
        err_msg="restart mid-stream changed the sampled stream")


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    SamplingParams(temperature=0.0, top_k=1, top_p=1.0)  # boundary values ok
